"""Tiered paged-KV runtime: the paper's technique, TPU-native (Pillar B)."""
from .tiered_kv import (COLD, FANOUT, HOT, TieredKV, append_token,
                        block_size_of, gather_kv, init, lookup_blocks,
                        migrate_sequence, release_sequence,
                        table_invariant_violations)
