"""Tiered paged KV cache with a hierarchical, Radiant-managed block table.

The TPU translation of the paper (DESIGN.md section 2, Pillar B):

  * two block pools per attention group — HOT (device HBM) and COLD (host
    memory on TPU; a second buffer here),
  * a two-level block table: the *upper* level (sequence -> leaf-page id)
    is small and always lives in fast memory (BHi: <0.2% of table bytes,
    touched on every lookup), while *leaf pages* of ``FANOUT`` (tier, slot)
    entries migrate between tiers with their data blocks,
  * Radiant invariant (Algorithm 1): a leaf page is HOT iff at least one
    KV block it maps is hot; demoting the last hot block under a leaf
    triggers the leaf's demotion, promoting any block triggers the leaf's
    promotion.  ``leaf_hot_children`` mirrors the kernel implementation's
    per-PTE-page DRAM-children counter.

Everything is functional JAX over a :class:`TieredKV` pytree, so the ops
jit/shard cleanly; the serving engine (repro.serving.engine) sequences
them.  ``gather_kv`` is the XLA reference data path; the Pallas
``paged_attention`` kernel consumes the same table layout with the upper
level scalar-prefetched into SMEM.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

I32 = jnp.int32
HOT, COLD = 0, 1
FANOUT = 64          # block-table entries per leaf page


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TieredKV:
    # pools: [G, n_blocks, block_size, KH, Dh]
    hot_k: jax.Array
    hot_v: jax.Array
    cold_k: jax.Array
    cold_v: jax.Array
    # hierarchical block table
    upper: jax.Array              # i32[n_seqs, max_leaf]  -> leaf page id
    leaf_tier_slot: jax.Array     # i32[n_leaf, FANOUT, 2] (tier, slot)
    leaf_tier: jax.Array          # i32[n_leaf]  tier of the leaf page itself
    leaf_hot_children: jax.Array  # i32[n_leaf]
    # allocators (stack free-lists)
    hot_free: jax.Array           # i32[n_hot] slot ids
    hot_free_top: jax.Array       # i32[] items remaining
    cold_free: jax.Array
    cold_free_top: jax.Array
    leaf_free: jax.Array          # i32[n_leaf]
    leaf_free_top: jax.Array
    # sequences
    seq_len: jax.Array            # i32[n_seqs] tokens written
    # stats (Radiant bookkeeping, Table-5 analogues)
    stats: jax.Array              # i32[6]: blk_promote, blk_demote,
    #                                leaf_promote, leaf_demote,
    #                                leaf_already, hot_alloc_fallback


STAT_BLK_PROMOTE, STAT_BLK_DEMOTE, STAT_LEAF_PROMOTE, STAT_LEAF_DEMOTE, \
    STAT_LEAF_ALREADY, STAT_FALLBACK = range(6)


def init(n_groups: int, n_hot: int, n_cold: int, block_size: int,
         kv_heads: int, head_dim: int, n_seqs: int, max_seq: int,
         dtype=jnp.bfloat16) -> TieredKV:
    max_blocks = -(-max_seq // block_size)
    max_leaf = -(-max_blocks // FANOUT)
    n_leaf = n_seqs * max_leaf            # worst case: no sharing
    pool = lambda n: jnp.zeros((n_groups, n, block_size, kv_heads, head_dim),
                               dtype)
    return TieredKV(
        hot_k=pool(n_hot), hot_v=pool(n_hot),
        cold_k=pool(n_cold), cold_v=pool(n_cold),
        upper=jnp.full((n_seqs, max_leaf), -1, I32),
        leaf_tier_slot=jnp.full((n_leaf, FANOUT, 2), -1, I32),
        leaf_tier=jnp.full((n_leaf,), -1, I32),
        leaf_hot_children=jnp.zeros((n_leaf,), I32),
        hot_free=jnp.arange(n_hot - 1, -1, -1, dtype=I32),
        hot_free_top=jnp.asarray(n_hot, I32),
        cold_free=jnp.arange(n_cold - 1, -1, -1, dtype=I32),
        cold_free_top=jnp.asarray(n_cold, I32),
        leaf_free=jnp.arange(n_leaf - 1, -1, -1, dtype=I32),
        leaf_free_top=jnp.asarray(n_leaf, I32),
        seq_len=jnp.zeros((n_seqs,), I32),
        stats=jnp.zeros((6,), I32),
    )


def block_size_of(kv: TieredKV) -> int:
    return kv.hot_k.shape[2]


# ---------------------------------------------------------------------------
# allocation
# ---------------------------------------------------------------------------
def _pop(free, top):
    top = top - 1
    return free[top], top


def _push(free, top, slot):
    free = free.at[top].set(slot)
    return free, top + 1


def append_token(kv: TieredKV, seq: jax.Array, k: jax.Array, v: jax.Array
                 ) -> TieredKV:
    """Write one token's KV ([G, KH, Dh]) for sequence ``seq``.

    Allocates a hot block (cold fallback when the hot pool is exhausted —
    the paper's "allow spill, then migrate" §3.5 lesson) and a leaf table
    page on block/leaf boundaries.
    """
    bs = block_size_of(kv)
    pos = kv.seq_len[seq]
    blk = pos // bs
    off = pos % bs
    leaf_idx = blk // FANOUT
    entry = blk % FANOUT

    # --- leaf page allocation on first touch (upper level stays pinned) ----
    leaf_id = kv.upper[seq, leaf_idx]
    need_leaf = leaf_id < 0
    new_leaf, leaf_top = _pop(kv.leaf_free, kv.leaf_free_top)
    leaf_id = jnp.where(need_leaf, new_leaf, leaf_id)
    upper = kv.upper.at[seq, leaf_idx].set(leaf_id)
    leaf_free_top = jnp.where(need_leaf, leaf_top, kv.leaf_free_top)

    # --- block allocation on block boundary --------------------------------
    # (if both pools are exhausted the token is dropped and counted — the
    # engine sizes pools so this is an overload signal, not a data path)
    hot_ok = kv.hot_free_top > 0
    cold_ok = kv.cold_free_top > 0
    need_blk = (off == 0) & (hot_ok | cold_ok)
    hot_slot, hot_top = _pop(kv.hot_free, kv.hot_free_top)
    cold_slot, cold_top = _pop(kv.cold_free, kv.cold_free_top)
    tier = jnp.where(hot_ok, HOT, COLD)
    slot = jnp.where(hot_ok, hot_slot, cold_slot)
    hot_free_top = jnp.where(need_blk & hot_ok, hot_top, kv.hot_free_top)
    cold_free_top = jnp.where(need_blk & ~hot_ok, cold_top,
                              kv.cold_free_top)
    old = kv.leaf_tier_slot[leaf_id, entry]
    tier = jnp.where(need_blk, tier, old[0])
    slot = jnp.where(need_blk, slot, old[1])
    lts = kv.leaf_tier_slot.at[leaf_id, entry].set(
        jnp.stack([tier, slot]))
    # a fresh leaf table page follows its first data block's tier (the
    # Linux default the paper studies: PT pages follow the data policy)
    leaf_tier = kv.leaf_tier.at[leaf_id].set(
        jnp.where(need_leaf, tier, kv.leaf_tier[leaf_id]))
    lhc = kv.leaf_hot_children.at[leaf_id].add(
        jnp.where(need_blk & (tier == HOT), 1, 0))
    stats = kv.stats.at[STAT_FALLBACK].add(
        jnp.where(need_blk & ~hot_ok, 1, 0))

    # --- write the token (masked into whichever pool owns the block) -------
    is_hot = tier == HOT
    hot_idx = jnp.where(is_hot, slot, 0)
    cold_idx = jnp.where(is_hot, 0, slot)
    hot_k = kv.hot_k.at[:, hot_idx, off].set(
        jnp.where(is_hot, k, kv.hot_k[:, hot_idx, off]))
    hot_v = kv.hot_v.at[:, hot_idx, off].set(
        jnp.where(is_hot, v, kv.hot_v[:, hot_idx, off]))
    cold_k = kv.cold_k.at[:, cold_idx, off].set(
        jnp.where(is_hot, kv.cold_k[:, cold_idx, off], k))
    cold_v = kv.cold_v.at[:, cold_idx, off].set(
        jnp.where(is_hot, kv.cold_v[:, cold_idx, off], v))

    kv = dataclasses.replace(
        kv, hot_k=hot_k, hot_v=hot_v, cold_k=cold_k, cold_v=cold_v,
        upper=upper, leaf_tier_slot=lts, leaf_tier=leaf_tier,
        leaf_hot_children=lhc, hot_free_top=hot_free_top,
        cold_free_top=cold_free_top, leaf_free_top=leaf_free_top,
        seq_len=kv.seq_len.at[seq].add(1), stats=stats)
    # Beyond-paper refinement: the paper triggers table migration only on
    # data *migrations*; we also trigger on allocation, so a hot block
    # allocated under a cold leaf page (post-demotion growth) promotes the
    # leaf immediately — found by the hypothesis invariant test.
    return _leaf_trigger(kv, leaf_id, need_blk)


# ---------------------------------------------------------------------------
# lookup / gather (the "page walk")
# ---------------------------------------------------------------------------
def lookup_blocks(kv: TieredKV, seq: jax.Array, n_blocks: int
                  ) -> Tuple[jax.Array, jax.Array]:
    """Walk the table: virtual blocks 0..n_blocks-1 of ``seq`` ->
    (tier[n_blocks], slot[n_blocks]).  Two dependent gathers — upper level
    then leaf entries — exactly a radix page walk."""
    vb = jnp.arange(n_blocks)
    leaf_ids = kv.upper[seq, vb // FANOUT]                 # walk level 1
    ts = kv.leaf_tier_slot[jnp.maximum(leaf_ids, 0), vb % FANOUT]
    valid = leaf_ids >= 0
    return jnp.where(valid, ts[:, 0], -1), jnp.where(valid, ts[:, 1], -1)


def gather_kv(kv: TieredKV, seq: jax.Array, n_blocks: int
              ) -> Tuple[jax.Array, jax.Array]:
    """Materialize [G, n_blocks*bs, KH, Dh] for attention (XLA reference
    path; the Pallas kernel streams blocks instead of copying them)."""
    tier, slot = lookup_blocks(kv, seq, n_blocks)
    safe = jnp.maximum(slot, 0)
    hk = kv.hot_k[:, safe]
    hv = kv.hot_v[:, safe]
    ck = kv.cold_k[:, jnp.minimum(safe, kv.cold_k.shape[1] - 1)]
    cv = kv.cold_v[:, jnp.minimum(safe, kv.cold_v.shape[1] - 1)]
    is_hot = (tier == HOT)[None, :, None, None, None]
    k = jnp.where(is_hot, hk, ck)
    v = jnp.where(is_hot, hv, cv)
    G, nb, bs, KH, Dh = k.shape
    return (k.reshape(G, nb * bs, KH, Dh), v.reshape(G, nb * bs, KH, Dh))


# ---------------------------------------------------------------------------
# Radiant migration (data-migration-triggered table migration)
# ---------------------------------------------------------------------------
def migrate_sequence(kv: TieredKV, seq: jax.Array, to_tier: int,
                     max_blocks: int, trigger_leaf: bool = True) -> TieredKV:
    """Move every block of ``seq`` to ``to_tier`` (scheduler swap-in/out),
    then apply the Radiant trigger to each covered leaf page.

    The per-block loop is a ``fori_loop`` (bounded by max_blocks); block
    copies route through the pools (the Pallas ``block_copy`` kernel is the
    TPU data path for the same op).
    """
    bs = block_size_of(kv)

    def body(vb, kv: TieredKV) -> TieredKV:
        n_used = (kv.seq_len[seq] + bs - 1) // bs
        leaf_idx, entry = vb // FANOUT, vb % FANOUT
        leaf_id = kv.upper[seq, leaf_idx]
        valid = (vb < n_used) & (leaf_id >= 0)
        leaf_id = jnp.maximum(leaf_id, 0)
        tier = kv.leaf_tier_slot[leaf_id, entry, 0]
        slot = kv.leaf_tier_slot[leaf_id, entry, 1]
        move = valid & (tier >= 0) & (tier != to_tier)

        if to_tier == HOT:
            can = kv.hot_free_top > 0
            move = move & can
            new_slot, new_top = _pop(kv.hot_free, kv.hot_free_top)
            hot_free_top = jnp.where(move, new_top, kv.hot_free_top)
            # copy cold[slot] -> hot[new_slot]
            src_k = kv.cold_k[:, jnp.maximum(slot, 0)]
            src_v = kv.cold_v[:, jnp.maximum(slot, 0)]
            idx = jnp.where(move, new_slot, 0)
            hot_k = kv.hot_k.at[:, idx].set(
                jnp.where(move, src_k, kv.hot_k[:, idx]))
            hot_v = kv.hot_v.at[:, idx].set(
                jnp.where(move, src_v, kv.hot_v[:, idx]))
            cold_free, cold_top2 = _push(kv.cold_free, kv.cold_free_top,
                                         jnp.maximum(slot, 0))
            kv = dataclasses.replace(
                kv, hot_k=hot_k, hot_v=hot_v,
                hot_free_top=hot_free_top,
                cold_free=jnp.where(move, cold_free, kv.cold_free),
                cold_free_top=jnp.where(move, cold_top2, kv.cold_free_top))
            new_tier = HOT
        else:
            can = kv.cold_free_top > 0
            move = move & can
            new_slot, new_top = _pop(kv.cold_free, kv.cold_free_top)
            cold_free_top = jnp.where(move, new_top, kv.cold_free_top)
            src_k = kv.hot_k[:, jnp.maximum(slot, 0)]
            src_v = kv.hot_v[:, jnp.maximum(slot, 0)]
            idx = jnp.where(move, new_slot, 0)
            cold_k = kv.cold_k.at[:, idx].set(
                jnp.where(move, src_k, kv.cold_k[:, idx]))
            cold_v = kv.cold_v.at[:, idx].set(
                jnp.where(move, src_v, kv.cold_v[:, idx]))
            hot_free, hot_top2 = _push(kv.hot_free, kv.hot_free_top,
                                       jnp.maximum(slot, 0))
            kv = dataclasses.replace(
                kv, cold_k=cold_k, cold_v=cold_v,
                cold_free_top=cold_free_top,
                hot_free=jnp.where(move, hot_free, kv.hot_free),
                hot_free_top=jnp.where(move, hot_top2, kv.hot_free_top))
            new_tier = COLD

        lts = kv.leaf_tier_slot.at[leaf_id, entry].set(
            jnp.where(move, jnp.stack([jnp.asarray(new_tier, I32),
                                       new_slot]),
                      kv.leaf_tier_slot[leaf_id, entry]))
        delta = jnp.where(move, 1 if to_tier == HOT else -1, 0)
        lhc = kv.leaf_hot_children.at[leaf_id].add(delta)
        stats = kv.stats.at[
            STAT_BLK_PROMOTE if to_tier == HOT else STAT_BLK_DEMOTE].add(
            jnp.where(move, 1, 0))
        kv = dataclasses.replace(kv, leaf_tier_slot=lts,
                                 leaf_hot_children=lhc, stats=stats)
        if trigger_leaf:
            # Radiant trigger: leaf follows its children (Algorithm 1)
            kv = _leaf_trigger(kv, leaf_id, valid)
        return kv

    return jax.lax.fori_loop(0, max_blocks, body, kv)


def release_sequence(kv: TieredKV, seq: jax.Array,
                     max_blocks: int) -> TieredKV:
    """Free every block and leaf table page of a finished sequence."""
    bs = block_size_of(kv)

    def body(vb, kv: TieredKV) -> TieredKV:
        n_used = (kv.seq_len[seq] + bs - 1) // bs
        leaf_idx, entry = vb // FANOUT, vb % FANOUT
        leaf_id = kv.upper[seq, leaf_idx]
        valid = (vb < n_used) & (leaf_id >= 0)
        leaf_id = jnp.maximum(leaf_id, 0)
        tier = kv.leaf_tier_slot[leaf_id, entry, 0]
        slot = jnp.maximum(kv.leaf_tier_slot[leaf_id, entry, 1], 0)
        free_hot = valid & (tier == HOT)
        free_cold = valid & (tier == COLD)
        hot_free, hot_top = _push(kv.hot_free, kv.hot_free_top, slot)
        cold_free, cold_top = _push(kv.cold_free, kv.cold_free_top, slot)
        lts = kv.leaf_tier_slot.at[leaf_id, entry].set(
            jnp.where(valid, jnp.full((2,), -1, I32),
                      kv.leaf_tier_slot[leaf_id, entry]))
        lhc = kv.leaf_hot_children.at[leaf_id].add(
            jnp.where(free_hot, -1, 0))
        # free the leaf page itself once its last entry is cleared
        last_entry = valid & ((entry == FANOUT - 1)
                              | (vb == n_used - 1))
        leaf_free, leaf_top = _push(kv.leaf_free, kv.leaf_free_top, leaf_id)
        return dataclasses.replace(
            kv,
            hot_free=jnp.where(free_hot, hot_free, kv.hot_free),
            hot_free_top=jnp.where(free_hot, hot_top, kv.hot_free_top),
            cold_free=jnp.where(free_cold, cold_free, kv.cold_free),
            cold_free_top=jnp.where(free_cold, cold_top, kv.cold_free_top),
            leaf_tier_slot=lts, leaf_hot_children=jnp.maximum(lhc, 0),
            leaf_free=jnp.where(last_entry, leaf_free, kv.leaf_free),
            leaf_free_top=jnp.where(last_entry, leaf_top,
                                    kv.leaf_free_top),
            leaf_tier=kv.leaf_tier.at[leaf_id].set(
                jnp.where(last_entry, -1, kv.leaf_tier[leaf_id])),
            upper=kv.upper.at[seq, leaf_idx].set(
                jnp.where(last_entry, -1, kv.upper[seq, leaf_idx])))

    kv = jax.lax.fori_loop(0, max_blocks, body, kv)
    return dataclasses.replace(kv, seq_len=kv.seq_len.at[seq].set(0))


def _leaf_trigger(kv: TieredKV, leaf_id: jax.Array,
                  active: jax.Array) -> TieredKV:
    """Algorithm-1 conditions for one leaf table page:

      * promote leaf to HOT if any child block is hot and the leaf is COLD,
      * demote leaf to COLD only when its last hot child left (line 18),
      * count 'already in destination' skips (Table 5 analogue).
    """
    children_hot = kv.leaf_hot_children[leaf_id] > 0
    cur = kv.leaf_tier[leaf_id]
    want = jnp.where(children_hot, HOT, COLD)
    do = active & (cur >= 0) & (cur != want)
    already = active & (cur >= 0) & (cur == want)
    leaf_tier = kv.leaf_tier.at[leaf_id].set(jnp.where(do, want, cur))
    stats = kv.stats
    stats = stats.at[STAT_LEAF_PROMOTE].add(
        jnp.where(do & (want == HOT), 1, 0))
    stats = stats.at[STAT_LEAF_DEMOTE].add(
        jnp.where(do & (want == COLD), 1, 0))
    stats = stats.at[STAT_LEAF_ALREADY].add(jnp.where(already, 1, 0))
    return dataclasses.replace(kv, leaf_tier=leaf_tier, stats=stats)


def table_invariant_violations(kv: TieredKV) -> jax.Array:
    """Radiant invariant checker (property tests): #leaf pages whose tier
    disagrees with their children (hot children => leaf must be HOT)."""
    alive = kv.leaf_tier >= 0
    should_hot = kv.leaf_hot_children > 0
    bad = alive & ((should_hot & (kv.leaf_tier != HOT))
                   | (~should_hot & (kv.leaf_tier != COLD)))
    return jnp.sum(bad.astype(I32))
