"""Minimal functional module system: parameter specs with logical axes.

Models declare their parameters as a pytree of :class:`ParamSpec` (shape,
dtype, logical axis names, initializer).  From that single declaration we
derive:

  * ``abstract_params``  — ShapeDtypeStruct tree for ``.lower()`` dry-runs
    (no host allocation for 340B-parameter configs),
  * ``init_params``      — real arrays for smoke tests / the 100M example,
  * ``param_shardings``  — PartitionSpec tree from logical-axis rules
    (DP/TP/EP mapping lives in ``repro.distributed.sharding``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]   # e.g. ("vocab", "embed")
    dtype: str = "bfloat16"
    init: str = "normal"                      # normal | zeros | ones | scaled
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), \
            f"{self.shape} vs {self.logical_axes}"


def abstract_params(specs) -> dict:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def init_params(specs, key: jax.Array) -> dict:
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))

    def make(s: ParamSpec, k):
        dt = jnp.dtype(s.dtype)
        if s.init == "zeros":
            return jnp.zeros(s.shape, dt)
        if s.init == "ones":
            return jnp.ones(s.shape, dt)
        scale = s.scale
        if s.init == "scaled":  # 1/sqrt(fan_in) output projections
            scale = s.scale / np.sqrt(max(s.shape[0], 1))
        return (jax.random.normal(k, s.shape, jnp.float32) * scale).astype(dt)

    return jax.tree.unflatten(treedef, [make(s, k) for s, k in
                                        zip(leaves, keys)])


def logical_axes_tree(specs):
    return jax.tree.map(lambda s: s.logical_axes, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def count_params(specs) -> int:
    leaves = jax.tree.leaves(specs,
                             is_leaf=lambda x: isinstance(x, ParamSpec))
    return sum(int(np.prod(s.shape)) for s in leaves)
