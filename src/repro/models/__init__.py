"""Model zoo: the 10 assigned architectures as one composable stack."""
from .model import (decode_step, forward, init_decode_state, input_specs,
                    layer_kinds, lm_loss, make_abstract_params, make_params,
                    param_specs, period_of, prefill)
from .modules import (ParamSpec, abstract_params, count_params, init_params,
                      logical_axes_tree)
