"""Architecture assembly: ArchConfig -> params / train / prefill / decode.

Layers are grouped into *periods* (dense archs: period 1; llama4-maverick:
2 — MoE every other layer; jamba: 8 — attention at offset 3, MoE on odd
offsets) and parameters are stacked over period groups so the whole stack
lowers as one ``lax.scan`` — HLO size and compile time stay bounded for
96-layer configs.  Each group body is ``jax.remat``-wrapped (policy
configurable).

Decode state per period position:
  attention  -> KV cache [G, B, S, KH, Dh] (dense) — the paged variant
                lives in ``repro.serving`` / ``repro.memsys``
  mamba      -> conv window + SSM state (O(1) per token)
  rwkv       -> outer-product state + token-shift registers (O(1))
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import layers, mamba as mamba_mod, moe as moe_mod, rwkv as rwkv_mod
from .modules import ParamSpec, abstract_params, init_params

F32 = jnp.float32


# ---------------------------------------------------------------------------
# layer schedule
# ---------------------------------------------------------------------------
def period_of(cfg: ArchConfig) -> int:
    p = cfg.attn_every
    if cfg.moe is not None:
        p = max(p, cfg.moe.every)
        assert p % cfg.moe.every == 0
    if cfg.attn_every > 1:
        assert p % cfg.attn_every == 0
    return p


def layer_kinds(cfg: ArchConfig) -> List[Tuple[str, str]]:
    """(mixer, ffn) kind per period position."""
    kinds = []
    for i in range(period_of(cfg)):
        if cfg.rwkv:
            mixer = "time_mix"
        elif cfg.mamba is not None and cfg.attn_every > 1:
            # jamba: one attention layer per period, at offset attn_every//2-1
            mixer = "attn" if i == (cfg.attn_every // 2 - 1) else "mamba"
        else:
            mixer = "attn"
        if cfg.rwkv:
            ffn = "channel_mix"
        elif cfg.moe is not None and (i % cfg.moe.every
                                      == cfg.moe.every - 1):
            ffn = "moe"
        else:
            ffn = "mlp"
        kinds.append((mixer, ffn))
    return kinds


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------
def _norm_specs(cfg: ArchConfig, name: str) -> Dict[str, ParamSpec]:
    s = {f"{name}_scale": ParamSpec((cfg.d_model,), ("embed",),
                                    dtype="float32", init="ones")}
    if cfg.encoder_only:   # hubert uses LayerNorm with bias
        s[f"{name}_bias"] = ParamSpec((cfg.d_model,), ("embed",),
                                      dtype="float32", init="zeros")
    return s


def _attn_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, H, KH, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.dtype
    s = {
        "wq": ParamSpec((d, H * Dh), ("embed", "heads_mm"), dtype=dt),
        "wk": ParamSpec((d, KH * Dh), ("embed", "kv_mm"), dtype=dt),
        "wv": ParamSpec((d, KH * Dh), ("embed", "kv_mm"), dtype=dt),
        "wo": ParamSpec((H * Dh, d), ("heads_mm", "embed"), dtype=dt,
                        init="scaled"),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((H * Dh,), ("heads_mm",), dtype=dt, init="zeros")
        s["bk"] = ParamSpec((KH * Dh,), ("kv_mm",), dtype=dt, init="zeros")
        s["bv"] = ParamSpec((KH * Dh,), ("kv_mm",), dtype=dt, init="zeros")
    return s


def _mlp_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.dtype
    if cfg.mlp == "swiglu":
        return {"w_gate": ParamSpec((d, f), ("embed", "ff"), dtype=dt),
                "w_up": ParamSpec((d, f), ("embed", "ff"), dtype=dt),
                "w_down": ParamSpec((f, d), ("ff", "embed"), dtype=dt,
                                    init="scaled")}
    if cfg.mlp == "squared_relu":
        return {"w_in": ParamSpec((d, f), ("embed", "ff"), dtype=dt),
                "w_out": ParamSpec((f, d), ("ff", "embed"), dtype=dt,
                                   init="scaled")}
    # gelu (hubert)
    return {"w_in": ParamSpec((d, f), ("embed", "ff"), dtype=dt),
            "b_in": ParamSpec((f,), ("ff",), dtype=dt, init="zeros"),
            "w_out": ParamSpec((f, d), ("ff", "embed"), dtype=dt,
                               init="scaled"),
            "b_out": ParamSpec((d,), ("embed",), dtype=dt, init="zeros")}


def _position_specs(cfg: ArchConfig, mixer: str, ffn: str) -> Dict:
    s: Dict[str, Any] = {}
    s.update(_norm_specs(cfg, "norm1"))
    if mixer == "attn":
        s["attn"] = _attn_specs(cfg)
    elif mixer == "mamba":
        mb = cfg.mamba
        s["mamba"] = mamba_mod.mamba_param_specs(
            cfg.d_model, mb.d_state, mb.d_conv, mb.expand, cfg.dtype)
    elif mixer == "time_mix":
        s["time_mix"] = rwkv_mod.rwkv_time_mix_specs(cfg.d_model, cfg.dtype)
    s.update(_norm_specs(cfg, "norm2"))
    if ffn == "moe":
        s["moe"] = moe_mod.moe_param_specs(
            cfg.d_model, cfg.moe.d_ff, cfg.moe.n_experts, cfg.mlp,
            cfg.moe.shared_expert, cfg.dtype)
    elif ffn == "mlp":
        s["mlp"] = _mlp_specs(cfg)
    else:
        s["channel_mix"] = rwkv_mod.rwkv_channel_mix_specs(
            cfg.d_model, cfg.d_ff, cfg.dtype)
    return s


def _stack_specs(tree, n: int):
    """Prepend a stacking ("layers") axis to every spec in the tree."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.logical_axes,
                            dtype=s.dtype, init=s.init, scale=s.scale),
        tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def param_specs(cfg: ArchConfig) -> Dict:
    period = period_of(cfg)
    n_groups = cfg.n_layers // period
    assert n_groups * period == cfg.n_layers, (cfg.n_layers, period)
    kinds = layer_kinds(cfg)
    specs: Dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                           dtype=cfg.dtype),
        "final_norm": _norm_specs(cfg, "final"),
        "layers": {f"pos{i}": _stack_specs(_position_specs(cfg, *kinds[i]),
                                           n_groups)
                   for i in range(period)},
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab),
                                     ("embed", "vocab"), dtype=cfg.dtype)
    if cfg.frontend == "vision":
        specs["patch_proj"] = ParamSpec((cfg.d_model, cfg.d_model),
                                        ("embed", "embed_out"),
                                        dtype=cfg.dtype)
    return specs


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------
def _norm(cfg: ArchConfig, p, name, x):
    if cfg.encoder_only:
        return layers.layer_norm(x, p[f"{name}_scale"], p[f"{name}_bias"],
                                 cfg.norm_eps)
    return layers.rms_norm(x, p[f"{name}_scale"], cfg.norm_eps)


def _attn_full(cfg: ArchConfig, w, x, positions, mrope_pos=None):
    """Training/prefill attention over the full sequence."""
    B, S, D = x.shape
    H, KH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, w["wq"])
    k = jnp.einsum("bsd,de->bse", x, w["wk"])
    v = jnp.einsum("bsd,de->bse", x, w["wv"])
    if cfg.qkv_bias:
        q, k, v = q + w["bq"], k + w["bk"], v + w["bv"]
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, KH, Dh)
    v = v.reshape(B, S, KH, Dh)
    if cfg.rope == "rope":
        q = layers.apply_rope(q, positions)
        k = layers.apply_rope(k, positions)
    elif cfg.rope == "mrope":
        q = layers.apply_mrope(q, mrope_pos)
        k = layers.apply_mrope(k, mrope_pos)
    out = layers.chunked_attention(q, k, v, causal=not cfg.encoder_only)
    out = out.reshape(B, S, H * Dh)
    return jnp.einsum("bse,ed->bsd", out, w["wo"]), (k, v)


def _apply_group_full(cfg: ArchConfig, kinds, gparams, x, positions,
                      mrope_pos, collect_kv: bool):
    """One period of layers (full-sequence mode).  Returns (x, aux, kvs)."""
    aux = jnp.zeros((), F32)
    kvs = []
    for i, (mixer, ffn) in enumerate(kinds):
        p = gparams[f"pos{i}"]
        h = _norm(cfg, p, "norm1", x)
        if mixer == "attn":
            y, kv = _attn_full(cfg, p["attn"], h, positions, mrope_pos)
            if collect_kv:
                kvs.append(kv)
        elif mixer == "mamba":
            y = mamba_mod.mamba_apply(p["mamba"], h)
        else:
            y = rwkv_mod.time_mix_apply(p["time_mix"], h)
        x = x + y
        h = _norm(cfg, p, "norm2", x)
        if ffn == "moe":
            y, a = moe_mod.moe_apply(p["moe"], h, top_k=cfg.moe.top_k,
                                     capacity_factor=cfg.moe.capacity_factor,
                                     mlp=cfg.mlp)
            aux = aux + a
        elif ffn == "mlp":
            y = layers.mlp_apply(cfg.mlp, h, p["mlp"])
        else:
            y = rwkv_mod.channel_mix_apply(p["channel_mix"], h)
        x = x + y
    return x, aux, kvs


def _embed(cfg: ArchConfig, params, batch) -> Tuple[jax.Array, Any]:
    """Token/frontend embedding.  Returns (x [B,S,D], mrope_pos or None)."""
    if cfg.frontend == "audio":
        x = batch["frame_embeds"].astype(jnp.dtype(cfg.dtype))
        pe = layers.sinusoidal_positions(x.shape[1], cfg.d_model)
        return x + pe.astype(x.dtype), None
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    mrope_pos = None
    if cfg.frontend == "vision":
        patches = jnp.einsum("bsd,de->bse",
                             batch["patch_embeds"].astype(x.dtype),
                             params["patch_proj"])
        x = jnp.concatenate([patches, x], axis=1)
        mrope_pos = batch["mrope_pos"]
    return x, mrope_pos


def _logits_chunk(cfg, params, h):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", h, head)


def forward(cfg: ArchConfig, params, batch, *, remat_policy: str = "full",
            collect_kv: bool = False, act_constraint=None):
    """Full-sequence forward.  Returns (hidden [B,S,D], aux, kv_caches).

    ``act_constraint``: optional fn applied to the [B,S,D] residual stream
    at every group boundary — e.g. a with_sharding_constraint implementing
    sequence parallelism (S over "model"), which divides the per-chip
    scan-carry/remat footprint by the TP degree.
    """
    kinds = layer_kinds(cfg)
    x, mrope_pos = _embed(cfg, params, batch)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    if act_constraint is not None:
        x = act_constraint(x)

    def group_fn(x, gparams):
        y, aux, kvs = _apply_group_full(cfg, kinds, gparams, x, positions,
                                        mrope_pos, collect_kv)
        if act_constraint is not None:
            y = act_constraint(y)
        return y, (aux, tuple(kvs) if collect_kv else ())

    if remat_policy == "full":
        group_fn = jax.remat(group_fn)
    elif remat_policy == "dots":
        group_fn = jax.remat(
            group_fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    x, (auxs, kvs) = jax.lax.scan(group_fn, x, params["layers"])
    x = _norm(cfg, params["final_norm"], "final", x)
    return x, jnp.sum(auxs), kvs


def lm_loss(cfg: ArchConfig, params, batch, *, remat_policy: str = "full",
            loss_chunk: int = 512, aux_weight: float = 0.01,
            act_constraint=None) -> jax.Array:
    """Next-token (or frame-target) cross entropy, chunked over S."""
    h, aux, _ = forward(cfg, params, batch, remat_policy=remat_policy,
                        act_constraint=act_constraint)
    targets = batch["targets"]
    if cfg.frontend == "vision":     # loss over text positions only
        h = h[:, -targets.shape[1]:, :]
    B, S, D = h.shape
    loss_chunk = min(loss_chunk, S)
    nc = S // loss_chunk

    def chunk_fn(acc, args):
        hc, tc = args
        logits = _logits_chunk(cfg, params, hc).astype(F32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, tc[..., None],
                                     axis=-1)[..., 0]
        return acc + jnp.sum(lse - picked), None

    hs = jnp.moveaxis(h[:, :nc * loss_chunk].reshape(B, nc, loss_chunk, D),
                      1, 0)
    ts = jnp.moveaxis(targets[:, :nc * loss_chunk].reshape(B, nc, loss_chunk),
                      1, 0)
    total, _ = jax.lax.scan(jax.remat(chunk_fn), jnp.zeros((), F32), (hs, ts))
    return total / (B * nc * loss_chunk) + aux_weight * aux


def prefill(cfg: ArchConfig, params, batch, *, remat_policy: str = "none",
            act_constraint=None):
    """Returns (last-token logits [B, V], stacked KV caches per position)."""
    h, _, kvs = forward(cfg, params, batch, remat_policy=remat_policy,
                        collect_kv=cfg.n_heads > 0 and not cfg.rwkv,
                        act_constraint=act_constraint)
    logits = _logits_chunk(cfg, params, h[:, -1:, :])[:, 0]
    return logits, kvs


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def init_decode_state(cfg: ArchConfig, batch: int, max_seq: int,
                      abstract: bool = False,
                      kv_dtype: Optional[str] = None) -> Dict:
    """Per-period-position decode state, stacked over groups.

    ``kv_dtype``: override the KV-cache element type (e.g. float8_e4m3fn
    — halves decode HBM traffic; §Perf hillclimb on the decode cell).
    """
    period = period_of(cfg)
    G = cfg.n_layers // period
    kinds = layer_kinds(cfg)
    dt = jnp.dtype(kv_dtype) if kv_dtype else jnp.dtype(cfg.dtype)
    state: Dict[str, Any] = {}

    def make(shape, dtype):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    for i, (mixer, _) in enumerate(kinds):
        key = f"pos{i}"
        if mixer == "attn":
            KH, Dh = cfg.n_kv_heads, cfg.head_dim
            state[key] = {
                "k": make((G, batch, max_seq, KH, Dh), dt),
                "v": make((G, batch, max_seq, KH, Dh), dt)}
        elif mixer == "mamba":
            di = cfg.mamba.expand * cfg.d_model
            K = cfg.mamba.d_conv
            state[key] = {
                "conv": make((G, batch, K - 1, di), dt),
                "ssm": make((G, batch, di, cfg.mamba.d_state), F32)}
        else:  # rwkv time-mix (+ channel-mix shift registers)
            H = cfg.d_model // rwkv_mod.HEAD
            state[key] = {
                "wkv": make((G, batch, H, rwkv_mod.HEAD, rwkv_mod.HEAD), F32),
                "x_tm": make((G, batch, cfg.d_model), dt),
                "x_cm": make((G, batch, cfg.d_model), dt)}
    return state


def decode_step(cfg: ArchConfig, params, state: Dict, tokens: jax.Array,
                pos: jax.Array) -> Tuple[Dict, jax.Array]:
    """One decode step: tokens [B] int32, pos [] int32 (cache write index).

    Returns (new_state, logits [B, V]).

    The decode state rides the scan *carry* (not xs/ys): each iteration
    dynamic-slices its group's slab and writes it back in place, which XLA
    aliases through the while loop — passing the caches as scan inputs/
    outputs instead materializes several full-cache copies (measured: +35 GB
    per chip on the 340B decode cell, see EXPERIMENTS.md §Dry-run).
    """
    kinds = layer_kinds(cfg)
    x = jnp.take(params["embed"], tokens, axis=0)        # [B, D]
    B, D = x.shape
    H, KH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    def group_fn(carry, gparams):
        x, gi, full_state = carry
        gstate = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, gi, 0,
                                                   keepdims=False),
            full_state)
        new_state = {}
        for i, (mixer, ffn) in enumerate(kinds):
            p = gparams[f"pos{i}"]
            st = gstate[f"pos{i}"]
            h = _norm(cfg, p, "norm1", x[:, None, :])[:, 0]
            if mixer == "attn":
                w = p["attn"]
                q = jnp.einsum("bd,de->be", h, w["wq"])
                k = jnp.einsum("bd,de->be", h, w["wk"])
                v = jnp.einsum("bd,de->be", h, w["wv"])
                if cfg.qkv_bias:
                    q, k, v = q + w["bq"], k + w["bk"], v + w["bv"]
                q = q.reshape(B, H, Dh)
                k = k.reshape(B, KH, Dh)
                v = v.reshape(B, KH, Dh)
                if cfg.rope in ("rope", "mrope"):
                    # decode positions are text positions; M-RoPE with equal
                    # (t, h, w) components reduces exactly to RoPE
                    posv = jnp.full((B, 1), pos, jnp.int32)
                    q = layers.apply_rope(q[:, None], posv)[:, 0]
                    k = layers.apply_rope(k[:, None], posv)[:, 0]
                k_cache = st["k"].at[:, pos].set(k.astype(st["k"].dtype))
                v_cache = st["v"].at[:, pos].set(v.astype(st["v"].dtype))
                y = layers.decode_attention(q, k_cache, v_cache,
                                            length=jnp.full((B,), pos + 1))
                y = jnp.einsum("be,ed->bd", y.reshape(B, H * Dh), w["wo"])
                new_state[f"pos{i}"] = {"k": k_cache, "v": v_cache}
            elif mixer == "mamba":
                ns, y = mamba_mod.mamba_decode(p["mamba"], st, h)
                new_state[f"pos{i}"] = ns
            else:
                wkv, y = rwkv_mod.time_mix_decode(p["time_mix"], st["wkv"],
                                                  st["x_tm"], h)
                new_state[f"pos{i}"] = {"wkv": wkv, "x_tm": h,
                                        "x_cm": st["x_cm"]}
            x = x + y
            h = _norm(cfg, p, "norm2", x[:, None, :])[:, 0]
            if ffn == "moe":
                y, _ = moe_mod.moe_apply(p["moe"], h[:, None, :],
                                         top_k=cfg.moe.top_k,
                                         capacity_factor=4.0, mlp=cfg.mlp)
                y = y[:, 0]
            elif ffn == "mlp":
                y = layers.mlp_apply(cfg.mlp, h[:, None, :], p["mlp"])[:, 0]
            else:
                y = rwkv_mod.channel_mix_decode(p["channel_mix"],
                                                new_state[f"pos{i}"]["x_cm"],
                                                h)
                new_state[f"pos{i}"] = dict(new_state[f"pos{i}"], x_cm=h)
            x = x + y
        full_state = jax.tree.map(
            lambda a, n: jax.lax.dynamic_update_index_in_dim(
                a, n.astype(a.dtype), gi, 0),
            full_state, new_state)
        return (x, gi + 1, full_state), None

    (x, _, new_state), _ = jax.lax.scan(
        group_fn, (x, jnp.zeros((), jnp.int32), state), params["layers"])
    x = _norm(cfg, params["final_norm"], "final", x[:, None, :])[:, 0]
    logits = _logits_chunk(cfg, params, x[:, None, :])[:, 0]
    return new_state, logits


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins; DESIGN.md: frontends are stubs)
# ---------------------------------------------------------------------------
def input_specs(cfg: ArchConfig, seq_len: int, batch: int,
                kind: str) -> Dict[str, jax.ShapeDtypeStruct]:
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    if kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((batch,), i32)}
    if cfg.frontend == "audio":
        specs = {"frame_embeds": jax.ShapeDtypeStruct(
            (batch, seq_len, cfg.d_model), dt)}
        if kind == "train":
            specs["targets"] = jax.ShapeDtypeStruct((batch, seq_len), i32)
        return specs
    if cfg.frontend == "vision":
        s_img = seq_len // 4                       # stubbed patch stream
        s_txt = seq_len - s_img
        specs = {
            "tokens": jax.ShapeDtypeStruct((batch, s_txt), i32),
            "patch_embeds": jax.ShapeDtypeStruct((batch, s_img, cfg.d_model),
                                                 dt),
            "mrope_pos": jax.ShapeDtypeStruct((batch, seq_len, 3), i32),
        }
        if kind == "train":
            specs["targets"] = jax.ShapeDtypeStruct((batch, s_txt), i32)
        return specs
    specs = {"tokens": jax.ShapeDtypeStruct((batch, seq_len), i32)}
    if kind == "train":
        specs["targets"] = jax.ShapeDtypeStruct((batch, seq_len), i32)
    return specs


def make_abstract_params(cfg: ArchConfig):
    return abstract_params(param_specs(cfg))


def make_params(cfg: ArchConfig, key: jax.Array):
    return init_params(param_specs(cfg), key)
