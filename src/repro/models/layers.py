"""Transformer building blocks: norms, RoPE/M-RoPE, GQA attention, MLPs.

Attention comes in three flavors:

  * ``chunked_attention`` — flash-style two-level ``lax.scan`` over query and
    key/value chunks with a running (max, denom, acc) online softmax.  Live
    intermediates stay at [B, Cq, H, Ck] instead of [B, S, H, S], which is
    what lets 32k-token prefill lower within per-chip HBM budgets.  Block-
    causal masking computes masked blocks and discards them (~2x FLOPs on
    the strictly-lower triangle at block granularity) — the waste is visible
    in the roofline MODEL_FLOPS/HLO_FLOPS ratio and discussed in §Perf.
  * ``decode_attention`` — one new token against a [B, S, KH, Dh] cache.
  * paged variants live in ``repro.memsys`` / ``repro.kernels``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32
NEG_INF = -1e30


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def rms_norm(x, scale, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(F32)), axis=-1, keepdims=True)
    return (x.astype(F32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) \
        * scale.astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(F32) + bias.astype(F32)).astype(x.dtype)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------
def _rope_freqs(head_dim: int, base: float = 10000.0):
    half = head_dim // 2
    return 1.0 / (base ** (jnp.arange(half, dtype=F32) / half))


def apply_rope(x, positions, base: float = 10000.0):
    """x: [..., S, H, Dh]; positions: broadcastable to [..., S]."""
    half = x.shape[-1] // 2
    freqs = _rope_freqs(x.shape[-1], base)                    # [half]
    angles = positions[..., None].astype(F32) * freqs         # [..., S, half]
    angles = angles[..., None, :]                             # [..., S, 1, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections=(0.25, 0.375, 0.375),
                base: float = 10000.0):
    """Qwen2-VL multimodal RoPE.

    positions3: [..., S, 3] (temporal, height, width position ids).  The
    rotary frequency slots are split into three contiguous sections, each
    rotated by its own position component.
    """
    half = x.shape[-1] // 2
    s0 = int(half * sections[0])
    s1 = int(half * sections[1])
    bounds = (s0, s0 + s1)
    freqs = _rope_freqs(x.shape[-1], base)
    slot = jnp.arange(half)
    comp = jnp.where(slot < bounds[0], 0, jnp.where(slot < bounds[1], 1, 2))
    pos = jnp.take_along_axis(
        positions3.astype(F32),
        jnp.broadcast_to(comp, positions3.shape[:-1] + (half,)) * 0 +
        comp, axis=-1)                                        # [..., S, half]
    angles = (pos * freqs)[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int):
    pos = jnp.arange(seq_len, dtype=F32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=F32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d_model)
    pe = jnp.zeros((seq_len, d_model), F32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------
def chunked_attention(q, k, v, *, causal: bool, q_chunk: int = 512,
                      kv_chunk: int = 512) -> jax.Array:
    """Flash-style online-softmax attention.

    q: [B, S, H, Dh]; k, v: [B, S, KH, Dh] with H a multiple of KH (GQA).
    Returns [B, S, H, Dh].
    """
    B, S, H, Dh = q.shape
    KH = k.shape[2]
    G = H // KH
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, S)
    nq, nk = S // q_chunk, S // kv_chunk
    assert S % q_chunk == 0 and S % kv_chunk == 0, (S, q_chunk, kv_chunk)

    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, F32))
    qr = q.reshape(B, nq, q_chunk, KH, G, Dh)
    kr = k.reshape(B, nk, kv_chunk, KH, Dh)
    vr = v.reshape(B, nk, kv_chunk, KH, Dh)

    def q_step(_, qi):
        i, q_blk = qi                                    # [B, Cq, KH, G, Dh]

        @jax.remat
        def kv_step(carry, kj):
            m, l, acc = carry
            j, k_blk, v_blk = kj
            s = jnp.einsum("bqkgd,bckd->bqkgc", q_blk, k_blk,
                           preferred_element_type=F32) * scale  # [B,Cq,KH,G,Ck]
            if causal:
                qpos = i * q_chunk + jnp.arange(q_chunk)
                kpos = j * kv_chunk + jnp.arange(kv_chunk)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqkgc,bckd->bqkgd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=F32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, q_chunk, KH, G), NEG_INF, F32)
        l0 = jnp.zeros((B, q_chunk, KH, G), F32)
        a0 = jnp.zeros((B, q_chunk, KH, G, Dh), F32)
        ks = (jnp.arange(nk), jnp.moveaxis(kr, 1, 0), jnp.moveaxis(vr, 1, 0))
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), ks)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)

    # Double remat: without it the backward saves the softmax probs for
    # every (q-chunk, kv-chunk) pair — i.e. the full S^2 attention matrix
    # in f32 (+30 GB/chip on the 340B train cell).  Flash-style recompute
    # keeps only the (m, l, acc) carries.
    qs = (jnp.arange(nq), jnp.moveaxis(qr, 1, 0))
    _, out = jax.lax.scan(jax.remat(q_step), None, qs)   # [nq,B,Cq,KH,G,Dh]
    return jnp.moveaxis(out, 0, 1).reshape(B, S, H, Dh)


def decode_attention(q, k_cache, v_cache, length=None) -> jax.Array:
    """One-token attention: q [B, H, Dh]; caches [B, S, KH, Dh].

    ``length``: optional [B] valid-length mask (entries >= length ignored).
    """
    B, H, Dh = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    G = H // KH
    if k_cache.dtype.itemsize == 1:     # f8 quantized cache: dequant here
        k_cache = k_cache.astype(q.dtype)
        v_cache = v_cache.astype(q.dtype)
    qr = q.reshape(B, KH, G, Dh)
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, F32))
    # accumulate in f32 without materializing an f32 copy of the cache
    s = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache,
                   preferred_element_type=F32) * scale
    if length is not None:
        mask = jnp.arange(S)[None, :] < length[:, None]
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=F32)
    return out.reshape(B, H, Dh).astype(q.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------
def mlp_apply(kind: str, x, w):
    """w: dict of weights produced by the model builder."""
    if kind == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, w["w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, w["w_up"])
        h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
        return jnp.einsum("bsf,fd->bsd", h, w["w_down"])
    if kind == "squared_relu":
        h = jnp.einsum("bsd,df->bsf", x, w["w_in"])
        h = jnp.square(jax.nn.relu(h.astype(F32))).astype(x.dtype)
        return jnp.einsum("bsf,fd->bsd", h, w["w_out"])
    if kind == "gelu":
        h = jnp.einsum("bsd,df->bsf", x, w["w_in"]) + w["b_in"]
        h = jax.nn.gelu(h.astype(F32)).astype(x.dtype)
        return jnp.einsum("bsf,fd->bsd", h, w["w_out"]) + w["b_out"]
    raise ValueError(kind)
