"""RWKV6 ("Finch") blocks: time-mix with data-dependent decay + channel-mix.

Attention-free: the per-head state is a fixed [64, 64] outer-product
accumulator with an input-dependent diagonal decay
``w_t = exp(-exp(w0 + tanh(x W_A) W_B))`` (the Finch contribution), so both
training (chunked scan) and decode (O(1) state) never materialize a KV
cache — which is why this arch runs the 500k-token cell and why the paper's
paged-KV technique is *inapplicable* to it (DESIGN.md section 4).

Simplification vs. the released model: token-shift mixing uses static
per-channel lerp weights rather than the dynamic ddlerp LoRA (noted in
DESIGN.md); the data-dependent decay, bonus ``u``, group-norm, and head
structure are faithful.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .modules import ParamSpec

F32 = jnp.float32
HEAD = 64  # RWKV6 fixed head size
DECAY_RANK = 64


def rwkv_time_mix_specs(d_model: int, dtype: str) -> Dict[str, ParamSpec]:
    d = d_model
    return {
        "mu_r": ParamSpec((d,), ("embed",), dtype="float32", init="zeros"),
        "mu_k": ParamSpec((d,), ("embed",), dtype="float32", init="zeros"),
        "mu_v": ParamSpec((d,), ("embed",), dtype="float32", init="zeros"),
        "mu_w": ParamSpec((d,), ("embed",), dtype="float32", init="zeros"),
        "mu_g": ParamSpec((d,), ("embed",), dtype="float32", init="zeros"),
        "w_r": ParamSpec((d, d), ("embed", "heads_mm"), dtype=dtype),
        "w_k": ParamSpec((d, d), ("embed", "heads_mm"), dtype=dtype),
        "w_v": ParamSpec((d, d), ("embed", "heads_mm"), dtype=dtype),
        "w_g": ParamSpec((d, d), ("embed", "heads_mm"), dtype=dtype),
        "w_o": ParamSpec((d, d), ("heads_mm", "embed"), dtype=dtype,
                         init="scaled"),
        "decay_base": ParamSpec((d,), ("embed",), dtype="float32",
                                init="ones"),
        "decay_A": ParamSpec((d, DECAY_RANK), ("embed", None),
                             dtype="float32"),
        "decay_B": ParamSpec((DECAY_RANK, d), (None, "embed"),
                             dtype="float32"),
        "bonus_u": ParamSpec((d,), ("embed",), dtype="float32",
                             init="zeros"),
        "ln_scale": ParamSpec((d,), ("embed",), dtype="float32", init="ones"),
    }


def rwkv_channel_mix_specs(d_model: int, d_ff: int,
                           dtype: str) -> Dict[str, ParamSpec]:
    return {
        "mu_k": ParamSpec((d_model,), ("embed",), dtype="float32",
                          init="zeros"),
        "mu_r": ParamSpec((d_model,), ("embed",), dtype="float32",
                          init="zeros"),
        "w_kk": ParamSpec((d_model, d_ff), ("embed", "ff"), dtype=dtype),
        "w_vv": ParamSpec((d_ff, d_model), ("ff", "embed"), dtype=dtype,
                          init="scaled"),
        "w_rr": ParamSpec((d_model, d_model), ("embed", "embed_out"),
                          dtype=dtype),
    }


def _shift(x):
    """Token shift: x[:, t] -> x[:, t-1] with zero at t=0."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]


def _lerp(x, xx, mu):
    return (x.astype(F32) + (xx - x).astype(F32) * mu).astype(x.dtype)


def _decay(w, mixed_w):
    lo = jnp.tanh(jnp.einsum("bsd,dr->bsr", mixed_w.astype(F32),
                             w["decay_A"]))
    lo = jnp.einsum("bsr,rd->bsd", lo, w["decay_B"])
    return jnp.exp(-jnp.exp(w["decay_base"] + lo))      # [B,S,d] in (0,1)


def time_mix_apply(w, x: jax.Array, *, chunk: int = 256) -> jax.Array:
    """x: [B, S, D] -> [B, S, D] (training / prefill)."""
    B, S, D = x.shape
    H = D // HEAD
    xx = _shift(x)
    r = jnp.einsum("bsd,de->bse", _lerp(x, xx, w["mu_r"]), w["w_r"])
    k = jnp.einsum("bsd,de->bse", _lerp(x, xx, w["mu_k"]), w["w_k"])
    v = jnp.einsum("bsd,de->bse", _lerp(x, xx, w["mu_v"]), w["w_v"])
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", _lerp(x, xx, w["mu_g"]),
                               w["w_g"]).astype(F32))
    decay = _decay(w, _lerp(x, xx, w["mu_w"]))          # [B,S,D]

    rh = r.reshape(B, S, H, HEAD).astype(F32)
    kh = k.reshape(B, S, H, HEAD).astype(F32)
    vh = v.reshape(B, S, H, HEAD).astype(F32)
    wh = decay.reshape(B, S, H, HEAD)
    u = w["bonus_u"].reshape(H, HEAD)

    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    def chunk_body(state, args):
        r_c, k_c, v_c, w_c = args

        def step(st, a):
            r_t, k_t, v_t, w_t = a                      # [B,H,64] each
            kv = k_t[..., :, None] * v_t[..., None, :]  # [B,H,64,64]
            y = jnp.einsum("bhk,bhkv->bhv", r_t,
                           st + u[..., :, None] * kv)
            st = w_t[..., :, None] * st + kv
            return st, y.astype(jnp.bfloat16)           # bf16 ys: 2x smaller

        state, ys = jax.lax.scan(step, state,
                                 tuple(jnp.moveaxis(a, 1, 0)
                                       for a in (r_c, k_c, v_c, w_c)))
        return state, jnp.moveaxis(ys, 0, 1)

    chunk_body = jax.remat(chunk_body)
    st0 = jnp.zeros((B, H, HEAD, HEAD), F32)
    args = tuple(jnp.moveaxis(a.reshape(B, nc, chunk, H, HEAD), 1, 0)
                 for a in (rh, kh, vh, wh))
    _, ys = jax.lax.scan(chunk_body, st0, args)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, D)

    # per-head group norm, then gate and project out
    yh = y.reshape(B, S, H, HEAD).astype(F32)
    mu = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    y = ((yh - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, S, D)
    y = y * w["ln_scale"] * g
    return jnp.einsum("bse,ed->bsd", y.astype(x.dtype), w["w_o"])


def time_mix_decode(w, state, x_prev, x: jax.Array):
    """One token: x [B, D]; state [B, H, 64, 64]; x_prev [B, D] (shift)."""
    B, D = x.shape
    H = D // HEAD
    xs, xx = x[:, None, :], x_prev[:, None, :]
    r = jnp.einsum("bsd,de->bse", _lerp(xs, xx, w["mu_r"]), w["w_r"])[:, 0]
    k = jnp.einsum("bsd,de->bse", _lerp(xs, xx, w["mu_k"]), w["w_k"])[:, 0]
    v = jnp.einsum("bsd,de->bse", _lerp(xs, xx, w["mu_v"]), w["w_v"])[:, 0]
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", _lerp(xs, xx, w["mu_g"]),
                               w["w_g"]).astype(F32))[:, 0]
    decay = _decay(w, _lerp(xs, xx, w["mu_w"]))[:, 0]

    rh = r.reshape(B, H, HEAD).astype(F32)
    kh = k.reshape(B, H, HEAD).astype(F32)
    vh = v.reshape(B, H, HEAD).astype(F32)
    wh = decay.reshape(B, H, HEAD)
    u = w["bonus_u"].reshape(H, HEAD)
    kv = kh[..., :, None] * vh[..., None, :]
    y = jnp.einsum("bhk,bhkv->bhv", rh, state + u[..., :, None] * kv)
    state = wh[..., :, None] * state + kv
    yh = y.reshape(B, H, HEAD)
    mu = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    y = ((yh - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, D)
    y = y * w["ln_scale"] * g
    return state, jnp.einsum("be,ed->bd", y.astype(x.dtype), w["w_o"])


def channel_mix_apply(w, x: jax.Array) -> jax.Array:
    xx = _shift(x)
    k = jnp.einsum("bsd,df->bsf", _lerp(x, xx, w["mu_k"]), w["w_kk"])
    k = jnp.square(jax.nn.relu(k.astype(F32))).astype(x.dtype)
    v = jnp.einsum("bsf,fd->bsd", k, w["w_vv"])
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", _lerp(x, xx, w["mu_r"]),
                                   w["w_rr"]).astype(F32))
    return (rr * v.astype(F32)).astype(x.dtype)


def channel_mix_decode(w, x_prev, x: jax.Array) -> jax.Array:
    xs, xx = x[:, None, :], x_prev[:, None, :]
    k = jnp.einsum("bsd,df->bsf", _lerp(xs, xx, w["mu_k"]), w["w_kk"])
    k = jnp.square(jax.nn.relu(k.astype(F32))).astype(x.dtype)
    v = jnp.einsum("bsf,fd->bsd", k, w["w_vv"])
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", _lerp(xs, xx, w["mu_r"]),
                                   w["w_rr"]).astype(F32))
    return (rr * v.astype(F32)).astype(x.dtype)[:, 0]
