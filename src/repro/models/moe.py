"""Mixture-of-Experts layer: top-k routing, capacity-based dispatch, EP.

Switch/GShard-style dense dispatch: tokens are routed per sequence with
capacity ``C = ceil(S * top_k / E * capacity_factor)``; the [B, S, E, C]
dispatch tensor is sharded over the expert axis (mapped to the "model" mesh
axis), which keeps it at tens of MB per device for the assigned shapes.
Expert weights are expert-parallel over the same axis.  Overflowed tokens
are dropped (contribute zero), standard for capacity-based MoE.

Returns the load-balancing auxiliary loss (Switch, eq. 4) alongside the
output.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .modules import ParamSpec

F32 = jnp.float32


def moe_param_specs(d_model: int, d_ff: int, n_experts: int, mlp: str,
                    shared_expert: bool, dtype: str) -> Dict[str, ParamSpec]:
    p = {
        "router": ParamSpec((d_model, n_experts), ("embed", None),
                            dtype="float32"),
    }
    if mlp == "swiglu":
        p["w_gate"] = ParamSpec((n_experts, d_model, d_ff),
                                ("experts", "embed", "ff"), dtype=dtype)
        p["w_up"] = ParamSpec((n_experts, d_model, d_ff),
                              ("experts", "embed", "ff"), dtype=dtype)
        p["w_down"] = ParamSpec((n_experts, d_ff, d_model),
                                ("experts", "ff", "embed"), dtype=dtype,
                                init="scaled")
    else:
        p["w_in"] = ParamSpec((n_experts, d_model, d_ff),
                              ("experts", "embed", "ff"), dtype=dtype)
        p["w_out"] = ParamSpec((n_experts, d_ff, d_model),
                               ("experts", "ff", "embed"), dtype=dtype,
                               init="scaled")
    if shared_expert:
        p["shared_w_gate"] = ParamSpec((d_model, d_ff), ("embed", "ff"),
                                       dtype=dtype)
        p["shared_w_up"] = ParamSpec((d_model, d_ff), ("embed", "ff"),
                                     dtype=dtype)
        p["shared_w_down"] = ParamSpec((d_ff, d_model), ("ff", "embed"),
                                       dtype=dtype, init="scaled")
    return p


def moe_apply(w, x: jax.Array, *, top_k: int, capacity_factor: float,
              mlp: str, seq_chunk: int = 4096) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> ([B, S, D], aux_loss scalar).

    Long sequences are processed in S-chunks (capacity per chunk, standard
    for capacity-based MoE): the [B, S, E, C] dispatch/combine tensors at
    S=32k otherwise dominate per-chip memory (~100 GiB on the jamba prefill
    cell — EXPERIMENTS.md §Dry-run iteration log).
    """
    B, S, D = x.shape
    if S > seq_chunk and S % seq_chunk == 0:
        nc = S // seq_chunk
        xs = jnp.moveaxis(x.reshape(B, nc, seq_chunk, D), 1, 0)

        def chunk_fn(acc, xc):
            yc, aux = moe_apply(w, xc, top_k=top_k,
                                capacity_factor=capacity_factor, mlp=mlp,
                                seq_chunk=seq_chunk)
            return acc + aux, yc

        aux, ys = jax.lax.scan(chunk_fn, jnp.zeros((), F32), xs)
        return jnp.moveaxis(ys, 0, 1).reshape(B, S, D), aux / nc
    E = w["router"].shape[1]
    C = max(int(math.ceil(S * top_k / E * capacity_factor)), 1)

    logits = jnp.einsum("bsd,de->bse", x.astype(F32),
                        w["router"].astype(F32))
    probs = jax.nn.softmax(logits, axis=-1)                    # [B,S,E]
    gate_vals, sel = jax.lax.top_k(probs, top_k)               # [B,S,k]

    # Switch load-balance loss: E * sum_e f_e * p_e
    density = jnp.mean(jax.nn.one_hot(sel[..., 0], E, dtype=F32), axis=(0, 1))
    p_mean = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(density * p_mean)

    # position of each (token, k-slot) within its expert's capacity buffer
    onehot = jax.nn.one_hot(sel, E, dtype=jnp.int32)           # [B,S,k,E]
    flat = onehot.reshape(B, S * top_k, E)
    pos = jnp.cumsum(flat, axis=1) - 1                         # [B,S*k,E]
    pos = jnp.sum(pos.reshape(B, S, top_k, E) * onehot, axis=-1)  # [B,S,k]
    keep = pos < C

    # build dispatch [B,S,E,C]: combine one-hot over expert and slot
    # (overflowed slots map to C which one_hot drops -> token dropped)
    slot_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C,
                             dtype=x.dtype)                    # [B,S,k,C]
    exp_oh = jax.nn.one_hot(sel, E, dtype=x.dtype)             # [B,S,k,E]
    dispatch = jnp.einsum("bske,bskc->bsec", exp_oh, slot_oh)  # [B,S,E,C]
    combine = jnp.einsum("bske,bskc,bsk->bsec", exp_oh, slot_oh,
                         gate_vals.astype(x.dtype))

    xe = jnp.einsum("bsec,bsd->becd", dispatch, x)             # [B,E,C,D]
    if mlp == "swiglu":
        g = jnp.einsum("becd,edf->becf", xe, w["w_gate"])
        u = jnp.einsum("becd,edf->becf", xe, w["w_up"])
        h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
        ye = jnp.einsum("becf,efd->becd", h, w["w_down"])
    else:
        h = jnp.einsum("becd,edf->becf", xe, w["w_in"])
        h = jnp.square(jax.nn.relu(h.astype(F32))).astype(x.dtype)
        ye = jnp.einsum("becf,efd->becd", h, w["w_out"])
    out = jnp.einsum("bsec,becd->bsd", combine, ye)

    if "shared_w_gate" in w:
        g = jnp.einsum("bsd,df->bsf", x, w["shared_w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, w["shared_w_up"])
        h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
        out = out + jnp.einsum("bsf,fd->bsd", h, w["shared_w_down"])
    return out, aux
