"""Mamba (S6) block for the Jamba hybrid architecture.

Selective state-space layer: input-dependent (dt, B, C) with diagonal decay
``exp(dt * A)``.  The sequence recurrence runs as a chunked ``lax.scan``
(outer scan over chunks, inner scan over steps, remat on the chunk body) so
backward-pass residuals stay at one [B, d_inner, d_state] carry per chunk
boundary instead of per step.  Decode carries the (conv window, SSM state)
pair — O(1) memory per token, which is what makes the 500k-token cell
runnable for the hybrid/SSM families (DESIGN.md section 4).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .modules import ParamSpec

F32 = jnp.float32


def mamba_param_specs(d_model: int, d_state: int, d_conv: int, expand: int,
                      dtype: str) -> Dict[str, ParamSpec]:
    di = expand * d_model
    dt_rank = max(math.ceil(d_model / 16), 1)
    return {
        "in_proj": ParamSpec((d_model, 2 * di), ("embed", "inner2"),
                             dtype=dtype),
        "conv_w": ParamSpec((d_conv, di), (None, "inner"), dtype=dtype),
        "conv_b": ParamSpec((di,), ("inner",), dtype=dtype, init="zeros"),
        "x_proj": ParamSpec((di, dt_rank + 2 * d_state), ("inner", None),
                            dtype=dtype),
        "dt_proj": ParamSpec((dt_rank, di), (None, "inner"), dtype=dtype),
        "dt_bias": ParamSpec((di,), ("inner",), dtype="float32", init="zeros"),
        "A_log": ParamSpec((di, d_state), ("inner", None), dtype="float32",
                           init="ones"),
        "D": ParamSpec((di,), ("inner",), dtype="float32", init="ones"),
        "out_proj": ParamSpec((di, d_model), ("inner", "embed"), dtype=dtype,
                              init="scaled"),
    }


def _ssm_inputs(w, x):
    """Shared front half: projections, causal conv, selective params."""
    di = w["dt_proj"].shape[1]
    d_state = w["A_log"].shape[1]
    dt_rank = w["dt_proj"].shape[0]
    xz = jnp.einsum("bsd,de->bse", x, w["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)                     # [B,S,di] each
    return xs, z, di, d_state, dt_rank


def _selective(w, xs_conv):
    dt_rank = w["dt_proj"].shape[0]
    d_state = w["A_log"].shape[1]
    x_dbl = jnp.einsum("bsi,ij->bsj", xs_conv, w["x_proj"])
    dt, Bs, Cs = jnp.split(x_dbl.astype(F32),
                           [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,ri->bsi", dt,
                                    w["dt_proj"].astype(F32)) + w["dt_bias"])
    A = -jnp.exp(w["A_log"])                              # [di, ds]
    return dt, Bs, Cs, A


def causal_conv(xs, conv_w, conv_b):
    """Depthwise causal conv over time: xs [B,S,di], conv_w [K,di]."""
    K = conv_w.shape[0]
    pad = jnp.pad(xs, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xs.shape[1], :] * conv_w[i] for i in range(K))
    return jax.nn.silu((out + conv_b).astype(F32)).astype(xs.dtype)


def mamba_apply(w, x: jax.Array, *, chunk: int = 512) -> jax.Array:
    """Training/prefill forward: x [B, S, D] -> [B, S, D].

    The *entire layer* (projections, conv, selective scan, gating, output
    projection) is chunked over S: an outer ``lax.scan`` carries the
    (SSM state, conv tail) pair and each remat'd chunk body works on
    [B, chunk, ...] slabs.  Materializing the full-sequence [B, S, 2*di]
    intermediates instead costs ~100 GiB/chip on the 32k-prefill cell
    (EXPERIMENTS.md §Dry-run iteration log).
    """
    B, S, D = x.shape
    di = w["dt_proj"].shape[1]
    d_state = w["A_log"].shape[1]
    K = w["conv_w"].shape[0]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    A = -jnp.exp(w["A_log"])

    def chunk_body(carry, x_c):
        h, tail = carry                                   # [B,di,ds],[B,K-1,di]
        xz = jnp.einsum("bsd,de->bse", x_c, w["in_proj"])
        xs, z = jnp.split(xz, 2, axis=-1)
        window = jnp.concatenate([tail, xs], axis=1)      # [B,K-1+chunk,di]
        conv = sum(window[:, i:i + chunk, :] * w["conv_w"][i]
                   for i in range(K))
        conv = jax.nn.silu((conv + w["conv_b"]).astype(F32)).astype(xs.dtype)
        dt, Bs, Cs, _ = _selective(w, conv)

        def step(hh, a):
            dt_t, B_t, C_t, x_t = a
            dA = jnp.exp(dt_t[..., None] * A)
            dBx = dt_t[..., None] * B_t[:, None, :] * x_t[..., None]
            hh = dA * hh + dBx
            y = jnp.einsum("bis,bs->bi", hh, C_t)
            return hh, y.astype(x_c.dtype)

        h, ys = jax.lax.scan(step, h,
                             (jnp.moveaxis(dt, 1, 0),
                              jnp.moveaxis(Bs, 1, 0),
                              jnp.moveaxis(Cs, 1, 0),
                              jnp.moveaxis(conv.astype(F32), 1, 0)))
        y = jnp.moveaxis(ys, 0, 1).astype(F32)            # [B,chunk,di]
        y = (y + w["D"] * conv.astype(F32)) * jax.nn.silu(z.astype(F32))
        out_c = jnp.einsum("bsi,id->bsd", y.astype(x_c.dtype),
                           w["out_proj"])
        return (h, window[:, chunk:]), out_c

    h0 = jnp.zeros((B, di, d_state), F32)
    tail0 = jnp.zeros((B, K - 1, di), x.dtype)
    xs_chunks = jnp.moveaxis(x.reshape(B, nc, chunk, D), 1, 0)
    _, outs = jax.lax.scan(jax.remat(chunk_body), (h0, tail0), xs_chunks)
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, D)


def mamba_decode_init(w, batch: int):
    di = w["dt_proj"].shape[1]
    d_state = w["A_log"].shape[1]
    K = w["conv_w"].shape[0]
    return {"conv": jnp.zeros((batch, K - 1, di), w["in_proj"].dtype),
            "ssm": jnp.zeros((batch, di, d_state), F32)}


def mamba_decode(w, state: Dict, x: jax.Array) -> Tuple[Dict, jax.Array]:
    """One-token decode: x [B, D] -> (new_state, y [B, D])."""
    B = x.shape[0]
    xs, z, di, d_state, _ = _ssm_inputs(w, x[:, None, :])
    xs, z = xs[:, 0], z[:, 0]                             # [B,di]
    K = w["conv_w"].shape[0]
    window = jnp.concatenate([state["conv"], xs[:, None, :]], axis=1)
    conv = sum(window[:, i, :] * w["conv_w"][i] for i in range(K))
    conv = jax.nn.silu((conv + w["conv_b"]).astype(F32)).astype(xs.dtype)
    dt, Bs, Cs, A = _selective(w, conv[:, None, :])
    dt, Bs, Cs = dt[:, 0], Bs[:, 0], Cs[:, 0]
    dA = jnp.exp(dt[..., None] * A)
    h = dA * state["ssm"] + dt[..., None] * Bs[:, None, :] \
        * conv.astype(F32)[..., None]
    y = jnp.einsum("bis,bs->bi", h, Cs)
    y = (y + w["D"] * conv.astype(F32)) * jax.nn.silu(z.astype(F32))
    out = jnp.einsum("bi,id->bd", y.astype(x.dtype), w["out_proj"])
    return {"conv": window[:, 1:], "ssm": h}, out
