"""Logical-axis -> mesh-axis sharding rules (DP / TP / EP / CP).

Parameters declare *logical* axes ("embed", "heads_mm", "ff", "experts",
"vocab", ...); this module maps them onto physical mesh axes.  The default
rule set is Megatron-style tensor parallelism on the "model" axis with data
parallelism over ("pod", "data"):

  heads_mm / kv_mm   attention projection columns  -> model
  ff / inner*        MLP / Mamba hidden width      -> model
  experts            MoE expert axis (EP)          -> model
  vocab              embedding / LM head rows      -> model
  embed / layers     replicated (row dimension)

A logical dim is only sharded if its size divides the mesh axis; otherwise
it silently falls back to replication (e.g. 56 heads on a 16-way model axis
shard via the fused ``heads_mm`` column dim instead).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.modules import ParamSpec

# logical axis -> mesh axis (None = replicate)
DEFAULT_RULES: Dict[str, Optional[str]] = {
    "vocab": "model",
    "heads_mm": "model",
    "kv_mm": "model",
    "ff": "model",
    "experts": "model",
    "inner": "model",
    "inner2": "model",
    "heads": "model",
    "embed": None,
    "embed_out": None,
    "layers": None,
    "batch": ("pod", "data"),
    "seq": None,
}


def make_mesh(axis_shapes, axis_names) -> Mesh:
    """``jax.make_mesh`` across API drift.

    Newer jax wants explicit ``axis_types`` (``jax.sharding.AxisType.Auto``)
    to keep the pre-explicit-sharding behavior; releases that predate the
    enum (e.g. 0.4.3x, which still provide ``jax.make_mesh`` itself) take
    no such kwarg.  Tests and launch helpers go through here so both
    worlds produce the same auto-sharded mesh.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(axis_shapes, axis_names,
                                 axis_types=(axis_type.Auto,)
                                 * len(axis_names))
        except TypeError:
            pass
    return jax.make_mesh(axis_shapes, axis_names)


def shard_map(f, mesh: Mesh, in_specs, out_specs, manual_axes):
    """``shard_map`` across API drift.

    Newer jax exposes ``jax.shard_map`` taking ``axis_names`` (the axes the
    function is manual over) and ``check_vma``; older releases only have
    ``jax.experimental.shard_map.shard_map`` with ``check_rep``, and their
    partial-manual mode (non-empty ``auto``) trips an XLA
    ``IsManualSubgroup`` check on CPU meshes — so the fallback goes manual
    over *all* mesh axes, which is equivalent as long as callers keep
    non-``manual_axes`` dimensions replicated in their specs (the
    compressed train step does: params/outputs are ``P()`` and only DP
    collectives appear in the body).
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False, axis_names=set(manual_axes))
        except TypeError:
            pass
    from jax.experimental.shard_map import shard_map as sm_old
    return sm_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def spec_for(spec: ParamSpec, mesh: Mesh,
             rules: Optional[Dict] = None) -> P:
    rules = rules or DEFAULT_RULES
    out = []
    used = set()
    for dim, logical in zip(spec.shape, spec.logical_axes):
        axis = rules.get(logical) if logical else None
        if axis is not None and axis not in used \
                and mesh_axis_size(mesh, axis) > 1 \
                and dim % mesh_axis_size(mesh, axis) == 0:
            out.append(axis)
            used.add(axis)
        else:
            out.append(None)
    return P(*out)


def param_shardings(specs, mesh: Mesh, rules: Optional[Dict] = None):
    """Pytree of NamedSharding matching a ParamSpec tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_for(s, mesh, rules)),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


FSDP_RULES: Dict[str, Optional[str]] = dict(
    DEFAULT_RULES,
    embed="data",       # shard the d_model dimension over DP (FSDP)
    embed_out="data",
)

# Expert-parallel-over-data (hillclimb variant, EXPERIMENTS.md §Perf):
# expert weights shard over ("data" x "model") via (E, ff), so they are
# never re-gathered — tokens travel to experts via all-to-all instead of
# weights traveling to tokens via all-gather.  Expert grads are wholly
# owned per shard (no DP all-reduce).  Requires n_experts % data == 0.
MOE_EP_RULES: Dict[str, Optional[str]] = dict(
    FSDP_RULES,
    experts="data",
)

# moe_ep + TP-resident non-expert weights: dense params are small enough
# to live sharded-over-model only (no FSDP regather per microbatch).
MOE_EP_TP_RULES: Dict[str, Optional[str]] = dict(
    DEFAULT_RULES,
    experts="data",
)

RULE_SETS = {"default": DEFAULT_RULES, "fsdp": FSDP_RULES,
             "moe_ep": MOE_EP_RULES, "moe_ep_tp": MOE_EP_TP_RULES}


def choose_rules(param_bytes: int, mesh: Mesh, mode: str = "serve",
                 hbm_bytes: int = 16 << 30) -> Dict[str, Optional[str]]:
    """TP-only if the cell's parameter-proportional state fits comfortably
    per chip, else TP+FSDP.

    Training carries ~7x the bf16 parameter bytes (params + f32 grads +
    f32 Adam moments); serving carries 1x.
    """
    tp = mesh_axis_size(mesh, "model") if "model" in mesh.shape else 1
    mult = 7.0 if mode == "train" else 1.0
    if param_bytes * mult / tp < 0.35 * hbm_bytes:
        return DEFAULT_RULES
    return FSDP_RULES


def opt_state_shardings(specs, mesh: Mesh, rules=None, factored=False):
    """NamedShardings for the optimizer state, from the ParamSpec tree."""
    m = param_shardings(specs, mesh, rules)
    scalar = NamedSharding(mesh, P())
    if not factored:
        return {"m": m, "v": m, "step": scalar}

    def reduce_spec(s: ParamSpec, keep):
        shape = tuple(s.shape[i] for i in keep)
        axes = tuple(s.logical_axes[i] for i in keep)
        if not shape:
            return NamedSharding(mesh, P())
        return NamedSharding(
            mesh, spec_for(ParamSpec(shape, axes, dtype="float32"), mesh,
                           rules))

    vr = jax.tree.map(
        lambda s: reduce_spec(s, range(len(s.shape) - 1))
        if len(s.shape) >= 2 else reduce_spec(s, range(len(s.shape))),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    vc = jax.tree.map(
        lambda s: reduce_spec(s, list(range(len(s.shape) - 2))
                              + [len(s.shape) - 1])
        if len(s.shape) >= 2 else NamedSharding(mesh, P()),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return {"m": m, "vr": vr, "vc": vc, "step": scalar}


def data_sharding(mesh: Mesh, *, batch_axes=None) -> NamedSharding:
    """Batch-leading arrays: shard dim 0 over DP axes."""
    axes = batch_axes or tuple(a for a in ("pod", "data")
                               if a in mesh.shape)
    if len(axes) == 1:
        axes = axes[0]
    return NamedSharding(mesh, P(axes))


def batch_specs(input_tree, mesh: Mesh) -> Dict:
    """ShapeDtypeStruct tree -> NamedSharding tree (dim 0 = batch)."""
    ds = data_sharding(mesh)

    def one(s):
        dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
        dp = dp[0] if len(dp) == 1 else dp
        if s.shape and s.shape[0] % mesh_axis_size(mesh, dp) == 0:
            return NamedSharding(mesh, P(dp, *([None] * (len(s.shape) - 1))))
        return NamedSharding(mesh, P())
    return jax.tree.map(one, input_tree)


def kv_cache_sharding(mesh: Mesh, cache_tree):
    """Decode-state shardings.

    KV caches are [G, B, S, KH, Dh]: batch over DP; the *head_dim* over
    "model" (always divisible by 16 for the assigned archs, unlike KH) —
    the attention contraction over a sharded Dh becomes a psum, keeping
    per-chip cache at B/dp x S x KH x Dh/tp.  SSM/RWKV states shard their
    inner width over "model" and batch over DP.
    """
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = dp[0] if len(dp) == 1 else dp
    tp = "model" if "model" in mesh.shape else None

    def one(s):
        shape = s.shape
        dims = [None] * len(shape)
        if len(shape) >= 2 and shape[1] % mesh_axis_size(mesh, dp) == 0:
            dims[1] = dp                     # batch dim (after group stack)
        # shard the trailing width over model if divisible
        if tp and len(shape) >= 3 and shape[-1] % mesh_axis_size(mesh, tp) == 0:
            dims[-1] = tp
        return NamedSharding(mesh, P(*dims))
    return jax.tree.map(one, cache_tree)
