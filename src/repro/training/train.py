"""Train-step factories: microbatched grad accumulation, donation, and the
optional compressed cross-DP gradient sync.

Two paths:

  * ``make_train_step`` — plain jit SPMD: batch sharded over ("pod","data"),
    XLA inserts the gradient all-reduce.  This is the baseline lowered for
    every dry-run cell.
  * ``make_compressed_train_step`` — ``jax.shard_map`` manual over the DP
    axes (model axis stays auto): per-shard grads are int8-quantized
    per-tensor before the explicit cross-DP psum — 4x less traffic on the
    scarce cross-pod links — then dequantized for the (replicated) AdamW
    update.  Numerics validated against the plain path in tests.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..distributed.sharding import shard_map
from ..models import lm_loss
from . import optimizer as opt_mod

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1            # grad-accumulation steps per train step
    remat_policy: str = "full"       # none | dots | full
    aux_weight: float = 0.01
    compress_grads: Optional[str] = None   # None | "int8"
    seq_shard: bool = False          # sequence parallelism on activations
    accum_dtype: str = "float32"     # grad-accumulation dtype (bf16 halves
    #                                  the accumulator for 340B+ cells)
    opt: opt_mod.OptConfig = opt_mod.OptConfig()


def batch_constraint(mesh: Mesh):
    """DP-only activation constraint: [B, S, D] batch over ("pod","data").

    Without an explicit constraint at group boundaries, SPMD sometimes
    drops the batch sharding inside the layer scan and materializes
    batch-replicated activations (measured +15 GB/chip on 32k prefill)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = dp[0] if len(dp) == 1 else dp
    spec = P(dp, None, None)

    def constrain(x):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return constrain


def seq_constraint(mesh: Mesh):
    """Sequence-parallel activation constraint: [B, S, D] -> S over model."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = dp[0] if len(dp) == 1 else dp
    spec = P(dp, "model", None)

    def constrain(x):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return constrain


def _split_microbatches(batch: Dict[str, jax.Array], n: int,
                        mesh: Optional[Mesh] = None):
    """[B, ...] -> [n, B/n, ...] per leaf.

    The reshape would otherwise let SPMD move the batch sharding onto the
    scanned microbatch dim (leaving each device with the *full* per-micro-
    batch rows) — constrain dim 1 to the DP axes explicitly.
    """
    def one(x):
        y = x.reshape((n, x.shape[0] // n) + x.shape[1:])
        if mesh is not None:
            dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
            dp = dp[0] if len(dp) == 1 else dp
            spec = P(None, dp, *([None] * (y.ndim - 2)))
            y = jax.lax.with_sharding_constraint(
                y, NamedSharding(mesh, spec))
        return y
    return jax.tree.map(one, batch)


def make_train_step(cfg: ArchConfig, tc: TrainConfig,
                    mesh: Optional[Mesh] = None) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    act = None
    if mesh is not None:
        act = seq_constraint(mesh) if tc.seq_shard else batch_constraint(mesh)

    def loss_fn(params, mb):
        return lm_loss(cfg, params, mb, remat_policy=tc.remat_policy,
                       aux_weight=tc.aux_weight, act_constraint=act)

    def train_step(params, opt_state, batch):
        if tc.microbatches > 1:
            mbs = _split_microbatches(batch, tc.microbatches, mesh)
            adt = jnp.dtype(tc.accum_dtype)

            def acc_fn(carry, mb):
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                acc_l, acc_g = carry
                return (acc_l + loss,
                        jax.tree.map(lambda a, g: a + g.astype(adt),
                                     acc_g, grads)), None

            zero = (jnp.zeros((), F32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params))
            (loss, grads), _ = jax.lax.scan(acc_fn, zero, mbs)
            loss = loss / tc.microbatches
            grads = jax.tree.map(lambda g: g / tc.microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        grads, gnorm = opt_mod.clip_by_global_norm(grads, tc.opt.grad_clip)
        params, opt_state = opt_mod.adamw_update(tc.opt, params, grads,
                                                 opt_state)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": opt_mod.schedule(tc.opt, opt_state["step"])}
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# compressed-gradient path (explicit DP collectives via shard_map)
# ---------------------------------------------------------------------------
def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(F32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(F32) * scale


def compressed_psum(grads, axes: Tuple[str, ...]):
    """int8-compressed mean over DP axes (inside shard_map manual region).

    Each leaf is quantized per-tensor, summed in int32 (no overflow for
    <= 2^23 shards), and dequantized with the max scale — the standard
    1-bit/8-bit-Adam style scheme without error feedback.
    """
    def one(g):
        q, scale = quantize_int8(g.astype(F32))
        scale = jax.lax.pmax(scale, axes)          # shared scale bound
        q = jnp.clip(jnp.round(g.astype(F32) / scale), -127, 127
                     ).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int32), axes)
        n = jax.lax.psum(jnp.ones((), jnp.int32), axes)
        return (total.astype(F32) * scale / n.astype(F32)).astype(g.dtype)
    return jax.tree.map(one, grads)


def make_compressed_train_step(cfg: ArchConfig, tc: TrainConfig,
                               mesh: Mesh) -> Callable:
    """shard_map train step: manual over DP axes, int8 gradient sync."""
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    other = tuple(a for a in mesh.shape if a not in dp_axes)

    def loss_fn(params, mb):
        return lm_loss(cfg, params, mb, remat_policy=tc.remat_policy,
                       aux_weight=tc.aux_weight)

    def per_shard(params, opt_state, batch):
        # local grads on this DP shard (model axis handled by auto SPMD)
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads = jax.tree.map(lambda g: g.astype(F32), grads)
        if tc.compress_grads == "int8":
            grads = compressed_psum(grads, dp_axes)
        else:
            grads = jax.tree.map(
                lambda g: jax.lax.pmean(g, dp_axes), grads)
        loss = jax.lax.pmean(loss, dp_axes)
        grads, gnorm = opt_mod.clip_by_global_norm(grads, tc.opt.grad_clip)
        params, opt_state = opt_mod.adamw_update(tc.opt, params, grads,
                                                 opt_state)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": opt_mod.schedule(tc.opt, opt_state["step"])}
        return params, opt_state, metrics

    batch_spec = P(dp_axes if len(dp_axes) > 1 else dp_axes[0])
    rep = P()
    return shard_map(
        per_shard, mesh=mesh,
        in_specs=(rep, rep, batch_spec),
        out_specs=(rep, rep, rep),
        manual_axes=dp_axes)
