"""AdamW with sharded f32 state, cosine schedule, global-norm clipping.

Optimizer moments shard exactly like their parameters (TP); with
``zero1=True`` the largest replicated dimension of each moment is
additionally sharded over the data axis (ZeRO-1): XLA then materializes the
update as reduce-scatter + sharded-update + all-gather, cutting optimizer
memory by the DP degree.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    zero1: bool = False
    # Factored mode (Adafactor-style): bf16 first moment + row/col-factored
    # f32 second moment.  Cuts optimizer memory from 8 to ~2 bytes/param —
    # required to fit the 340B/400B train cells on a 256-chip pod.
    factored: bool = False


def schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def _v_shapes(shape) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """Factored second-moment shapes: row/col stats over the last two dims."""
    if len(shape) < 2:
        return shape, ()
    return shape[:-1], shape[:-2] + shape[-1:]


def init_opt_state(params, factored: bool = False) -> Dict[str, Any]:
    if not factored:
        zeros = lambda p: jnp.zeros(p.shape, F32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}
    m = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
    vr = jax.tree.map(lambda p: jnp.zeros(_v_shapes(p.shape)[0], F32), params)
    vc = jax.tree.map(lambda p: jnp.zeros(_v_shapes(p.shape)[1], F32), params)
    return {"m": m, "vr": vr, "vc": vc, "step": jnp.zeros((), jnp.int32)}


def abstract_opt_state(abstract_tree, factored: bool = False) -> Dict[str, Any]:
    if not factored:
        sds = lambda p: jax.ShapeDtypeStruct(p.shape, F32)
        return {"m": jax.tree.map(sds, abstract_tree),
                "v": jax.tree.map(sds, abstract_tree),
                "step": jax.ShapeDtypeStruct((), jnp.int32)}
    return {
        "m": jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.bfloat16),
            abstract_tree),
        "vr": jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(_v_shapes(p.shape)[0], F32),
            abstract_tree),
        "vc": jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(_v_shapes(p.shape)[1], F32),
            abstract_tree),
        "step": jax.ShapeDtypeStruct((), jnp.int32)}


# Optimizer-state shardings live in repro.distributed.sharding
# (opt_state_shardings), derived from the same ParamSpec logical axes.


def clip_by_global_norm(grads, max_norm: float):
    sq = sum(jnp.sum(jnp.square(g.astype(F32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(F32) * scale).astype(g.dtype),
                        grads), norm


def adamw_update(cfg: OptConfig, params, grads, opt_state):
    if cfg.factored:
        return _factored_update(cfg, params, grads, opt_state)
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(F32)
    bc2 = 1.0 - b2 ** step.astype(F32)

    def upd(p, g, m, v):
        g = g.astype(F32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}


def _factored_update(cfg: OptConfig, params, grads, opt_state):
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, vr, vc):
        g = g.astype(F32)
        g2 = jnp.square(g) + 1e-30
        if g.ndim >= 2:
            vr_new = b2 * vr + (1 - b2) * jnp.mean(g2, axis=-1)
            vc_new = b2 * vc + (1 - b2) * jnp.mean(g2, axis=-2)
            denom = jnp.sqrt(
                vr_new[..., :, None] * vc_new[..., None, :]
                / jnp.maximum(jnp.mean(vr_new, axis=-1,
                                       keepdims=True)[..., None], 1e-30))
        else:
            vr_new = b2 * vr + (1 - b2) * g2
            vc_new = vc
            denom = jnp.sqrt(vr_new)
        m_new = b1 * m.astype(F32) + (1 - b1) * g
        delta = m_new / (denom + cfg.eps) + cfg.weight_decay * p.astype(F32)
        return ((p.astype(F32) - lr * delta).astype(p.dtype),
                m_new.astype(jnp.bfloat16), vr_new, vc_new)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_vr = tdef.flatten_up_to(opt_state["vr"])
    flat_vc = tdef.flatten_up_to(opt_state["vc"])
    out = [upd(*a) for a in zip(flat_p, flat_g, flat_m, flat_vr, flat_vc)]
    return (tdef.unflatten([o[0] for o in out]),
            {"m": tdef.unflatten([o[1] for o in out]),
             "vr": tdef.unflatten([o[2] for o in out]),
             "vc": tdef.unflatten([o[3] for o in out]),
             "step": step})
