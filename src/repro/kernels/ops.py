"""Jitted public wrappers around the Pallas kernels.

``use_pallas`` defaults to interpret-mode-off + real kernels on TPU
backends, and falls back to the jnp reference implementations elsewhere
(the CPU dry-run container validates kernels in interpret mode via tests;
the XLA model paths never require Pallas).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .block_copy import block_copy_kernel
from .paged_attention import paged_attention_kernel
from .pt_walk import pt_walk_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("impl",))
def paged_attention(q, k_pool, v_pool, tables, lengths, impl: str = "auto"):
    """q [B,H,Dh] (H = KH*G), pools [KH,P,bs,Dh] -> [B,H,Dh]."""
    B, H, Dh = q.shape
    KH = k_pool.shape[0]
    G = H // KH
    qk = q.reshape(B, KH, G, Dh)
    if impl == "pallas" or (impl == "auto" and _on_tpu()):
        out = paged_attention_kernel(qk, k_pool, v_pool, tables, lengths,
                                     interpret=not _on_tpu())
    else:
        out = ref.paged_attention_ref(qk, k_pool, v_pool, tables, lengths)
    return out.reshape(B, H, Dh)


@functools.partial(jax.jit, static_argnames=("impl",))
def pt_walk(upper_row, leaf_tier, leaf_entries, vb, impl: str = "auto"):
    if impl == "pallas" or (impl == "auto" and _on_tpu()):
        return pt_walk_kernel(upper_row, leaf_tier, leaf_entries, vb,
                              interpret=not _on_tpu())
    return ref.pt_walk_ref(upper_row, leaf_tier, leaf_entries, vb)


@functools.partial(jax.jit, static_argnames=("impl",))
def block_copy(src_pool, dst_pool, ids, impl: str = "auto"):
    if impl == "pallas" or (impl == "auto" and _on_tpu()):
        return block_copy_kernel(src_pool, dst_pool, ids,
                                 interpret=not _on_tpu())
    return ref.block_copy_ref(src_pool, dst_pool, ids)
