"""Pallas TPU block-copy kernel — the tier-migration copy engine.

Moves KV blocks between pool buffers given (src_slot, dst_slot) pairs: the
data path of a Radiant migration (the control path — table updates and the
Algorithm-1 trigger — stays in ``memsys.tiered_kv``).  The slot indices are
scalar-prefetched into SMEM and consumed by the BlockSpec index maps, so
the DMA engine performs gather-from/scatter-to HBM directly; the
destination pool is passed as an aliased input (in-place update).

Layouts: pools [P, bs, KH, Dh]; ids i32[M, 2] (src, dst).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, src_ref, dst_ref, out_ref):
    del ids_ref, dst_ref
    out_ref[...] = src_ref[...]


def block_copy_kernel(src_pool, dst_pool, ids, *, interpret: bool = False):
    """Copy blocks src_pool[ids[m,0]] -> dst_pool[ids[m,1]] in place."""
    P, bs, KH, Dh = dst_pool.shape
    M = ids.shape[0]

    def src_map(m, ids):
        return (ids[m, 0], 0, 0, 0)

    def dst_map(m, ids):
        return (ids[m, 1], 0, 0, 0)

    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(M,),
            in_specs=[
                pl.BlockSpec((1, bs, KH, Dh), src_map),
                pl.BlockSpec((1, bs, KH, Dh), dst_map),
            ],
            out_specs=pl.BlockSpec((1, bs, KH, Dh), dst_map),
        ),
        out_shape=jax.ShapeDtypeStruct(dst_pool.shape, dst_pool.dtype),
        input_output_aliases={2: 0},    # dst_pool (operand 2 incl. prefetch) aliases out
        interpret=interpret,
    )(ids, src_pool, dst_pool)
