"""Pallas TPU paged-attention decode kernel.

The Radiant mapping is structural here (DESIGN.md section 2):

  * the **block table is scalar-prefetched into SMEM**
    (``pltpu.PrefetchScalarGridSpec``) — the paper's BHi guarantee that the
    page-table levels feeding the walk live in the fastest tier.  The
    ``BlockSpec`` index maps *are* the page walk: they read the table in
    SMEM and direct the DMA engine at the right physical KV block in HBM;
  * KV blocks stream HBM -> VMEM one (block_size, head_dim) tile per grid
    step, flash-style running softmax in f32 VMEM scratch;
  * tiles are MXU/VPU-aligned: head_dim padded to a multiple of 128 by the
    ops wrapper, block_size a multiple of 8.

Layouts (kernel-native; ``ops.paged_attention`` adapts from memsys):
  q        [B, KH, G, Dh]      G = query heads per kv head (GQA group)
  k_pool   [KH, P, bs, Dh]     physical block pools
  v_pool   [KH, P, bs, Dh]
  tables   [B, NB] int32       physical block id per (seq, virtual block)
  lengths  [B] int32           valid tokens per sequence
  out      [B, KH, G, Dh]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30

# renamed TPUCompilerParams -> CompilerParams across jax releases
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def _kernel(tables, lengths,            # scalar-prefetch refs (SMEM)
            q_ref, k_ref, v_ref,        # VMEM blocks
            o_ref,                      # VMEM output block
            m_ref, l_ref, acc_ref,      # VMEM scratch
            *, bs: int, nb: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(F32)                       # [G, Dh]
    k = k_ref[0, 0].astype(F32)                       # [bs, Dh]
    v = v_ref[0, 0].astype(F32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], F32))
    s = jnp.dot(q, k.T, preferred_element_type=F32) * scale   # [G, bs]

    # mask out positions beyond the sequence length in this block
    base = j * bs
    valid = (base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
             ) < lengths[b]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                               # [G, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=F32)
    m_ref[...] = m_new

    @pl.when(j == nb - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_attention_kernel(q, k_pool, v_pool, tables, lengths, *,
                           interpret: bool = False) -> jax.Array:
    """q [B,KH,G,Dh] x pools [KH,P,bs,Dh] -> [B,KH,G,Dh]."""
    B, KH, G, Dh = q.shape
    _, P, bs, _ = k_pool.shape
    NB = tables.shape[1]

    grid = (B, KH, NB)

    def q_map(b, h, j, tables, lengths):
        del j
        return (b, h, 0, 0)

    def kv_map(b, h, j, tables, lengths):
        # THE page walk: table lookup in SMEM chooses the physical block
        return (h, tables[b, j], 0, 0)

    def o_map(b, h, j, tables, lengths):
        del j
        return (b, h, 0, 0)

    kernel = functools.partial(_kernel, bs=bs, nb=NB)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, Dh), q_map),
                pl.BlockSpec((1, 1, bs, Dh), kv_map),
                pl.BlockSpec((1, 1, bs, Dh), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, G, Dh), o_map),
            scratch_shapes=[
                pltpu.VMEM((G, 1), F32),
                pltpu.VMEM((G, 1), F32),
                pltpu.VMEM((G, Dh), F32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KH, G, Dh), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(tables, lengths, q, k_pool, v_pool)
