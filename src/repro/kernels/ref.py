"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32
NEG_INF = -1e30


def paged_attention_ref(q, k_pool, v_pool, tables, lengths):
    """q [B,KH,G,Dh]; pools [KH,P,bs,Dh]; tables [B,NB]; lengths [B]."""
    B, KH, G, Dh = q.shape
    _, P, bs, _ = k_pool.shape
    NB = tables.shape[1]
    safe = jnp.maximum(tables, 0)
    # gather blocks: [B, KH, NB, bs, Dh] -> [B, KH, S, Dh]
    k = jnp.moveaxis(k_pool[:, safe], 0, 2)      # [B, NB, KH, bs, Dh]...
    k = k_pool[:, safe]                          # [KH, B, NB, bs, Dh]
    v = v_pool[:, safe]
    k = jnp.moveaxis(k, 0, 1).reshape(B, KH, NB * bs, Dh)
    v = jnp.moveaxis(v, 0, 1).reshape(B, KH, NB * bs, Dh)
    s = jnp.einsum("bkgd,bksd->bkgs", q.astype(F32), k.astype(F32))
    s = s / jnp.sqrt(jnp.asarray(Dh, F32))
    pos = jnp.arange(NB * bs)
    mask = pos[None, :] < lengths[:, None]
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", p, v.astype(F32))
    return out.astype(q.dtype)


def pt_walk_ref(upper_row, leaf_tier, leaf_entries, vb):
    fanout = leaf_entries.shape[1]
    leaf_id = upper_row[vb // fanout]
    valid = leaf_id >= 0
    safe = jnp.where(valid, leaf_id, 0)
    slot = leaf_entries[safe, vb % fanout]
    tier = leaf_tier[safe]
    return (jnp.where(valid, tier, -1).astype(jnp.int32),
            jnp.where(valid, slot, -1).astype(jnp.int32))


def block_copy_ref(src_pool, dst_pool, ids):
    return dst_pool.at[ids[:, 1]].set(src_pool[ids[:, 0]])
