"""Pallas TPU radix block-table walk kernel — the paper's object of study
as a compute kernel.

Translates virtual block ids to (tier, slot) physical coordinates through
a two-level radix table.  The tiling *is* the Radiant placement decision:

  * the upper level (``upper``) and the leaf-page tier vector are tiny and
    ride whole in VMEM — BHi: the high levels of the table are pinned in
    the fastest tier and every walk's first accesses are guaranteed fast;
  * leaf entry pages stream through VMEM in grid-sized tiles (they are the
    bulk of the table, like the paper's L4/PTE pages — 1/FANOUT of data).

Queries are [N] virtual block ids for one sequence (the decode hot path);
the batched wrapper vmaps.  Output is (tier[N], slot[N]).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

I32 = jnp.int32


def _kernel(upper_ref, leaf_tier_ref, leaf_entries_ref, vb_ref,
            tier_ref, slot_ref, *, fanout: int):
    vb = vb_ref[...]                                  # [QB] virtual blocks
    leaf_idx = vb // fanout                           # position in upper
    entry = vb % fanout
    # level-1 access: upper table (VMEM-pinned — BHi)
    leaf_id = upper_ref[0, leaf_idx]                  # gather from VMEM
    valid = leaf_id >= 0
    safe = jnp.where(valid, leaf_id, 0)
    # level-2 access: leaf entry page (streamed) + the leaf page's own tier
    slot = leaf_entries_ref[safe, entry]
    tier = leaf_tier_ref[0, safe]
    tier_ref[...] = jnp.where(valid, tier, -1)
    slot_ref[...] = jnp.where(valid, slot, -1)


def pt_walk_kernel(upper_row, leaf_tier, leaf_entries, vb, *,
                   q_block: int = 256, interpret: bool = False):
    """upper_row i32[max_leaf], leaf_tier i32[n_leaf],
    leaf_entries i32[n_leaf, FANOUT], vb i32[N] -> (tier[N], slot[N]).

    ``N`` need not divide ``q_block``: queries are zero-padded to the
    next block multiple (query 0 is always in range, the pad lanes walk
    it harmlessly) and the pad results are sliced off.
    """
    n = vb.shape[0]
    n_leaf, fanout = leaf_entries.shape
    q_block = min(q_block, max(n, 1))
    pad = (-n) % q_block
    if pad:
        vb = jnp.concatenate([vb, jnp.zeros((pad,), vb.dtype)])
    n_pad = n + pad
    grid = (n_pad // q_block,)

    kernel = functools.partial(_kernel, fanout=fanout)
    tier, slot = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, upper_row.shape[0]), lambda i: (0, 0)),
            pl.BlockSpec((1, n_leaf), lambda i: (0, 0)),
            pl.BlockSpec((n_leaf, fanout), lambda i: (0, 0)),
            pl.BlockSpec((q_block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((q_block,), lambda i: (i,)),
            pl.BlockSpec((q_block,), lambda i: (i,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((n_pad,), I32),
                   jax.ShapeDtypeStruct((n_pad,), I32)],
        interpret=interpret,
    )(upper_row[None, :], leaf_tier[None, :], leaf_entries, vb)
    if pad:
        tier, slot = tier[:n], slot[:n]
    return tier, slot
