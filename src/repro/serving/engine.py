"""Continuous-batching serving engine over the tiered paged-KV cache.

The scheduler is where the paper's policies become throughput:

  * ACTIVE sequences decode in a fixed-size batch; their KV blocks live in
    the HOT pool and — by the Radiant invariant — their block-table leaf
    pages are HOT too, so the decode kernel's "page walk" (upper table ->
    leaf -> block) never touches the slow tier.
  * When a sequence PAUSES (preempted by arrivals), its blocks are demoted
    to the COLD pool; the *last* demotion drags the leaf table page cold
    (Algorithm 1).  The upper table never moves (BHi): resume scheduling
    can inspect any sequence's table cheaply.
  * On RESUME the blocks are promoted back; the first promotion drags the
    leaf page hot before the sequence re-enters the batch.

Compare policy="bind_none" (leaf pages pinned cold — every walk pays the
slow tier) and policy="bind_all" (everything pinned hot — hot pool
exhaustion stalls admission; the paper's section 3.5 pathology) in
benchmarks/kv_tiering.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..memsys import tiered_kv as tkv


@dataclasses.dataclass
class Request:
    rid: int
    prompt_len: int
    max_new: int
    generated: int = 0
    state: str = "queued"      # queued | active | paused | done


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens: int = 0
    swaps_in: int = 0
    swaps_out: int = 0
    cold_walks: int = 0        # decode steps whose table walk touched COLD


class TieredServingEngine:
    """Scheduler + tiered KV; the model decode fn is injected (tests use a
    toy model, examples use the real stack)."""

    def __init__(self, *, n_groups: int, kv_heads: int, head_dim: int,
                 block_size: int = 16, n_hot_blocks: int = 256,
                 n_cold_blocks: int = 1024, n_seqs: int = 64,
                 max_seq: int = 4096, active_slots: int = 4,
                 radiant: bool = True):
        self.kv = tkv.init(n_groups, n_hot_blocks, n_cold_blocks, block_size,
                           kv_heads, head_dim, n_seqs, max_seq)
        self.block_size = block_size
        self.active_slots = active_slots
        self.max_seq = max_seq
        self.radiant = radiant
        self.requests: Dict[int, Request] = {}
        self.active: List[int] = []
        self.queued: List[int] = []
        self.paused: List[int] = []
        self.stats = EngineStats()
        self._append = jax.jit(tkv.append_token)
        self._migrate = jax.jit(
            tkv.migrate_sequence,
            static_argnames=("to_tier", "max_blocks", "trigger_leaf"))
        self._release = jax.jit(tkv.release_sequence,
                                static_argnames=("max_blocks",))

    # ------------------------------------------------------------------ API
    def submit(self, req: Request):
        self.requests[req.rid] = req
        self.queued.append(req.rid)

    def _max_blocks(self) -> int:
        return -(-self.max_seq // self.block_size)

    def _swap_out(self, rid: int):
        self.kv = self._migrate(self.kv, jnp.asarray(rid), tkv.COLD,
                                self._max_blocks(),
                                trigger_leaf=self.radiant)
        self.requests[rid].state = "paused"
        self.paused.append(rid)
        self.stats.swaps_out += 1

    def _swap_in(self, rid: int):
        self.kv = self._migrate(self.kv, jnp.asarray(rid), tkv.HOT,
                                self._max_blocks(),
                                trigger_leaf=self.radiant)
        self.requests[rid].state = "active"
        self.active.append(rid)
        self.stats.swaps_in += 1

    def schedule(self):
        """Round-robin fairness: rotate one active seq out when the queue
        has waiters; fill free slots from paused-then-queued."""
        if (self.queued or self.paused) and len(self.active) >= self.active_slots:
            victim = self.active.pop(0)
            self._swap_out(victim)
        while len(self.active) < self.active_slots:
            if self.paused:
                self._swap_in(self.paused.pop(0))
            elif self.queued:
                # activation == promotion: a queued request whose prefill
                # spilled to the cold pool is pulled hot (and, under
                # Radiant, its table leaf pages with it) before decoding
                rid = self.queued.pop(0)
                self.kv = self._migrate(self.kv, jnp.asarray(rid), tkv.HOT,
                                        self._max_blocks(),
                                        trigger_leaf=self.radiant)
                self.requests[rid].state = "active"
                self.active.append(rid)
            else:
                break

    def prefill(self, rid: int, kv_tokens):
        """Write prompt KV ([prompt_len, G, KH, Dh] pair) for a request."""
        k_toks, v_toks = kv_tokens
        for t in range(self.requests[rid].prompt_len):
            self.kv = self._append(self.kv, jnp.asarray(rid),
                                   k_toks[t], v_toks[t])

    def decode_tick(self, decode_fn) -> Dict[int, int]:
        """One decode step for the active batch.

        ``decode_fn(kv, seq_ids) -> (k_new, v_new)`` produces each active
        sequence's next-token KV ([G, KH, Dh] per seq); the engine appends
        them and advances bookkeeping.  Returns {rid: new_len}.
        """
        out = {}
        tier_now = np.asarray(self.kv.leaf_tier)
        upper_now = np.asarray(self.kv.upper)
        for rid in list(self.active):
            # count walks that would touch cold table pages (shouldn't
            # happen under Radiant for active sequences)
            leafs = upper_now[rid]
            leafs = leafs[leafs >= 0]
            if len(leafs) and (tier_now[leafs] == tkv.COLD).any():
                self.stats.cold_walks += 1
            k_new, v_new = decode_fn(self.kv, rid)
            self.kv = self._append(self.kv, jnp.asarray(rid), k_new, v_new)
            req = self.requests[rid]
            req.generated += 1
            self.stats.tokens += 1
            out[rid] = req.prompt_len + req.generated
            if req.generated >= req.max_new:
                req.state = "done"
                self.active.remove(rid)
                # free blocks + table pages (paper: PT pages are reclaimed
                # when their data pages are freed)
                self.kv = self._release(self.kv, jnp.asarray(rid),
                                        self._max_blocks())
        self.stats.steps += 1
        return out

    def run(self, decode_fn, max_ticks: int = 10000) -> EngineStats:
        ticks = 0
        while (self.queued or self.paused or self.active) \
                and ticks < max_ticks:
            self.schedule()
            if not self.active:
                break
            self.decode_tick(decode_fn)
            ticks += 1
        return self.stats
