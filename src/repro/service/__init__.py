"""Simulation-as-a-service: a shape-bucketed query broker over the
batched sweep engine.

The paper's evaluation — and the ROADMAP north star — is a large grid of
(policy, cost-model, workload) what-if simulations.  ``repro.core.sweep``
made one *hand-built* grid cheap; this package makes *arbitrary
concurrent* scenario traffic cheap:

  * :class:`SimQuery` — one independent question: a machine, a policy
    bundle, a cost model, and a trace (by value or by
    :class:`~repro.core.workloads.TraceSpec`), plus priority/deadline.
  * :class:`SimBroker` — admission-queues queries, buckets them by
    (machine, compiled-budget bound, trace shape), microbatches each
    bucket into a single ``sweep_lanes`` call across the policy-lane
    axis (optionally sharded over devices), and resolves per-query
    futures.  A content-addressed result cache answers repeats with zero
    XLA recompiles and zero device work.
  * :mod:`repro.service.search` — a client-side search driver (grid +
    successive halving over PolicyConfig space) that exercises the broker
    the way an architecture-search harness would.

Every layer reports into an optional :class:`repro.obs.Telemetry`
(``SimBroker(telemetry=...)``): lifecycle spans, queue-wait/flush
histograms, cache and migration counters — ``broker.snapshot()`` renders
the lot; the default is a no-op sink and results are identical either
way (see :mod:`repro.obs`).

``benchmarks/service_throughput.py`` measures the broker against naive
per-query execution; ``tests/test_service.py`` pins bit-identical
per-query results against direct sequential ``TieredMemSimulator`` runs.

The failure model lives in :mod:`repro.service.resilience` (typed error
taxonomy, TTL quarantine, per-bucket circuit breaker, retry/backoff and
admission-control knobs) and is chaos-tested through the deterministic
fault-injection harness in :mod:`repro.obs.inject` — see the README's
"Robustness" section for the taxonomy and degraded-mode semantics.
"""
from ..obs import FaultInjector, FaultRule, InjectedFault, NullTelemetry, \
    Telemetry, fail_lane, fail_n, fail_once, fail_rate
from .broker import BrokerStats, SimBroker
from .cache import DiskCacheTier, ResultCache
from .query import (SimFuture, SimQuery, lane_digest, query_cache_key,
                    spec_cache_key)
from .resilience import (BrokerOverloadedError, BrokerTimeoutError,
                         CircuitBreaker, DeadlineExceededError,
                         PoisonedQueryError, Quarantine, ResilienceConfig,
                         ServiceError)
from .search import grid_search, policy_grid, successive_halving

__all__ = [
    "BrokerStats", "SimBroker", "DiskCacheTier", "ResultCache", "SimFuture",
    "SimQuery", "lane_digest", "query_cache_key", "spec_cache_key",
    "grid_search", "policy_grid", "successive_halving",
    "Telemetry", "NullTelemetry",
    "ServiceError", "PoisonedQueryError", "DeadlineExceededError",
    "BrokerOverloadedError", "BrokerTimeoutError",
    "ResilienceConfig", "Quarantine", "CircuitBreaker",
    "FaultInjector", "FaultRule", "InjectedFault",
    "fail_once", "fail_n", "fail_lane", "fail_rate",
]
