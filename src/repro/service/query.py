"""Service query / future types and their content-addressed identity.

A :class:`SimQuery` is one independent simulation request.  Its identity
for caching is fully content-addressed: the machine shape, the fault
engine, every cost and policy leaf, and the digest of the (canonical)
trace — never object identity — so two clients asking the same question
share one cache line and one lane.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional, Tuple, Union

from ..core.config import CostConfig, MachineConfig, PolicyConfig
from ..core.sim import RunResult, Trace
from ..core.workloads import TraceSpec, trace_digest


def _leaf_tuple(obj, name: str) -> Tuple:
    """Dataclass -> hashable leaf tuple; rejects traced/stacked leaves
    (service queries are single simulations, not pre-batched bundles)."""
    vals = tuple(getattr(obj, f.name) for f in dataclasses.fields(obj))
    try:
        hash(vals)
    except TypeError:
        raise ValueError(
            f"{name} for a SimQuery must hold plain Python scalars; got "
            f"array leaves — submit one query per lane and let the broker "
            f"batch them") from None
    return vals


@dataclasses.dataclass(frozen=True)
class SimQuery:
    """One simulation request.

    ``trace`` is either a built :class:`Trace` (used as-is — the caller
    owns its shape) or a :class:`TraceSpec` (service-owned construction:
    the broker builds it once per distinct spec and idle-pads it to a
    power-of-two step count so specs of similar length share a bucket,
    a compile, and a microbatch).

    ``priority`` (higher flushes first) and ``deadline`` (absolute
    broker-clock seconds by which the bucket must flush) drive the
    broker's scheduler; both are identity-irrelevant for caching.

    ``phase_b`` and ``engine`` select the fault engine and the stepper
    (see ``core.sim``); both are part of the query's cache identity and
    of its bucket key (lanes batched into one program must agree).  The
    non-default combinations are reference (oracle) paths and require
    ``debug=True`` — identity-irrelevant, like the scheduler knobs.
    """

    trace: Union[Trace, TraceSpec]
    policy: PolicyConfig
    cost: CostConfig = dataclasses.field(default_factory=CostConfig)
    machine: MachineConfig = dataclasses.field(default_factory=MachineConfig)
    phase_b: str = "batched"
    engine: str = "blocked"
    priority: int = 0
    deadline: Optional[float] = None
    debug: bool = False

    def __post_init__(self):
        if not isinstance(self.trace, (Trace, TraceSpec)):
            raise ValueError(
                f"trace must be a Trace or TraceSpec, got "
                f"{type(self.trace).__name__}")
        if self.phase_b not in ("batched", "sequential"):
            raise ValueError(f"unknown phase_b {self.phase_b!r}")
        if self.engine not in ("blocked", "per_step"):
            raise ValueError(f"unknown engine {self.engine!r}")
        if (self.engine != "blocked" or self.phase_b != "batched") \
                and not self.debug:
            raise ValueError(
                f"engine={self.engine!r} phase_b={self.phase_b!r} are "
                "reference (oracle) paths; pass debug=True to query them")


def query_cache_key(q: SimQuery, canonical: Trace) -> Tuple:
    """Content-addressed identity of a query given its canonical trace."""
    return (q.machine, q.phase_b, q.engine,
            _leaf_tuple(q.cost, "CostConfig"),
            _leaf_tuple(q.policy, "PolicyConfig"), trace_digest(canonical))


def lane_digest(key: Tuple) -> str:
    """Short stable digest of a cache key — the identity that
    ``PoisonedQueryError`` carries, the quarantine deny-list stores, and
    ``fail_lane`` chaos rules match against.  Cache keys are tuples of
    dataclass instances and scalars whose reprs are process-stable, so
    hashing the repr is deterministic across runs."""
    return hashlib.blake2b(repr(key).encode(), digest_size=8).hexdigest()


def spec_cache_key(q: SimQuery, pad_floor: int) -> Tuple:
    """Identity of a spec-addressed query WITHOUT materializing the trace
    — the spec recipe digest (plus the broker's canonical pad floor,
    which determines the padded shape) stands in for the content digest,
    so a cache hit skips trace generation entirely.  The trade-off: a
    spec query and a raw-Trace query with identical content occupy
    separate cache lines."""
    assert isinstance(q.trace, TraceSpec)
    return (q.machine, q.phase_b, q.engine,
            _leaf_tuple(q.cost, "CostConfig"),
            _leaf_tuple(q.policy, "PolicyConfig"),
            ("spec", q.trace.digest(q.machine), pad_floor))


class SimFuture:
    """Handle to a pending (or cached) query result.

    ``result()`` drives the broker until this query's bucket has flushed
    (the broker is synchronous and in-process; a future is "pending"
    exactly while its query waits in an admission bucket for a microbatch
    to fill or come due).  ``result(timeout=...)`` bounds that drive on
    the broker's scheduling clock and raises ``BrokerTimeoutError`` when
    the budget runs out; the future stays pending and can be re-forced.
    A failed query re-raises its typed ``ServiceError`` (poisoned, shed,
    rejected) on every ``result()`` call.
    """

    __slots__ = ("query", "from_cache", "_broker", "_result", "_error")

    def __init__(self, query: SimQuery, broker):
        self.query = query
        self.from_cache = False
        self._broker = broker
        self._result: Optional[RunResult] = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._result is not None or self._error is not None

    def result(self, timeout: Optional[float] = None) -> RunResult:
        if not self.done():
            self._broker._force(self, timeout=timeout)
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result

    def _resolve(self, res: RunResult, from_cache: bool = False) -> None:
        self._result = res
        self.from_cache = from_cache

    def _fail(self, exc: BaseException) -> None:
        self._error = exc
