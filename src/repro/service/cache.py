"""Content-addressed, LRU-bounded result cache for the simulation service.

Keys are :func:`repro.service.query.query_cache_key` tuples — machine +
engine + every cost/policy leaf + the canonical trace digest (for
spec-addressed queries, :func:`~repro.service.query.spec_cache_key`
substitutes the recipe digest so hits skip generation too) — so a hit
means "this exact simulation already ran" and is served with zero device
work and zero XLA recompiles (``tests/test_service.py`` asserts the
latter via ``sweep.compile_count()``).  Values are full
:class:`~repro.core.sim.RunResult` pytrees (host-side numpy), shared by
reference: results are treated as immutable by convention, like every
other artifact of the functional simulator.
"""
from __future__ import annotations

import collections
from typing import Optional, Tuple

from ..core.sim import RunResult


class ResultCache:
    def __init__(self, max_entries: int = 512):
        self._data: "collections.OrderedDict[Tuple, RunResult]" = \
            collections.OrderedDict()
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._data)

    def get(self, key: Tuple) -> Optional[RunResult]:
        hit = self._data.get(key)
        if hit is None:
            self.misses += 1
            return None
        self._data.move_to_end(key)
        self.hits += 1
        return hit

    def put(self, key: Tuple, value: RunResult) -> None:
        self._data[key] = value
        self._data.move_to_end(key)
        while len(self._data) > self.max_entries:
            self._data.popitem(last=False)

    def clear(self) -> None:
        self._data.clear()
