"""Content-addressed, LRU-bounded result cache for the simulation service.

Keys are :func:`repro.service.query.query_cache_key` tuples — machine +
engines + every cost/policy leaf + the canonical trace digest (for
spec-addressed queries, :func:`~repro.service.query.spec_cache_key`
substitutes the recipe digest so hits skip generation too) — so a hit
means "this exact simulation already ran" and is served with zero device
work and zero XLA recompiles (``tests/test_service.py`` asserts the
latter via ``sweep.compile_count()``).  Values are full
:class:`~repro.core.sim.RunResult` pytrees (host-side numpy), shared by
reference: results are treated as immutable by convention, like every
other artifact of the functional simulator.

Optionally the cache spills to disk (``spill_dir``): keys are already
process-stable (dataclass reprs of plain scalars plus content digests —
no object identity anywhere), so a fresh process pointed at the same
directory serves warm hits with zero device work.  The disk tier is an
mtime-LRU with a byte cap; entries store their full key alongside the
value, so a (vanishingly unlikely) filename-hash collision or a stale
format reads as a miss, never as a wrong result.

The disk tier is *self-healing*: every entry carries a framed header
(magic + blake2b payload checksum + length) written via temp-file +
atomic rename, so a torn write, bit rot, or truncation is detected on
read — the entry is counted (``cache.disk.corrupt``), moved to a
``quarantine/`` sidecar directory for post-mortem, and served as a miss
so the value is recomputed and rewritten clean.  Corruption can never
surface as a wrong result, only as a recompute.  The chaos harness
(:mod:`repro.obs.inject`) hooks the ``cache.disk.read`` /
``cache.disk.write`` sites to exercise exactly these paths.
"""
from __future__ import annotations

import collections
import hashlib
import os
import pickle
import tempfile
from pathlib import Path
from typing import Optional, Tuple

from ..core.sim import RunResult
from ..obs import or_null
from ..obs.inject import InjectedFault, or_null_injector

_DISK_FORMAT = 2
# Framed entry: magic/version | blake2b-16(payload) | u64 payload length
_MAGIC = b"RPTC\x02"
_CKSUM_LEN = 16
_HEADER_LEN = len(_MAGIC) + _CKSUM_LEN + 8


def _frame(payload: bytes) -> bytes:
    cksum = hashlib.blake2b(payload, digest_size=_CKSUM_LEN).digest()
    return _MAGIC + cksum + len(payload).to_bytes(8, "big") + payload


def _unframe(record: bytes) -> bytes:
    """Payload of a framed record; raises ``ValueError`` on any sign of
    corruption (bad magic, truncation, checksum mismatch)."""
    if len(record) < _HEADER_LEN or not record.startswith(_MAGIC):
        raise ValueError("bad magic or truncated header")
    off = len(_MAGIC)
    cksum = record[off:off + _CKSUM_LEN]
    n = int.from_bytes(record[off + _CKSUM_LEN:_HEADER_LEN], "big")
    payload = record[_HEADER_LEN:]
    if len(payload) != n:
        raise ValueError("payload length mismatch (torn write?)")
    if hashlib.blake2b(payload, digest_size=_CKSUM_LEN).digest() != cksum:
        raise ValueError("payload checksum mismatch")
    return payload


class DiskCacheTier:
    """Pickle-file LRU keyed by a stable hash of ``repr(key)``.

    Not safe against concurrent writers of the *same* entry beyond
    last-write-wins (writes go through a temp file + atomic rename), which
    matches the cache contract: identical keys hold identical results.

    Every operation is accounted: ``hits``/``misses`` (gets),
    ``flushes`` (entries written to disk) and ``evictions`` (entries
    unlinked by the byte cap) — without them spill effectiveness is
    unmeasurable.  ``stats()`` exposes the lot; an attached
    :class:`~repro.obs.Telemetry` mirrors each count into the metrics
    registry under ``cache.disk.*``.
    """

    def __init__(self, path, max_bytes: int = 1 << 30, telemetry=None,
                 injector=None):
        self.path = Path(path)
        self.path.mkdir(parents=True, exist_ok=True)
        self.max_bytes = int(max_bytes)
        self.telemetry = or_null(telemetry)
        self.injector = or_null_injector(injector)
        self.hits = 0
        self.misses = 0
        self.flushes = 0       # entries written (spilled) to disk
        self.evictions = 0     # entries unlinked by the byte cap
        self.corrupt = 0       # entries failing frame/checksum validation
        # Running byte estimate so put() doesn't rescan the directory
        # every time: None = unknown (first put resyncs via _evict);
        # overwrites over-count, which only triggers an early resync.
        self._approx_bytes = None

    def _file(self, key: Tuple) -> Path:
        digest = hashlib.blake2b(repr(key).encode(), digest_size=16)
        return self.path / f"{digest.hexdigest()}.pkl"

    def _quarantine(self, f: Path) -> None:
        """Move a corrupt entry to the ``quarantine/`` sidecar for
        post-mortem (never served again; never re-detected as corrupt)."""
        qdir = self.path / "quarantine"
        try:
            qdir.mkdir(exist_ok=True)
            os.replace(f, qdir / f.name)
        except OSError:
            try:
                f.unlink()              # sidecar unavailable: just drop it
            except OSError:
                pass

    def get(self, key: Tuple) -> Optional[RunResult]:
        f = self._file(key)
        try:
            self.injector.fire("cache.disk.read", key=f.stem)
            with open(f, "rb") as fh:
                record = fh.read()
        except FileNotFoundError:
            self.misses += 1
            self.telemetry.counter("cache.disk.misses").inc()
            return None
        except (OSError, InjectedFault):
            # an I/O error (real or injected) is a miss, not corruption
            self.misses += 1
            self.telemetry.counter("cache.disk.misses").inc()
            return None
        try:
            payload = pickle.loads(_unframe(record))
            if (payload.get("format") != _DISK_FORMAT
                    or payload.get("key") != key):
                raise ValueError("stale or colliding cache entry")
        except Exception:  # noqa: BLE001 — any decode failure = corrupt
            # the entry existed but failed validation: self-heal by
            # quarantining it and reporting a miss so the value is
            # recomputed and rewritten clean
            self.corrupt += 1
            self.telemetry.counter("cache.disk.corrupt").inc()
            self._quarantine(f)
            self.misses += 1
            self.telemetry.counter("cache.disk.misses").inc()
            return None
        try:
            os.utime(f)                      # refresh LRU position
        except OSError:
            pass          # read-only spill dir: the hit still counts
        self.hits += 1
        self.telemetry.counter("cache.disk.hits").inc()
        return payload["value"]

    def put(self, key: Tuple, value: RunResult) -> None:
        record = _frame(pickle.dumps(
            {"format": _DISK_FORMAT, "key": key, "value": value}))
        if len(record) > self.max_bytes:
            return
        try:
            self.injector.fire("cache.disk.write", key=self._file(key).stem)
        except InjectedFault as exc:
            if exc.kind == "corrupt":
                # simulate a torn write: half the framed record lands on
                # disk (still via atomic rename — the tear is in the
                # content, which only the checksum frame can catch)
                record = record[:max(len(record) // 2, 1)]
            else:
                return                       # injected write error: drop
        fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as fh:
                fh.write(record)
            os.replace(tmp, self._file(key))
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            return
        self.flushes += 1
        self.telemetry.counter("cache.disk.flushes").inc()
        if self._approx_bytes is not None:
            self._approx_bytes += len(record)
        if self._approx_bytes is None or self._approx_bytes > self.max_bytes:
            self._evict()                    # scans once, then resyncs

    def _evict(self) -> None:
        entries = []
        for f in self.path.glob("*.pkl"):
            try:
                st = f.stat()
            except OSError:
                continue      # raced with a concurrent evictor: skip
            entries.append((st.st_mtime, st.st_size, f))
        total = sum(size for _, size, _ in entries)
        for _, size, f in sorted(entries):   # oldest mtime first
            if total <= self.max_bytes:
                break
            try:
                f.unlink()
                self.evictions += 1
                self.telemetry.counter("cache.disk.evictions").inc()
            except OSError:
                pass          # already gone elsewhere; still over-counted
            total -= size
        self._approx_bytes = total

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "flushes": self.flushes, "evictions": self.evictions,
                "corrupt": self.corrupt,
                "entries": sum(1 for _ in self.path.glob("*.pkl")),
                "quarantined": sum(
                    1 for _ in (self.path / "quarantine").glob("*.pkl"))}

    def clear(self) -> None:
        for f in self.path.glob("*.pkl"):
            try:
                f.unlink()
            except OSError:
                pass
        for f in (self.path / "quarantine").glob("*.pkl"):
            try:
                f.unlink()
            except OSError:
                pass
        self._approx_bytes = 0


class ResultCache:
    """In-memory LRU with an optional on-disk spill tier.

    ``get`` checks memory first, then disk (promoting the entry back into
    memory); ``put`` writes through to both tiers.
    """

    def __init__(self, max_entries: int = 512, spill_dir=None,
                 disk_max_bytes: int = 1 << 30, telemetry=None):
        self._data: "collections.OrderedDict[Tuple, RunResult]" = \
            collections.OrderedDict()
        self.max_entries = max_entries
        self.telemetry = or_null(telemetry)
        self.disk = (DiskCacheTier(spill_dir, disk_max_bytes,
                                   telemetry=self.telemetry)
                     if spill_dir is not None else None)
        self.hits = 0
        self.misses = 0
        self.evictions = 0     # entries dropped from the in-memory LRU

    def attach_telemetry(self, telemetry) -> None:
        """Late-bind a telemetry sink (the broker owns the Telemetry but
        callers may hand it a pre-built cache)."""
        self.telemetry = or_null(telemetry)
        if self.disk is not None:
            self.disk.telemetry = self.telemetry

    def attach_injector(self, injector) -> None:
        """Late-bind a fault injector over the disk sites (the broker
        owns the chaos plan but callers may hand it a pre-built cache)."""
        if self.disk is not None:
            self.disk.injector = or_null_injector(injector)

    def __len__(self) -> int:
        return len(self._data)

    def get(self, key: Tuple) -> Optional[RunResult]:
        hit = self._data.get(key)
        if hit is None and self.disk is not None:
            hit = self.disk.get(key)
            if hit is not None:
                self._data[key] = hit        # promote; evicted LRU below
                self._trim()
        if hit is None:
            self.misses += 1
            self.telemetry.counter("cache.mem.misses").inc()
            return None
        self._data.move_to_end(key)
        self.hits += 1
        self.telemetry.counter("cache.mem.hits").inc()
        return hit

    def put(self, key: Tuple, value: RunResult) -> None:
        self._data[key] = value
        self._data.move_to_end(key)
        self._trim()
        if self.disk is not None:
            self.disk.put(key, value)

    def _trim(self) -> None:
        while len(self._data) > self.max_entries:
            self._data.popitem(last=False)
            self.evictions += 1
            self.telemetry.counter("cache.mem.evictions").inc()

    def stats(self) -> dict:
        out = {"hits": self.hits, "misses": self.misses,
               "evictions": self.evictions, "entries": len(self._data)}
        if self.disk is not None:
            out["disk"] = self.disk.stats()
        return out

    def clear(self) -> None:
        self._data.clear()
        if self.disk is not None:
            self.disk.clear()
