"""Failure vocabulary and resilience primitives for the service layer.

The broker's failure model (see ``service.broker``): a microbatch flush
can fail for three distinct reasons — a *poisoned lane* (one query
deterministically kills the program it rides in), a *transient device
error* (retry with backoff clears it), or *pressure* (deadlines already
blown, admission queue over capacity).  Each gets a typed error so
clients and the search drivers can tell "your query is bad" from "the
service is busy" from "you asked too late", and three small primitives
implement the policy:

  * :class:`Quarantine` — TTL'd deny-list of poisoned query digests, so
    resubmitting a known-bad query fails fast instead of re-poisoning a
    64-lane batch;
  * :class:`CircuitBreaker` — per-bucket consecutive-failure counter
    that trips the bucket into degraded (per-lane, isolating) execution
    and closes again after consecutive clean flushes;
  * :class:`ResilienceConfig` — the knobs, injectable into
    ``SimBroker`` and defaulted for production.

Everything is host-side and clock-injectable: chaos tests drive the TTL
and breaker transitions deterministically with a fake clock.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


class ServiceError(RuntimeError):
    """Base of every typed service-layer failure."""


class PoisonedQueryError(ServiceError):
    """This query (digest) deterministically fails the device program it
    is batched into.  Raised on the isolated lane after bisection, and
    fast on resubmits while the digest is quarantined."""

    def __init__(self, digest: str, cause: Optional[BaseException] = None,
                 quarantined: bool = False):
        self.digest = digest
        self.quarantined = quarantined
        how = "quarantined" if quarantined else "isolated by bisection"
        detail = f": {cause}" if cause is not None else ""
        super().__init__(f"poisoned query {digest} ({how}){detail}")
        if cause is not None:
            self.__cause__ = cause


class DeadlineExceededError(ServiceError):
    """The query's deadline passed before its bucket flushed; the broker
    sheds it instead of silently computing a result nobody wants."""

    def __init__(self, deadline: float, now: float):
        self.deadline = deadline
        self.now = now
        super().__init__(
            f"deadline {deadline:.3f} expired {now - deadline:.3f}s before "
            "flush")


class BrokerOverloadedError(ServiceError):
    """Admission control: the broker is at ``max_pending_lanes`` and this
    query lost the priority comparison."""

    def __init__(self, pending: int, cap: int):
        self.pending = pending
        self.cap = cap
        super().__init__(
            f"broker over admission cap ({pending}/{cap} pending lanes); "
            "lowest-priority work is rejected")


class BrokerTimeoutError(ServiceError):
    """``SimFuture.result(timeout=...)`` ran out of broker-clock budget
    before the future settled (the future stays pending)."""

    def __init__(self, timeout: float):
        self.timeout = timeout
        super().__init__(f"future not settled within {timeout:.3f}s")


@dataclasses.dataclass
class ResilienceConfig:
    """Knobs of the broker's failure policy.

    max_retries        transient whole-batch re-executions before the
                       failure is treated as persistent and bisected.
    backoff_base/cap   exponential backoff between retries:
                       ``min(base * 2**attempt, cap)`` seconds through
                       the broker's injectable ``sleep``.
    breaker_threshold  consecutive failed flushes (per bucket) that trip
                       the bucket into degraded per-lane execution.
    breaker_recovery   consecutive clean degraded flushes that close the
                       breaker again.
    quarantine_ttl     seconds a poisoned digest stays on the deny-list
                       (broker scheduling clock).
    max_pending_lanes  admission cap over all buckets; ``None`` = no cap.
    deadline_grace     slack added to deadlines before flush-time
                       shedding (0 = shed anything strictly past due).
    """

    max_retries: int = 2
    backoff_base: float = 0.01
    backoff_cap: float = 1.0
    breaker_threshold: int = 3
    breaker_recovery: int = 2
    quarantine_ttl: float = 300.0
    max_pending_lanes: Optional[int] = None
    deadline_grace: float = 0.0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.breaker_threshold < 1:
            raise ValueError("breaker_threshold must be >= 1")
        if self.breaker_recovery < 1:
            raise ValueError("breaker_recovery must be >= 1")

    def backoff(self, attempt: int) -> float:
        """Seconds to back off before retry ``attempt`` (0-based)."""
        return min(self.backoff_base * (2 ** attempt), self.backoff_cap)


class Quarantine:
    """TTL'd deny-list of poisoned query digests."""

    def __init__(self, ttl: float):
        self.ttl = ttl
        self._expiry: Dict[str, float] = {}

    def add(self, digest: str, now: float) -> None:
        self._expiry[digest] = now + self.ttl

    def check(self, digest: str, now: float) -> bool:
        """True while ``digest`` is quarantined; expired entries are
        purged on the way through."""
        exp = self._expiry.get(digest)
        if exp is None:
            return False
        if now >= exp:
            del self._expiry[digest]
            return False
        return True

    def purge(self, now: float) -> None:
        for d in [d for d, e in self._expiry.items() if now >= e]:
            del self._expiry[d]

    def __len__(self) -> int:
        return len(self._expiry)

    def digests(self) -> List[str]:
        return sorted(self._expiry)


class CircuitBreaker:
    """Per-key (bucket) consecutive-failure breaker.

    closed --[threshold consecutive failures]--> open (degraded)
    open   --[recovery consecutive clean flushes]--> closed
    """

    def __init__(self, threshold: int, recovery: int):
        self.threshold = threshold
        self.recovery = recovery
        self._failures: Dict[Tuple, int] = {}
        self._successes: Dict[Tuple, int] = {}
        self._open: Dict[Tuple, bool] = {}

    def is_open(self, key: Tuple) -> bool:
        return self._open.get(key, False)

    def record_failure(self, key: Tuple) -> bool:
        """Count one failed flush; returns True when this failure trips
        (or keeps) the breaker open."""
        self._successes[key] = 0
        n = self._failures.get(key, 0) + 1
        self._failures[key] = n
        if n >= self.threshold:
            self._open[key] = True
        return self._open.get(key, False)

    def record_success(self, key: Tuple) -> bool:
        """Count one clean flush; returns True when this success closes
        an open breaker."""
        self._failures[key] = 0
        if not self._open.get(key, False):
            return False
        n = self._successes.get(key, 0) + 1
        self._successes[key] = n
        if n >= self.recovery:
            self._open[key] = False
            self._successes[key] = 0
            return True
        return False

    def open_keys(self) -> List[Tuple]:
        return [k for k, v in self._open.items() if v]
