"""Client-side policy-search drivers over the simulation service.

The archgym-style loop: a search algorithm proposes candidate
configurations, a simulation backend scores them, the algorithm culls and
proposes again.  Here the backend is a :class:`~repro.service.SimBroker`,
so every rung of candidates lands in one shape bucket and runs as one
microbatched ``sweep_lanes`` program — and repeated evaluations (grid
refinements, halving survivors re-scored at longer horizons with the same
spec) hit the content-addressed result cache instead of the device.

Two drivers:

  * :func:`grid_search` — score every candidate on one trace, rank.
  * :func:`successive_halving` — rung 0 scores everyone on a short
    (cheap) trace spec, each following rung keeps the best ``1/eta`` and
    re-scores them on an ``eta``-times longer horizon; the classic
    multi-fidelity budget allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

from ..core.config import CostConfig, PolicyConfig, MachineConfig, \
    FIRST_TOUCH, INTERLEAVE, PT_BIND_ALL, PT_BIND_HIGH, PT_FOLLOW_DATA
from ..core.sim import Trace
from ..core.workloads import TraceSpec
from .broker import SimBroker
from .query import SimQuery
from .resilience import ServiceError

DEFAULT_SPACE: Dict[str, Sequence] = {
    "data_policy": (FIRST_TOUCH, INTERLEAVE),
    "pt_policy": (PT_FOLLOW_DATA, PT_BIND_ALL, PT_BIND_HIGH),
    "mig": (False, True),
}


def policy_grid(space: Optional[Dict[str, Sequence]] = None,
                base: Optional[PolicyConfig] = None) -> List[PolicyConfig]:
    """Cartesian product over PolicyConfig field values.

    ``space`` maps field names to candidate values (default: the paper's
    Table-3 axes); ``base`` supplies every unswept field.
    """
    space = dict(DEFAULT_SPACE if space is None else space)
    base = base if base is not None else PolicyConfig()
    grid = [{}]
    for field, values in space.items():
        grid = [dict(g, **{field: v}) for g in grid for v in values]
    return [dataclasses.replace(base, **g) for g in grid]


def grid_search(broker: SimBroker, mc: MachineConfig,
                trace: Union[Trace, TraceSpec],
                policies: Sequence[PolicyConfig],
                cc: Optional[CostConfig] = None,
                objective: str = "total_cycles",
                ) -> List[Tuple[PolicyConfig, float]]:
    """Score every policy on one trace; return (policy, objective) sorted
    ascending (lower is better — objectives are cycle/event counts).

    A candidate whose lane fails with a typed :class:`ServiceError`
    (shed deadline, poisoned, rejected by admission control) is dropped
    from the ranking and counted (``search.dropped_lanes``) instead of
    failing the whole rung — a search over N candidates survives losing
    a few.  Non-service errors still propagate."""
    cc = cc if cc is not None else CostConfig()
    tel = broker.telemetry
    queries = [SimQuery(trace=trace, policy=pc, cost=cc, machine=mc)
               for pc in policies]
    with tel.span("search.grid", args={"candidates": len(queries),
                                       "objective": objective}):
        futs = broker.submit_many(queries)
        broker.drain()
    tel.counter("search.evaluations").inc(len(queries))
    scored = []
    dropped = 0
    for pc, fut in zip(policies, futs):
        try:
            res = fut.result()
        except ServiceError:
            dropped += 1
            continue
        scored.append((pc, float(res.summary()[objective])))
    if dropped:
        tel.counter("search.dropped_lanes").inc(dropped)
    scored.sort(key=lambda t: t[1])
    return scored


def successive_halving(broker: SimBroker, mc: MachineConfig,
                       spec: TraceSpec,
                       policies: Optional[Sequence[PolicyConfig]] = None,
                       cc: Optional[CostConfig] = None,
                       rungs: int = 3, eta: int = 2,
                       objective: str = "total_cycles",
                       ) -> Dict:
    """Multi-fidelity policy search: rung r scores the survivors on
    ``spec`` with ``run_steps * eta**r`` simulated steps, then keeps the
    best ``ceil(n/eta)``.  Returns the winner plus the full history.

    The broker makes each rung one microbatch; because fidelity is part
    of the trace spec (hence the cache key), re-running the search — or
    widening it — only simulates candidates it has never seen at that
    horizon.
    """
    cands = list(policies if policies is not None else policy_grid())
    if not cands:
        raise ValueError("successive_halving needs at least one candidate")
    cc = cc if cc is not None else CostConfig()
    tel = broker.telemetry
    history = []
    for r in range(rungs):
        rung_spec = dataclasses.replace(
            spec, run_steps=spec.run_steps * eta ** r)
        with tel.span("search.rung",
                      args={"rung": r, "run_steps": rung_spec.run_steps,
                            "candidates": len(cands)}):
            scored = grid_search(broker, mc, rung_spec, cands, cc=cc,
                                 objective=objective)
        if not scored:
            raise ServiceError(
                f"successive_halving rung {r}: every candidate lane "
                "failed; nothing left to halve")
        tel.counter("search.rungs").inc()
        history.append({
            "rung": r, "run_steps": rung_spec.run_steps,
            "scores": [(pc.label(), s) for pc, s in scored],
        })
        keep = max((len(cands) + eta - 1) // eta, 1)
        cands = [pc for pc, _ in scored[:keep]]
        if len(cands) == 1 and r < rungs - 1:
            continue                      # still re-score at full fidelity
    best = cands[0]
    return {"best": best, "best_label": best.label(),
            "objective": objective, "history": history}
