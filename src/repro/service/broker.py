"""The shape-bucketed simulation-query broker.

Turns independent :class:`~repro.service.query.SimQuery` requests into a
small number of batched ``sweep_lanes`` device programs:

  admission   ``submit()`` canonicalizes the query's trace (specs build
              once and idle-pad to a power-of-two step count), computes
              its content-addressed cache key, answers repeats from the
              result cache (zero recompiles, zero device work), joins
              duplicates already in flight onto one lane, and otherwise
              enqueues the query in its *bucket*.
  bucketing   a bucket is everything that can share one compiled
              executable: (machine, fault engine, trace step count,
              AutoNUMA scan period).  The compiled AutoNUMA-budget
              bound is computed per flush as the batch maximum rounded
              up to a power of two — per-lane budgets gate through
              traced masks, so the round-up never changes results, it
              only keeps the compile key stable across bursts with
              different policy mixes.
  microbatch  a bucket flushes when it holds ``max_lanes`` lanes, when
              its oldest query has waited ``max_wait`` broker-clock
              seconds, when a member's deadline arrives (``pump``), or
              when a caller forces a future (``result()``).  Lanes are
              ordered by (priority, deadline, arrival) and the lane
              count is padded to a power of two so recurring burst sizes
              reuse one executable; pad lanes replicate lane 0 and are
              discarded.
  execution   one ``sweep_lanes`` call per flush — one lane per distinct
              query, optionally sharded over devices
              (``lane_sharding="auto"``) — then every future resolves
              and every result enters the cache.

The broker is synchronous and in-process: nothing runs until a bucket
fills, comes due inside ``pump()``/``drain()``, or a future is forced.
That keeps it deterministic (the test suite pins per-query results
bit-identical to direct sequential ``TieredMemSimulator`` runs) while
preserving the surface of an async service.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.sweep import compile_count as sweep_compile_count
from ..core.sweep import sweep_lanes
from ..core.config import MIG_POLICY_NAMES, MachineConfig
from ..core.sim import RunResult, Trace, pow2ceil as _pow2ceil
from ..core.workloads import TraceSpec
from ..obs import or_null
from .cache import ResultCache
from .query import SimFuture, SimQuery, query_cache_key, spec_cache_key


@dataclasses.dataclass
class BrokerStats:
    queries: int = 0
    cache_hits: int = 0
    inflight_joins: int = 0    # duplicate queries merged onto one lane
    flushes: int = 0
    lanes_run: int = 0         # distinct query lanes executed
    pad_lanes: int = 0         # power-of-two padding lanes (discarded)
    compiles: int = 0          # XLA compiles observed across flushes

    @property
    def pad_ratio(self) -> float:
        """Discarded padding lanes as a fraction of all executed lanes —
        the padding overhead of pow2 lane quantization."""
        run = self.lanes_run + self.pad_lanes
        return self.pad_lanes / run if run else 0.0

    def as_dict(self) -> Dict[str, float]:
        out = dataclasses.asdict(self)
        out["pad_ratio"] = self.pad_ratio
        return out

    def reset(self) -> None:
        """Zero every counter (measurement-window bookends in benchmarks
        and long-lived services)."""
        for f in dataclasses.fields(self):
            setattr(self, f.name, f.default)


def _bucket_label(bkey: Tuple) -> str:
    """Compact, label-safe bucket identity for metrics/spans (the full
    bucket key embeds a MachineConfig repr)."""
    mc, phase_b, engine, n_steps, period = bkey
    return f"{engine}/{phase_b}/t{mc.n_threads}/s{n_steps}/p{period}"


class _Pending:
    """One future lane: a distinct (machine, engine, cost, policy, trace)
    simulation plus every future waiting on it."""

    __slots__ = ("key", "trace", "query", "futures", "enqueue_t", "admit_t")

    def __init__(self, key, trace: Trace, query: SimQuery,
                 enqueue_t: float, admit_t: Optional[float] = None):
        self.key = key
        self.trace = trace
        self.query = query          # representative (first) query
        self.futures: List[SimFuture] = []
        self.enqueue_t = enqueue_t
        self.admit_t = admit_t      # tracer clock (None unless tracing)

    @property
    def priority(self) -> int:
        return max(f.query.priority for f in self.futures)

    @property
    def deadline(self) -> float:
        ds = [f.query.deadline for f in self.futures
              if f.query.deadline is not None]
        return min(ds) if ds else float("inf")


class SimBroker:
    """See module docstring.  Parameters:

    max_lanes      microbatch capacity per bucket (flush-when-full).
    max_wait       seconds a query may age in an open bucket before
                   ``pump()`` flushes it (the max-wait microbatch flush).
    lane_sharding  passed through to ``sweep_lanes`` — ``None``,
                   ``"auto"`` (shard the lane axis over local devices),
                   or an explicit 1-D ``"lanes"`` mesh.
    pad_steps_floor  smallest power-of-two step count specs are padded
                   to (raw ``Trace`` queries are never reshaped — the
                   caller owns their shape and bucket).
    cache / clock  injectable for sizing and for deterministic tests.
    telemetry      optional :class:`repro.obs.Telemetry`: per-query
                   lifecycle spans (admit → queue → flush → sweep →
                   resolve), queue-wait and flush-latency histograms,
                   per-bucket compile counters, cache and per-policy-
                   family migration counters.  Defaults to the no-op
                   sink; every hook is host-side, so compiled programs
                   and results are identical either way.  Note spans use
                   the telemetry clock, while queue-wait *metrics* use
                   the broker's injectable scheduling ``clock``.
    """

    def __init__(self, max_lanes: int = 64, max_wait: float = 0.25,
                 lane_sharding=None, pad_steps_floor: int = 64,
                 cache: Optional[ResultCache] = None, clock=time.monotonic,
                 telemetry=None):
        if max_lanes < 1:
            raise ValueError("max_lanes must be >= 1")
        self.max_lanes = max_lanes
        self.max_wait = max_wait
        self.lane_sharding = lane_sharding
        self.pad_steps_floor = pad_steps_floor
        self.cache = cache if cache is not None else ResultCache()
        self.clock = clock
        self.telemetry = or_null(telemetry)
        if telemetry is not None and hasattr(self.cache, "attach_telemetry"):
            self.cache.attach_telemetry(self.telemetry)
        self.stats = BrokerStats()
        # bucket key -> (cache key -> pending lane), insertion-ordered
        self._buckets: Dict[Tuple, Dict[Tuple, _Pending]] = {}
        self._fut_index: Dict[int, Tuple[Tuple, Tuple]] = {}

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def canonical_trace(self, q: SimQuery) -> Trace:
        """The exact trace a query simulates (what cache keys hash and
        what a differential test must run sequentially)."""
        if isinstance(q.trace, Trace):
            if q.trace.va.shape[1] != q.machine.n_threads:
                raise ValueError(
                    f"query trace has {q.trace.va.shape[1]} threads, "
                    f"machine has {q.machine.n_threads}")
            return q.trace
        spec = q.trace
        if spec.pad_to == 0:
            natural = spec.build(q.machine)       # memoized in workloads
            spec = dataclasses.replace(
                spec, pad_to=_pow2ceil(natural.n_steps,
                                       self.pad_steps_floor))
        return spec.build(q.machine)

    def _bucket_key(self, q: SimQuery, canonical: Trace) -> Tuple:
        mc: MachineConfig = q.machine
        period = int(q.policy.autonuma_period) if bool(q.policy.autonuma) \
            else 0
        return (mc, q.phase_b, q.engine, canonical.n_steps, period)

    def submit(self, q: SimQuery) -> SimFuture:
        tel = self.telemetry
        self.stats.queries += 1
        tel.counter("broker.queries").inc()
        admit_t0 = tel.now()
        fut = SimFuture(q, self)
        if isinstance(q.trace, TraceSpec):
            # recipe-addressed: a hit skips trace generation entirely
            key = spec_cache_key(q, self.pad_steps_floor)
            canonical = None
        else:
            canonical = self.canonical_trace(q)
            key = query_cache_key(q, canonical)
        hit = self.cache.get(key)
        if hit is not None:
            self.stats.cache_hits += 1
            tel.counter("broker.cache_hits").inc()
            fut._resolve(hit, from_cache=True)
            if admit_t0 is not None:
                tel.add_span("query.admit", admit_t0, tel.now(),
                             args={"cache_hit": True})
            return fut

        if canonical is None:
            canonical = self.canonical_trace(q)
        bkey = self._bucket_key(q, canonical)
        bucket = self._buckets.setdefault(bkey, {})
        pend = bucket.get(key)
        if pend is None:
            pend = _Pending(key, canonical, q, self.clock(),
                            admit_t=tel.now())
            bucket[key] = pend
        else:
            self.stats.inflight_joins += 1
            tel.counter("broker.inflight_joins").inc()
        pend.futures.append(fut)
        self._fut_index[id(fut)] = (bkey, key)
        if admit_t0 is not None:
            tel.add_span("query.admit", admit_t0, tel.now(),
                         args={"cache_hit": False,
                               "bucket": _bucket_label(bkey)})

        if len(bucket) >= self.max_lanes:
            self._flush(bkey)
        else:
            self.pump()
        return fut

    def submit_many(self, queries: Sequence[SimQuery]) -> List[SimFuture]:
        return [self.submit(q) for q in queries]

    def run(self, queries: Sequence[SimQuery]) -> List[RunResult]:
        """Submit a burst, drain every bucket, return aligned results."""
        futs = self.submit_many(queries)
        self.drain()
        return [f.result() for f in futs]

    # ------------------------------------------------------------------
    # scheduling
    # ------------------------------------------------------------------
    def _due(self, bucket: Dict[Tuple, _Pending], now: float) -> bool:
        if not bucket:
            return False
        oldest = min(p.enqueue_t for p in bucket.values())
        if now - oldest >= self.max_wait:
            return True
        return min(p.deadline for p in bucket.values()) <= now

    def pump(self, now: Optional[float] = None) -> int:
        """Flush every due bucket (max-wait age or deadline reached),
        highest-priority bucket first.  Returns the number of flushes."""
        now = self.clock() if now is None else now
        due = [bk for bk, b in self._buckets.items() if self._due(b, now)]
        due.sort(key=lambda bk: (
            -max(p.priority for p in self._buckets[bk].values()),
            min(p.enqueue_t for p in self._buckets[bk].values())))
        n = 0
        for bk in due:
            while self._buckets.get(bk):
                self._flush(bk)
                n += 1
        return n

    def drain(self) -> None:
        """Flush everything regardless of age/deadline."""
        while any(self._buckets.values()):
            for bk in list(self._buckets):
                while self._buckets.get(bk):
                    self._flush(bk)

    def pending_lanes(self) -> int:
        return sum(len(b) for b in self._buckets.values())

    def _force(self, fut: SimFuture) -> None:
        loc = self._fut_index.get(id(fut))
        if loc is None:                      # already resolved
            return
        bkey, _ = loc
        while not fut.done():
            if not self._buckets.get(bkey):
                raise RuntimeError(
                    "future's bucket vanished without resolving it")
            self._flush(bkey)

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------
    def _flush(self, bkey: Tuple) -> None:
        bucket = self._buckets.get(bkey)
        if not bucket:
            self._buckets.pop(bkey, None)
            return
        tel = self.telemetry
        blabel = _bucket_label(bkey) if tel.enabled else ""
        flush_t0 = tel.now()
        now = self.clock()
        pendings = sorted(
            bucket.values(),
            key=lambda p: (-p.priority, p.deadline, p.enqueue_t))
        batch = pendings[:self.max_lanes]
        for p in batch:
            del bucket[p.key]
        if not bucket:
            del self._buckets[bkey]
        if tel.enabled:
            qwait = tel.histogram("broker.queue_wait_seconds")
            for p in batch:
                # broker scheduling clock, matching max_wait semantics
                qwait.observe(max(now - p.enqueue_t, 0.0))
                if p.admit_t is not None and flush_t0 is not None:
                    tel.add_span("query.queue", p.admit_t, flush_t0,
                                 args={"bucket": blabel,
                                       "waiters": len(p.futures)})

        mc, phase_b, engine, _, _ = bkey
        qbudget = _pow2ceil(min(
            max(int(p.query.policy.autonuma_budget) for p in batch),
            mc.n_map))
        # The allocator conflict-group bound is trace-content-derived, so
        # letting sweep_lanes compute it per batch would mint up to
        # log2(T)+1 executables per bucket as fault profiles vary across
        # bursts.  Like the budget bound above, brokers trade the scan-
        # depth cut for compile-key stability: pin the bound at its
        # maximum (full thread depth — the pre-blocked-engine status quo
        # for fault steps; per-lane results are unaffected).
        qgroup = mc.n_threads if phase_b == "batched" else None
        ccs = [p.query.cost for p in batch]
        pcs = [p.query.policy for p in batch]
        trs = [p.trace for p in batch]
        # Lane padding replicates lane 0, which is also block-aware: a pad
        # lane adds no new trace, so the union event mask — and with it
        # the windowed shapes the blocked engine compiles for — stays
        # exactly the batch's own, and pow2 lane counts keep quantizing.
        n_pad = _pow2ceil(len(batch)) - len(batch)
        for _ in range(n_pad):
            ccs.append(batch[0].query.cost)
            pcs.append(batch[0].query.policy)
            trs.append(batch[0].trace)

        before = sweep_compile_count()
        wall_t0 = time.perf_counter()
        try:
            results = sweep_lanes(
                mc, ccs, pcs, trs, phase_b=phase_b, budget=qbudget,
                lane_sharding=self.lane_sharding, engine=engine,
                group=qgroup,
                # queries on a reference path already carried debug=True
                # (SimQuery validates); the bucket inherits it
                debug=(engine != "blocked" or phase_b != "batched"),
                telemetry=tel)
        except Exception as exc:
            # a poisoned microbatch must not strand its futures: fail the
            # whole batch (waiters raise instead of spinning) and let the
            # flusher see the error too
            for p in batch:
                for f in p.futures:
                    self._fut_index.pop(id(f), None)
                    f._fail(exc)
            tel.counter("broker.flush_failures").inc()
            raise
        compiles = sweep_compile_count() - before
        self.stats.compiles += compiles
        self.stats.flushes += 1
        self.stats.lanes_run += len(batch)
        self.stats.pad_lanes += n_pad
        if tel.enabled:
            tel.counter("broker.flushes", bucket=blabel).inc()
            tel.counter("broker.compiles", bucket=blabel).inc(compiles)
            tel.counter("broker.lanes_run", bucket=blabel).inc(len(batch))
            tel.counter("broker.pad_lanes", bucket=blabel).inc(n_pad)
            tel.histogram("broker.flush_seconds").observe(
                time.perf_counter() - wall_t0)
            tel.gauge("broker.pending_lanes").set(self.pending_lanes())

        resolve_t0 = tel.now()
        for p, res in zip(batch, results):
            self.cache.put(p.key, res)
            for f in p.futures:
                self._fut_index.pop(id(f), None)
                f._resolve(res)
        if tel.enabled:
            self._record_summaries(batch, results)
            if flush_t0 is not None:
                t1 = tel.now()
                tel.add_span("query.resolve", resolve_t0, t1,
                             args={"bucket": blabel, "lanes": len(batch)})
                tel.add_span("bucket.flush", flush_t0, t1,
                             args={"bucket": blabel, "lanes": len(batch),
                                   "pad_lanes": n_pad,
                                   "compiles": compiles})

    def _record_summaries(self, batch: Sequence[_Pending],
                          results: Sequence[RunResult]) -> None:
        """Lift per-policy-family migration totals and per-tier page
        placement out of each lane's ``RunResult.summary()`` into the
        metrics registry (telemetry-on only: summary() walks host state)."""
        tel = self.telemetry
        for p, res in zip(batch, results):
            s = res.summary()
            fam = MIG_POLICY_NAMES.get(int(p.query.policy.mig_policy),
                                       "unknown")
            tel.counter("sim.promotions", family=fam).inc(
                int(s["data_migrations"]))
            tel.counter("sim.demotions", family=fam).inc(
                int(s["demotions"]))
            tel.counter("sim.nomad_aborts", family=fam).inc(
                int(s["nomad_retries"]) + int(s["nomad_shadow_drops"]))
            for t, n in enumerate(s["data_pages_per_tier"]):
                tel.counter("sim.data_pages", tier=t).inc(int(n))
            for t, n in enumerate(s["leaf_pages_per_tier"]):
                tel.counter("sim.leaf_pages", tier=t).inc(int(n))

    def snapshot(self) -> Dict[str, object]:
        """One JSON-friendly dict of everything observable: broker stats,
        cache stats (both tiers) and the telemetry snapshot.  The blessed
        artifact payload — replaces ad-hoc ``stats.as_dict()`` readouts."""
        out = {"broker": self.stats.as_dict(),
               "pending_lanes": self.pending_lanes()}
        if hasattr(self.cache, "stats"):
            out["cache"] = self.cache.stats()
        out["telemetry"] = self.telemetry.snapshot()
        return out
