"""The shape-bucketed simulation-query broker.

Turns independent :class:`~repro.service.query.SimQuery` requests into a
small number of batched ``sweep_lanes`` device programs:

  admission   ``submit()`` canonicalizes the query's trace (specs build
              once and idle-pad to a power-of-two step count), computes
              its content-addressed cache key, answers repeats from the
              result cache (zero recompiles, zero device work), fails
              quarantined (known-poisoned) digests fast, joins
              duplicates already in flight onto one lane, enforces the
              ``max_pending_lanes`` admission cap (lowest-priority work
              is rejected with ``BrokerOverloadedError``), and otherwise
              enqueues the query in its *bucket*.
  bucketing   a bucket is everything that can share one compiled
              executable: (machine, fault engine, trace step count,
              AutoNUMA scan period).  The compiled AutoNUMA-budget
              bound is computed per flush as the batch maximum rounded
              up to a power of two — per-lane budgets gate through
              traced masks, so the round-up never changes results, it
              only keeps the compile key stable across bursts with
              different policy mixes.
  microbatch  a bucket flushes when it holds ``max_lanes`` lanes, when
              its oldest query has waited ``max_wait`` broker-clock
              seconds, when a member's deadline arrives (``pump``), or
              when a caller forces a future (``result()``).  Lanes are
              ordered by (priority, deadline, arrival) and the lane
              count is padded to a power of two so recurring burst sizes
              reuse one executable; pad lanes replicate lane 0 and are
              discarded.
  execution   one ``sweep_lanes`` call per flush — one lane per distinct
              query, optionally sharded over devices
              (``lane_sharding="auto"``) — then every future resolves
              and every result enters the cache.

Failure model (see :mod:`repro.service.resilience` for the taxonomy and
:mod:`repro.obs.inject` for the chaos harness that drives it):

  shedding    queries whose deadline already expired at flush time fail
              with ``DeadlineExceededError`` instead of being silently
              computed; fully-shed lanes never reach the device.
  retry       a failed batch execution is retried up to
              ``resilience.max_retries`` times with exponential backoff
              while the error looks transient (injected faults carry an
              explicit flag; real device errors are treated as
              retryable).
  bisection   a persistent batch failure is isolated by bisection: each
              half re-runs as a normal ``sweep_lanes`` call (pow2 lane
              padding keeps compile-key quantization intact), recursing
              into failing halves until the poisoned lane(s) stand
              alone.  Innocent lanes resolve normally; the guilty fail
              with ``PoisonedQueryError`` and their digest enters a
              TTL'd quarantine so resubmits fail fast.
  breaker     ``resilience.breaker_threshold`` consecutive failed
              flushes trip the bucket into *degraded mode* — per-lane
              ``debug=True`` execution, slow but isolating — flipping
              the ``broker.degraded`` gauge; ``breaker_recovery``
              consecutive clean degraded flushes close the breaker.
  liveness    ``pump()``/``drain()`` never propagate a flush failure:
              exceptions route to the affected futures and telemetry,
              other buckets keep flushing, and per-bucket attempt bounds
              guarantee termination even if ``_flush`` itself misbehaves
              (stranded futures are failed, never leaked).

The broker is synchronous and in-process: nothing runs until a bucket
fills, comes due inside ``pump()``/``drain()``, or a future is forced.
That keeps it deterministic (the test suite pins per-query results
bit-identical to direct sequential ``TieredMemSimulator`` runs) while
preserving the surface of an async service.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.sweep import compile_count as sweep_compile_count
from ..core.sweep import sweep_lanes
from ..core.config import MIG_POLICY_NAMES, MachineConfig
from ..core.sim import RunResult, Trace, pow2ceil as _pow2ceil
from ..core.workloads import TraceSpec
from ..obs import or_null
from ..obs.inject import or_null_injector
from .cache import ResultCache
from .query import (SimFuture, SimQuery, lane_digest, query_cache_key,
                    spec_cache_key)
from .resilience import (BrokerOverloadedError, CircuitBreaker,
                         DeadlineExceededError, PoisonedQueryError,
                         Quarantine, ResilienceConfig)


@dataclasses.dataclass
class BrokerStats:
    queries: int = 0
    cache_hits: int = 0
    inflight_joins: int = 0    # duplicate queries merged onto one lane
    flushes: int = 0
    lanes_run: int = 0         # distinct query lanes executed
    pad_lanes: int = 0         # power-of-two padding lanes (discarded)
    compiles: int = 0          # XLA compiles observed across flushes
    retries: int = 0           # transient-failure batch re-executions
    shed: int = 0              # futures failed with DeadlineExceededError
    quarantined: int = 0       # lanes poisoned and deny-listed
    rejected: int = 0          # futures failed by the admission cap

    @property
    def pad_ratio(self) -> float:
        """Discarded padding lanes as a fraction of all executed lanes —
        the padding overhead of pow2 lane quantization."""
        run = self.lanes_run + self.pad_lanes
        return self.pad_lanes / run if run else 0.0

    def as_dict(self) -> Dict[str, float]:
        out = dataclasses.asdict(self)
        out["pad_ratio"] = self.pad_ratio
        return out

    def reset(self) -> None:
        """Zero every counter (measurement-window bookends in benchmarks
        and long-lived services)."""
        for f in dataclasses.fields(self):
            setattr(self, f.name, f.default)


def _bucket_label(bkey: Tuple) -> str:
    """Compact, label-safe bucket identity for metrics/spans (the full
    bucket key embeds a MachineConfig repr)."""
    mc, phase_b, engine, n_steps, period = bkey
    return f"{engine}/{phase_b}/t{mc.n_threads}/s{n_steps}/p{period}"


class _Pending:
    """One future lane: a distinct (machine, engine, cost, policy, trace)
    simulation plus every future waiting on it."""

    __slots__ = ("key", "trace", "query", "futures", "enqueue_t", "admit_t")

    def __init__(self, key, trace: Trace, query: SimQuery,
                 enqueue_t: float, admit_t: Optional[float] = None):
        self.key = key
        self.trace = trace
        self.query = query          # representative (first) query
        self.futures: List[SimFuture] = []
        self.enqueue_t = enqueue_t
        self.admit_t = admit_t      # tracer clock (None unless tracing)

    @property
    def priority(self) -> int:
        return max(f.query.priority for f in self.futures)

    @property
    def deadline(self) -> float:
        ds = [f.query.deadline for f in self.futures
              if f.query.deadline is not None]
        return min(ds) if ds else float("inf")


class SimBroker:
    """See module docstring.  Parameters:

    max_lanes      microbatch capacity per bucket (flush-when-full).
    max_wait       seconds a query may age in an open bucket before
                   ``pump()`` flushes it (the max-wait microbatch flush).
    lane_sharding  passed through to ``sweep_lanes`` — ``None``,
                   ``"auto"`` (shard the lane axis over local devices),
                   or an explicit 1-D ``"lanes"`` mesh.
    pad_steps_floor  smallest power-of-two step count specs are padded
                   to (raw ``Trace`` queries are never reshaped — the
                   caller owns their shape and bucket).
    cache / clock  injectable for sizing and for deterministic tests.
    telemetry      optional :class:`repro.obs.Telemetry`: per-query
                   lifecycle spans (admit → queue → flush → sweep →
                   resolve), queue-wait and flush-latency histograms,
                   per-bucket compile counters, cache and per-policy-
                   family migration counters.  Defaults to the no-op
                   sink; every hook is host-side, so compiled programs
                   and results are identical either way.  Note spans use
                   the telemetry clock, while queue-wait *metrics* use
                   the broker's injectable scheduling ``clock``.
    resilience     :class:`~repro.service.resilience.ResilienceConfig`
                   (retry/backoff, breaker, quarantine TTL, admission
                   cap, deadline grace).  Defaults are production-sane.
    injector       optional :class:`~repro.obs.inject.FaultInjector`;
                   armed over the ``broker.flush`` / ``sweep.device``
                   sites here and propagated to the cache's disk sites.
                   Defaults to the no-op injector.
    flight         optional :class:`~repro.obs.FlightRecorder`: every
                   *persistent* failure — poison confirmed, breaker
                   trip, livelock abandon — dumps a postmortem artifact
                   (recent spans, metrics delta, broker state) before
                   the futures settle.  Dumps are best-effort: a
                   recorder error increments ``broker.flight_errors``
                   and never disturbs settlement.
    sleep          injectable backoff sleep (tests pass a recorder).
    """

    def __init__(self, max_lanes: int = 64, max_wait: float = 0.25,
                 lane_sharding=None, pad_steps_floor: int = 64,
                 cache: Optional[ResultCache] = None, clock=time.monotonic,
                 telemetry=None, resilience: Optional[ResilienceConfig] = None,
                 injector=None, flight=None, sleep=time.sleep):
        if max_lanes < 1:
            raise ValueError("max_lanes must be >= 1")
        self.max_lanes = max_lanes
        self.max_wait = max_wait
        self.lane_sharding = lane_sharding
        self.pad_steps_floor = pad_steps_floor
        self.cache = cache if cache is not None else ResultCache()
        self.clock = clock
        self.sleep = sleep
        self.telemetry = or_null(telemetry)
        self.injector = or_null_injector(injector)
        if telemetry is not None and hasattr(self.cache, "attach_telemetry"):
            self.cache.attach_telemetry(self.telemetry)
        if injector is not None and hasattr(self.cache, "attach_injector"):
            self.cache.attach_injector(self.injector)
        self.resilience = resilience if resilience is not None \
            else ResilienceConfig()
        self.quarantine = Quarantine(self.resilience.quarantine_ttl)
        self.breaker = CircuitBreaker(self.resilience.breaker_threshold,
                                      self.resilience.breaker_recovery)
        self.flight = flight
        self.stats = BrokerStats()
        # bucket key -> (cache key -> pending lane), insertion-ordered
        self._buckets: Dict[Tuple, Dict[Tuple, _Pending]] = {}
        self._fut_index: Dict[int, Tuple[Tuple, Tuple]] = {}
        # bucket key -> stable trace tid for its queue-wait spans (tid 0
        # is the broker's own track, tid 1 the engine's window track;
        # per-bucket tracks keep concurrent buckets' queue spans from
        # partially overlapping on one line)
        self._bucket_tids: Dict[Tuple, int] = {}

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def canonical_trace(self, q: SimQuery) -> Trace:
        """The exact trace a query simulates (what cache keys hash and
        what a differential test must run sequentially)."""
        if isinstance(q.trace, Trace):
            if q.trace.va.shape[1] != q.machine.n_threads:
                raise ValueError(
                    f"query trace has {q.trace.va.shape[1]} threads, "
                    f"machine has {q.machine.n_threads}")
            return q.trace
        spec = q.trace
        if spec.pad_to == 0:
            natural = spec.build(q.machine)       # memoized in workloads
            spec = dataclasses.replace(
                spec, pad_to=_pow2ceil(natural.n_steps,
                                       self.pad_steps_floor))
        return spec.build(q.machine)

    def query_digest(self, q: SimQuery) -> str:
        """The stable digest quarantine deny-lists and
        ``PoisonedQueryError`` carry (and the ``sweep.device`` injection
        site matches ``fail_lane`` rules against)."""
        if isinstance(q.trace, TraceSpec):
            return lane_digest(spec_cache_key(q, self.pad_steps_floor))
        return lane_digest(query_cache_key(q, self.canonical_trace(q)))

    def _bucket_key(self, q: SimQuery, canonical: Trace) -> Tuple:
        mc: MachineConfig = q.machine
        period = int(q.policy.autonuma_period) if bool(q.policy.autonuma) \
            else 0
        return (mc, q.phase_b, q.engine, canonical.n_steps, period)

    def submit(self, q: SimQuery) -> SimFuture:
        tel = self.telemetry
        self.stats.queries += 1
        tel.counter("broker.queries").inc()
        admit_t0 = tel.now()
        fut = SimFuture(q, self)
        if isinstance(q.trace, TraceSpec):
            # recipe-addressed: a hit skips trace generation entirely
            key = spec_cache_key(q, self.pad_steps_floor)
            canonical = None
        else:
            canonical = self.canonical_trace(q)
            key = query_cache_key(q, canonical)
        hit = self.cache.get(key)
        if hit is not None:
            self.stats.cache_hits += 1
            tel.counter("broker.cache_hits").inc()
            fut._resolve(hit, from_cache=True)
            if admit_t0 is not None:
                tel.add_span("query.admit", admit_t0, tel.now(),
                             args={"cache_hit": True})
            return fut

        digest = lane_digest(key)
        if self.quarantine.check(digest, self.clock()):
            # known-poisoned: fail fast instead of re-poisoning a batch
            tel.counter("broker.quarantine_rejections").inc()
            fut._fail(PoisonedQueryError(digest, quarantined=True))
            return fut

        if canonical is None:
            canonical = self.canonical_trace(q)
        bkey = self._bucket_key(q, canonical)
        pend = self._buckets.get(bkey, {}).get(key)
        if pend is None:
            if not self._admit_lane(q, fut):
                return fut                # rejected: future already failed
            # (re-)resolve the bucket only after admission: eviction may
            # have emptied and dropped this very bucket's dict
            bucket = self._buckets.setdefault(bkey, {})
            pend = _Pending(key, canonical, q, self.clock(),
                            admit_t=tel.now())
            bucket[key] = pend
        else:
            bucket = self._buckets[bkey]
            self.stats.inflight_joins += 1
            tel.counter("broker.inflight_joins").inc()
        pend.futures.append(fut)
        self._fut_index[id(fut)] = (bkey, key)
        if admit_t0 is not None:
            tel.add_span("query.admit", admit_t0, tel.now(),
                         args={"cache_hit": False,
                               "bucket": _bucket_label(bkey)})

        if len(bucket) >= self.max_lanes:
            self._flush(bkey)
        else:
            self.pump()
        return fut

    def _admit_lane(self, q: SimQuery, fut: SimFuture) -> bool:
        """``max_pending_lanes`` admission control: when the broker is at
        capacity, the lowest-priority lane loses — either the newcomer is
        rejected outright, or (when the newcomer outranks it) the lowest
        pending lane is evicted to make room.  Returns False when ``fut``
        was failed with ``BrokerOverloadedError``."""
        cap = self.resilience.max_pending_lanes
        if cap is None or self.pending_lanes() < cap:
            return True
        tel = self.telemetry
        victim_loc = None
        for bk, bucket in self._buckets.items():
            for key, p in bucket.items():
                rank = (p.priority, -p.enqueue_t)   # lowest prio, youngest
                if victim_loc is None or rank < victim_loc[0]:
                    victim_loc = (rank, bk, key)
        if victim_loc is not None and q.priority > victim_loc[0][0]:
            _, bk, key = victim_loc
            victim = self._buckets[bk].pop(key)
            if not self._buckets[bk]:
                del self._buckets[bk]
            err = BrokerOverloadedError(self.pending_lanes() + 1, cap)
            self.stats.rejected += len(victim.futures)
            tel.counter("broker.overload_rejections").inc(
                len(victim.futures))
            self._settle_lane(victim, error=err)
            return True
        self.stats.rejected += 1
        tel.counter("broker.overload_rejections").inc()
        fut._fail(BrokerOverloadedError(self.pending_lanes(), cap))
        return False

    def submit_many(self, queries: Sequence[SimQuery]) -> List[SimFuture]:
        return [self.submit(q) for q in queries]

    def run(self, queries: Sequence[SimQuery]) -> List[RunResult]:
        """Submit a burst, drain every bucket, return aligned results.

        Raises the first failed future's typed error; callers that want
        per-query errors use ``submit_many`` + ``result()``."""
        futs = self.submit_many(queries)
        self.drain()
        return [f.result() for f in futs]

    # ------------------------------------------------------------------
    # scheduling
    # ------------------------------------------------------------------
    def _due(self, bucket: Dict[Tuple, _Pending], now: float) -> bool:
        if not bucket:
            return False
        oldest = min(p.enqueue_t for p in bucket.values())
        if now - oldest >= self.max_wait:
            return True
        return min(p.deadline for p in bucket.values()) <= now

    def pump(self, now: Optional[float] = None) -> int:
        """Flush every due bucket (max-wait age or deadline reached),
        highest-priority bucket first; equal priorities tie-break by
        oldest enqueue.  Flush failures route to the affected futures —
        ``pump`` itself never raises them — and per-bucket attempt bounds
        guarantee termination.  Returns the number of flushes."""
        now = self.clock() if now is None else now
        due = [bk for bk, b in self._buckets.items() if self._due(b, now)]
        due.sort(key=lambda bk: (
            -max(p.priority for p in self._buckets[bk].values()),
            min(p.enqueue_t for p in self._buckets[bk].values())))
        n = 0
        for bk in due:
            n += self._drain_bucket(bk)
        return n

    def drain(self) -> None:
        """Flush everything regardless of age/deadline.  Survives any
        flush failure (errors route to futures + telemetry) and always
        terminates: a bucket that will not empty within its bounded
        attempts is abandoned, failing its futures."""
        while any(self._buckets.values()):
            for bk in list(self._buckets):
                self._drain_bucket(bk)

    def _drain_bucket(self, bk: Tuple) -> int:
        """Flush ``bk`` until empty; never raises, never livelocks.
        Returns the number of completed ``_flush`` passes."""
        bucket = self._buckets.get(bk)
        if not bucket:
            return 0
        # each pass retires up to max_lanes lanes; 2x + slack tolerates
        # sheds/evictions racing the count without permitting a livelock
        limit = 2 * ((len(bucket) + self.max_lanes - 1)
                     // self.max_lanes) + 2
        flushes = 0
        last_exc: Optional[BaseException] = None
        for _ in range(limit):
            if not self._buckets.get(bk):
                return flushes
            try:
                self._flush(bk)
                flushes += 1
            except Exception as exc:  # noqa: BLE001 — route, don't raise
                last_exc = exc
                self.telemetry.counter("broker.flush_errors").inc()
        if self._buckets.get(bk):
            self._abandon_bucket(bk, last_exc)
        return flushes

    def _abandon_bucket(self, bk: Tuple, cause: Optional[BaseException]) \
            -> None:
        """Last-resort liveness: fail every future still in ``bk`` and
        drop the bucket, so ``drain``/``pump`` terminate even when
        ``_flush`` keeps raising without retiring lanes."""
        bucket = self._buckets.pop(bk, None)
        if not bucket:
            return
        err = RuntimeError(
            f"bucket {_bucket_label(bk)} failed to flush within bounded "
            "attempts; abandoning its lanes")
        if cause is not None:
            err.__cause__ = cause
        n = 0
        for p in bucket.values():
            n += len(p.futures)
            self._settle_lane(p, error=err)
        self.telemetry.counter("broker.abandoned_futures").inc(n)
        self._flight_dump("broker.abandon", err, bucket=_bucket_label(bk))

    def pending_lanes(self) -> int:
        return sum(len(b) for b in self._buckets.values())

    def degraded_buckets(self) -> List[str]:
        """Labels of buckets currently in degraded (per-lane) mode."""
        return sorted(_bucket_label(bk) for bk in self.breaker.open_keys())

    def _force(self, fut: SimFuture, timeout: Optional[float] = None) \
            -> None:
        loc = self._fut_index.get(id(fut))
        if loc is None:                      # already resolved
            return
        bkey, _ = loc
        t0 = self.clock() if timeout is not None else None
        while not fut.done():
            if timeout is not None and self.clock() - t0 >= timeout:
                from .resilience import BrokerTimeoutError
                raise BrokerTimeoutError(timeout)
            if not self._buckets.get(bkey):
                raise RuntimeError(
                    "future's bucket vanished without resolving it")
            self._flush(bkey)

    # ------------------------------------------------------------------
    # settlement (every path that retires a future goes through here, so
    # _fut_index can never leak a stale id() key)
    # ------------------------------------------------------------------
    def _settle_future(self, fut: SimFuture, result=None, error=None) \
            -> None:
        self._fut_index.pop(id(fut), None)
        if error is not None:
            fut._fail(error)
        else:
            fut._resolve(result)

    def _settle_lane(self, pend: _Pending, result=None, error=None) -> None:
        for f in pend.futures:
            self._settle_future(f, result=result, error=error)

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------
    def _flush(self, bkey: Tuple) -> None:
        bucket = self._buckets.get(bkey)
        if not bucket:
            self._buckets.pop(bkey, None)
            return
        tel = self.telemetry
        blabel = _bucket_label(bkey) if tel.enabled else ""
        flush_t0 = tel.now()
        wall_t0 = time.perf_counter()
        now = self.clock()
        pendings = sorted(
            bucket.values(),
            key=lambda p: (-p.priority, p.deadline, p.enqueue_t))
        batch = pendings[:self.max_lanes]
        for p in batch:
            del bucket[p.key]
        if not bucket:
            del self._buckets[bkey]
        if tel.enabled:
            qwait = tel.histogram("broker.queue_wait_seconds")
            for p in batch:
                # broker scheduling clock, matching max_wait semantics
                qwait.observe(max(now - p.enqueue_t, 0.0))
                if p.admit_t is not None and flush_t0 is not None:
                    tel.add_span("query.queue", p.admit_t, flush_t0,
                                 tid=self._bucket_tid(bkey),
                                 args={"bucket": blabel,
                                       "waiters": len(p.futures)})

        live = self._shed_expired(batch, now)
        if not live:
            return                      # everything shed; nothing to run
        self.stats.flushes += 1
        if tel.enabled:
            tel.counter("broker.flushes", bucket=blabel).inc()

        if self.breaker.is_open(bkey):
            self._flush_degraded(bkey, live, blabel)
        else:
            self._flush_batched(bkey, live, blabel)

        if tel.enabled:
            tel.histogram("broker.flush_seconds").observe(
                time.perf_counter() - wall_t0)
            tel.gauge("broker.pending_lanes").set(self.pending_lanes())
            if flush_t0 is not None:
                tel.add_span("bucket.flush", flush_t0, tel.now(),
                             args={"bucket": blabel, "lanes": len(live)})

    def _shed_expired(self, batch: Sequence[_Pending], now: float) \
            -> List[_Pending]:
        """Deadline enforcement: futures strictly past due fail with
        ``DeadlineExceededError``; lanes with no live waiter left are
        dropped before any device work."""
        grace = self.resilience.deadline_grace
        tel = self.telemetry
        live: List[_Pending] = []
        for p in batch:
            keep: List[SimFuture] = []
            for f in p.futures:
                dl = f.query.deadline
                if dl is not None and dl + grace < now:
                    self.stats.shed += 1
                    tel.counter("broker.deadline_shed").inc()
                    self._settle_future(
                        f, error=DeadlineExceededError(dl, now))
                else:
                    keep.append(f)
            p.futures = keep
            if keep:
                live.append(p)
        return live

    def _flush_batched(self, bkey: Tuple, live: List[_Pending],
                       blabel: str) -> None:
        """The normal path: one batched execution with bounded transient
        retries; a persistent failure trips the breaker and bisects."""
        try:
            results = self._run_with_retries(bkey, live, blabel)
        except Exception as exc:  # noqa: BLE001 — typed handling below
            was_open = self.breaker.is_open(bkey)
            self.breaker.record_failure(bkey)
            self._update_degraded_gauge()
            if not was_open and self.breaker.is_open(bkey):
                self._flight_dump("broker.breaker", exc, bucket=blabel)
            if len(live) == 1:
                self._poison(live[0], exc)
            else:
                mid = (len(live) + 1) // 2
                self._bisect(bkey, live[:mid], blabel)
                self._bisect(bkey, live[mid:], blabel)
            return
        self.breaker.record_success(bkey)
        self._resolve_batch(live, results, blabel)

    def _flush_degraded(self, bkey: Tuple, live: List[_Pending],
                        blabel: str) -> None:
        """Degraded (breaker-open) mode: every lane runs solo with
        ``debug=True`` — slow, but a failure can only take down its own
        lane.  A fully clean pass counts toward breaker recovery."""
        tel = self.telemetry
        tel.counter("broker.degraded_flushes", bucket=blabel).inc()
        clean = True
        for p in live:
            try:
                res = self._run_with_retries(bkey, [p], blabel,
                                             degraded=True)[0]
            except Exception as exc:  # noqa: BLE001
                clean = False
                self._poison(p, exc)
                continue
            self._resolve_batch([p], [res], blabel)
        if clean:
            self.breaker.record_success(bkey)
        else:
            self.breaker.record_failure(bkey)
        self._update_degraded_gauge()

    def _run_with_retries(self, bkey: Tuple, pendings: List[_Pending],
                          blabel: str, degraded: bool = False) \
            -> List[RunResult]:
        """Execute one lane group, retrying transient failures with
        exponential backoff.  Raises the final error when the failure is
        persistent or the retry budget is exhausted."""
        rs = self.resilience
        tel = self.telemetry
        attempt = 0
        while True:
            try:
                self.injector.fire("broker.flush", bucket=blabel)
                return self._run_lanes(bkey, pendings, blabel,
                                       degraded=degraded)
            except Exception as exc:  # noqa: BLE001 — classified below
                tel.counter("broker.flush_failures").inc()
                # injected faults carry an explicit transience flag; real
                # device errors default to retryable
                transient = getattr(exc, "transient", True)
                if not transient or attempt >= rs.max_retries:
                    raise
                delay = rs.backoff(attempt)
                tel.histogram("broker.backoff_seconds").observe(delay)
                self.sleep(delay)
                attempt += 1
                self.stats.retries += 1
                tel.counter("broker.retries").inc()

    def _bisect(self, bkey: Tuple, pendings: List[_Pending],
                blabel: str) -> None:
        """Poison-lane isolation: run the group once as a normal
        ``sweep_lanes`` call; on failure split it, recursing log2-deep
        until single lanes fail alone and are quarantined.  Innocent
        lanes resolve with results bit-identical to a fault-free run."""
        self.telemetry.counter("broker.bisect_runs").inc()
        try:
            results = self._run_lanes(bkey, pendings, blabel)
        except Exception as exc:  # noqa: BLE001
            self.telemetry.counter("broker.flush_failures").inc()
            if len(pendings) == 1:
                self._poison(pendings[0], exc)
                return
            mid = (len(pendings) + 1) // 2
            self._bisect(bkey, pendings[:mid], blabel)
            self._bisect(bkey, pendings[mid:], blabel)
            return
        self._resolve_batch(pendings, results, blabel)

    def _poison(self, pend: _Pending, cause: BaseException) -> None:
        digest = lane_digest(pend.key)
        self.quarantine.add(digest, self.clock())
        self.stats.quarantined += 1
        self.telemetry.counter("broker.quarantined").inc()
        err = PoisonedQueryError(digest, cause=cause)
        self._settle_lane(pend, error=err)
        self._flight_dump("broker.poison", err)

    def _bucket_tid(self, bkey: Tuple) -> int:
        tid = self._bucket_tids.get(bkey)
        if tid is None:
            tid = self._bucket_tids[bkey] = 2 + len(self._bucket_tids)
        return tid

    def _flight_dump(self, site: str, error: BaseException, **extra) -> None:
        """Best-effort postmortem on a persistent failure.  Never raises:
        the black box must not be able to crash the plane."""
        if self.flight is None:
            return
        try:
            state: Dict[str, object] = {
                "stats": self.stats.as_dict(),
                "pending_lanes": self.pending_lanes(),
                "quarantine": self.quarantine.digests(),
                "degraded_buckets": self.degraded_buckets()}
            if self.injector.rules:
                state["faults"] = self.injector.stats()
            state.update(extra)
            self.flight.dump(site, error=error, state=state)
        except Exception:  # noqa: BLE001 — observability stays best-effort
            self.telemetry.counter("broker.flight_errors").inc()

    def _update_degraded_gauge(self) -> None:
        self.telemetry.gauge("broker.degraded").set(
            1 if self.breaker.open_keys() else 0)

    def _run_lanes(self, bkey: Tuple, pendings: List[_Pending],
                   blabel: str, degraded: bool = False) -> List[RunResult]:
        """One ``sweep_lanes`` execution over ``pendings`` (pow2 lane
        padding as always, so compile-key quantization holds for full
        batches and bisection halves alike).  Fires the ``sweep.device``
        injection site with the group's lane digests."""
        tel = self.telemetry
        mc, phase_b, engine, _, _ = bkey
        qbudget = _pow2ceil(min(
            max(int(p.query.policy.autonuma_budget) for p in pendings),
            mc.n_map))
        # The allocator conflict-group bound is trace-content-derived, so
        # letting sweep_lanes compute it per batch would mint up to
        # log2(T)+1 executables per bucket as fault profiles vary across
        # bursts.  Like the budget bound above, brokers trade the scan-
        # depth cut for compile-key stability: pin the bound at its
        # maximum (full thread depth — the pre-blocked-engine status quo
        # for fault steps; per-lane results are unaffected).
        qgroup = mc.n_threads if phase_b == "batched" else None
        ccs = [p.query.cost for p in pendings]
        pcs = [p.query.policy for p in pendings]
        trs = [p.trace for p in pendings]
        # Lane padding replicates lane 0, which is also block-aware: a pad
        # lane adds no new trace, so the union event mask — and with it
        # the windowed shapes the blocked engine compiles for — stays
        # exactly the batch's own, and pow2 lane counts keep quantizing.
        n_pad = _pow2ceil(len(pendings)) - len(pendings)
        for _ in range(n_pad):
            ccs.append(pendings[0].query.cost)
            pcs.append(pendings[0].query.policy)
            trs.append(pendings[0].trace)

        self.injector.fire("sweep.device", bucket=blabel,
                           lanes=[lane_digest(p.key) for p in pendings])
        before = sweep_compile_count()
        results = sweep_lanes(
            mc, ccs, pcs, trs, phase_b=phase_b, budget=qbudget,
            lane_sharding=self.lane_sharding, engine=engine,
            group=qgroup,
            # queries on a reference path already carried debug=True
            # (SimQuery validates); degraded mode always isolates with it
            debug=(degraded or engine != "blocked" or phase_b != "batched"),
            telemetry=tel)
        compiles = sweep_compile_count() - before
        self.stats.compiles += compiles
        self.stats.lanes_run += len(pendings)
        self.stats.pad_lanes += n_pad
        if tel.enabled:
            tel.counter("broker.compiles", bucket=blabel).inc(compiles)
            tel.counter("broker.lanes_run", bucket=blabel).inc(len(pendings))
            tel.counter("broker.pad_lanes", bucket=blabel).inc(n_pad)
        return results[:len(pendings)]

    def _resolve_batch(self, pendings: Sequence[_Pending],
                       results: Sequence[RunResult], blabel: str) -> None:
        tel = self.telemetry
        resolve_t0 = tel.now()
        for p, res in zip(pendings, results):
            self.cache.put(p.key, res)
            self._settle_lane(p, result=res)
        if tel.enabled:
            self._record_summaries(pendings, results)
            if resolve_t0 is not None:
                tel.add_span("query.resolve", resolve_t0, tel.now(),
                             args={"bucket": blabel,
                                   "lanes": len(pendings)})

    def _record_summaries(self, batch: Sequence[_Pending],
                          results: Sequence[RunResult]) -> None:
        """Lift per-policy-family migration totals and per-tier page
        placement out of each lane's ``RunResult.summary()`` into the
        metrics registry (telemetry-on only: summary() walks host state)."""
        tel = self.telemetry
        for p, res in zip(batch, results):
            s = res.summary()
            fam = MIG_POLICY_NAMES.get(int(p.query.policy.mig_policy),
                                       "unknown")
            tel.counter("sim.promotions", family=fam).inc(
                int(s["data_migrations"]))
            tel.counter("sim.demotions", family=fam).inc(
                int(s["demotions"]))
            tel.counter("sim.nomad_aborts", family=fam).inc(
                int(s["nomad_retries"]) + int(s["nomad_shadow_drops"]))
            for t, n in enumerate(s["data_pages_per_tier"]):
                tel.counter("sim.data_pages", tier=t).inc(int(n))
            for t, n in enumerate(s["leaf_pages_per_tier"]):
                tel.counter("sim.leaf_pages", tier=t).inc(int(n))

    def snapshot(self) -> Dict[str, object]:
        """One JSON-friendly dict of everything observable: broker stats,
        cache stats (both tiers), resilience state (quarantine size,
        degraded buckets) and the telemetry snapshot.  The blessed
        artifact payload — replaces ad-hoc ``stats.as_dict()`` readouts."""
        out = {"broker": self.stats.as_dict(),
               "pending_lanes": self.pending_lanes(),
               "quarantine": {"size": len(self.quarantine),
                              "digests": self.quarantine.digests()},
               "degraded_buckets": self.degraded_buckets()}
        if hasattr(self.cache, "stats"):
            out["cache"] = self.cache.stats()
        if self.injector.rules:
            out["faults"] = self.injector.stats()
        out["telemetry"] = self.telemetry.snapshot()
        return out
