"""Sharded checkpointing with atomic manifests, async save, and elastic
restore.

Layout:  <dir>/step_<N>/
            manifest.json       tree structure, shapes, dtypes, step
            <leaf-path>.npy     one file per pytree leaf

Writes go to ``step_<N>.tmp`` and are renamed only after the manifest is
fsynced — a crashed save can never be mistaken for a complete checkpoint
(``latest_step`` only considers directories with a manifest).  Restore
takes a target sharding tree, so a checkpoint written on one mesh reloads
onto a different mesh/DP degree (elastic rescale) — arrays are saved
unsharded (gathered) at this scale; a per-host-shard format is the
documented path for >1-host pods.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree, *, blocking: bool = True
         ) -> threading.Thread:
    """Atomic checkpoint write; pass blocking=False for async save."""
    base = Path(ckpt_dir)
    final = base / f"step_{step}"
    tmp = base / f"step_{step}.tmp"
    flat = {k: np.asarray(jax.device_get(v)) for k, v in
            _flatten(tree).items()}

    def write():
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": {}}
        for key, arr in flat.items():
            fname = key.replace(_SEP, "__") + ".npy"
            dtype = str(arr.dtype)
            if dtype == "bfloat16":      # numpy can't serialize ml_dtypes
                np.save(tmp / fname, arr.view(np.uint16))
            else:
                np.save(tmp / fname, arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape), "dtype": dtype}
        mpath = tmp / "manifest.json"
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)

    t = threading.Thread(target=write, daemon=True)
    t.start()
    if blocking:
        t.join()
    return t


def latest_step(ckpt_dir: str) -> Optional[int]:
    base = Path(ckpt_dir)
    if not base.exists():
        return None
    steps = []
    for d in base.iterdir():
        if d.is_dir() and d.name.startswith("step_") \
                and not d.name.endswith(".tmp") \
                and (d / "manifest.json").exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, example_tree, shardings=None):
    """Load into the structure of ``example_tree``; if ``shardings`` is
    given, each leaf is device_put with its (possibly new-mesh) sharding —
    this is the elastic-rescale path."""
    final = Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((final / "manifest.json").read_text())
    flat_keys = list(_flatten(example_tree))
    missing = [k for k in flat_keys if k not in manifest["leaves"]]
    if missing:
        raise ValueError(f"checkpoint missing leaves: {missing[:5]}")
    leaves, treedef = jax.tree_util.tree_flatten(example_tree)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    out = []
    for (path, leaf), key in zip(
            jax.tree_util.tree_flatten_with_path(example_tree)[0],
            flat_keys):
        info = manifest["leaves"][key]
        arr = np.load(final / info["file"])
        if info["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        if key in flat_sh:
            arr = jax.device_put(arr, flat_sh[key])
        out.append(arr)
    return treedef.unflatten(out)
