"""Access-trace generators for the paper's workload suite (Table 2).

Each generator emits a :class:`~repro.core.sim.Trace` — a ``[steps, threads]``
array of 4-KiB virtual page numbers plus phase metadata.  Footprints are
scaled down from the paper's 600 GB–1 TB (Table 2) but keep the ratios that
drive the results: RSS ≈ 2× DRAM capacity, hot sets ≫ TLB reach, page-level
access patterns matching each application:

  kv_store   Memcached/Redis: sequential heap growth during populate with
             interleaved reads of the growing hash table, then YCSB-zipfian
             (theta=0.99) reads over value pages scattered by a hash
             permutation.
  btree      root/inner/leaf traversal: one lookup = 4 dependent accesses
             through exponentially growing regions (index lookups, [2]).
  hashjoin   build (populate) + uniform random probes ([3]).
  xsbench    uniform random reads of large cross-section tables + a small
             hot index region ([34]).
  bfs        frontier traversal: sequential neighbor runs with power-law
             jump targets (Ligra rMAT, [33]).

All randomness is drawn from a seeded ``numpy.random.Generator`` — traces
are plain input data, so the JAX/oracle equivalence is unaffected.

Traces are also *spec-addressable*: a :class:`TraceSpec` names a generator,
its parameters and an optional idle-pad length, builds deterministically
for a given machine, and hashes stably — the simulation service
(``repro.service``) keys admission buckets and its result cache on these
digests, so two queries naming the same workload share one generation
pass, one fault-schedule pass, and one cache line.  :func:`trace_digest`
gives the matching content hash for ad-hoc ``Trace`` objects.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
from typing import Optional, Tuple

import numpy as np

from .config import MachineConfig
from .sim import Trace, pad_trace




def _zipf_cdf(n: int, theta: float) -> np.ndarray:
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = 1.0 / ranks ** theta
    return np.cumsum(w) / np.sum(w)


def _zipf_sample(rng, cdf: np.ndarray, size) -> np.ndarray:
    u = rng.random(size)
    return np.searchsorted(cdf, u).astype(np.int32)


def _populate_rows(rng, footprint: int, T: int, read_mix: float,
                   page_perm: Optional[np.ndarray] = None):
    """Sequential heap growth: thread t faults pages [t*S, (t+1)*S) in order,
    with ``read_mix`` of its steps replaced by reads of already-touched pages
    (hash-table updates during inserts).  Returns (va, is_write)."""
    shard = footprint // T
    steps = shard + int(shard * read_mix)
    va = np.full((steps, T), -1, np.int32)
    wr = np.zeros((steps, T), bool)
    for t in range(T):
        base = t * shard
        seq = np.arange(shard, dtype=np.int32) + base
        n_reads = steps - shard
        read_pos = rng.choice(steps, size=n_reads, replace=False) if n_reads else \
            np.empty((0,), np.int64)
        is_read = np.zeros(steps, bool)
        is_read[read_pos] = True
        col = np.empty(steps, np.int32)
        col[~is_read] = seq
        # reads target a uniformly random already-populated page of this shard
        prog = np.maximum(np.cumsum(~is_read) - 1, 0)
        col[is_read] = base + (rng.random(steps) * np.maximum(prog, 1)
                               ).astype(np.int32)[is_read] % shard
        va[:, t] = col
        wr[:, t] = ~is_read
    if page_perm is not None:
        va = page_perm[va]
    return va, wr


def _finish(mc: MachineConfig, va, wr, name, llc_pop, llc_run,
            populate_steps, seg_of_map=None) -> Trace:
    steps = va.shape[0]
    llc = np.full((steps,), llc_run, np.float32)
    llc[:populate_steps] = llc_pop
    if seg_of_map is None:
        seg_of_map = np.zeros((mc.n_map,), np.int32)
    return Trace(va=va.astype(np.int32), is_write=wr,
                 free_seg=np.full((steps,), -1, np.int32),
                 llc=llc, seg_of_map=seg_of_map, name=name,
                 populate_steps=populate_steps)


def kv_store(mc: MachineConfig, footprint: int, run_steps: int,
             seed: int = 0, theta: float = 0.99, write_frac: float = 0.0,
             name: str = "kv_store") -> Trace:
    """Memcached/Redis under YCSB: populate then zipfian reads."""
    rng = np.random.default_rng(seed)
    T = mc.n_threads
    footprint = min(footprint, mc.va_pages) // T * T
    # hash scatter: hot items land on random pages across the heap
    perm = rng.permutation(footprint).astype(np.int32)
    pva, pwr = _populate_rows(rng, footprint, T, read_mix=0.5)
    cdf = _zipf_cdf(footprint, theta)
    rva = perm[_zipf_sample(rng, cdf, (run_steps, T))]
    rwr = rng.random((run_steps, T)) < write_frac
    va = np.concatenate([pva, rva])
    wr = np.concatenate([pwr, rwr])
    return _finish(mc, va, wr, name, 0.45, 0.50, pva.shape[0])


def hashjoin(mc: MachineConfig, footprint: int, run_steps: int,
             seed: int = 1, name: str = "hashjoin") -> Trace:
    rng = np.random.default_rng(seed)
    T = mc.n_threads
    footprint = min(footprint, mc.va_pages) // T * T
    pva, pwr = _populate_rows(rng, footprint, T, read_mix=0.25)
    rva = rng.integers(0, footprint, (run_steps, T), dtype=np.int32)
    rwr = np.zeros((run_steps, T), bool)
    va = np.concatenate([pva, rva])
    wr = np.concatenate([pwr, rwr])
    return _finish(mc, va, wr, name, 0.35, 0.15, pva.shape[0])


def xsbench(mc: MachineConfig, footprint: int, run_steps: int,
            seed: int = 2, name: str = "xsbench") -> Trace:
    rng = np.random.default_rng(seed)
    T = mc.n_threads
    footprint = min(footprint, mc.va_pages) // T * T
    pva, pwr = _populate_rows(rng, footprint, T, read_mix=0.1)
    hot = max(footprint // 64, 1)           # unionized-energy-grid index
    r = rng.random((run_steps, T))
    idx_hot = rng.integers(0, hot, (run_steps, T), dtype=np.int32)
    idx_cold = rng.integers(hot, footprint, (run_steps, T), dtype=np.int32)
    rva = np.where(r < 0.2, idx_hot, idx_cold).astype(np.int32)
    va = np.concatenate([pva, rva])
    wr = np.concatenate([pwr, np.zeros((run_steps, T), bool)])
    return _finish(mc, va, wr, name, 0.30, 0.10, pva.shape[0])


def btree(mc: MachineConfig, footprint: int, run_steps: int,
          seed: int = 3, name: str = "btree") -> Trace:
    """Index lookups: each lookup walks root -> inner -> inner -> leaf
    regions (region sizes grow ~64x per level, mirroring node fanout)."""
    rng = np.random.default_rng(seed)
    T = mc.n_threads
    footprint = min(footprint, mc.va_pages) // T * T
    pva, pwr = _populate_rows(rng, footprint, T, read_mix=0.0)
    r0 = max(footprint // 32768, 1)
    r1 = max(footprint // 512, 1)
    r2 = max(footprint // 16, 1)
    lookups = run_steps // 4
    lv0 = rng.integers(0, r0, (lookups, T), dtype=np.int32)
    lv1 = r0 + rng.integers(0, r1, (lookups, T), dtype=np.int32)
    lv2 = r0 + r1 + rng.integers(0, r2, (lookups, T), dtype=np.int32)
    lv3 = rng.integers(r0 + r1 + r2, footprint, (lookups, T), dtype=np.int32)
    rva = np.stack([lv0, lv1, lv2, lv3], axis=1).reshape(lookups * 4, T)
    va = np.concatenate([pva, rva])
    wr = np.concatenate([pwr, np.zeros((rva.shape[0], T), bool)])
    return _finish(mc, va, wr, name, 0.40, 0.35, pva.shape[0])


def bfs(mc: MachineConfig, footprint: int, run_steps: int,
        seed: int = 4, run_len: int = 8, name: str = "bfs") -> Trace:
    """Graph traversal: sequential neighbor-list runs with power-law jumps."""
    rng = np.random.default_rng(seed)
    T = mc.n_threads
    footprint = min(footprint, mc.va_pages) // T * T
    pva, pwr = _populate_rows(rng, footprint, T, read_mix=0.0)
    n_jumps = run_steps // run_len + 1
    cdf = _zipf_cdf(footprint, 0.6)
    starts = _zipf_sample(rng, cdf, (n_jumps, T))
    offs = np.arange(run_len, dtype=np.int32)[None, :, None]
    rva = ((starts[:, None, :] + offs) % footprint).reshape(-1, T)[:run_steps]
    va = np.concatenate([pva, rva.astype(np.int32)])
    wr = np.concatenate([pwr, np.zeros((rva.shape[0], T), bool)])
    return _finish(mc, va, wr, name, 0.35, 0.25, pva.shape[0])


ALL_WORKLOADS = {
    "memcached": lambda mc, fp, rs, seed=0, **kw: kv_store(
        mc, fp, rs, seed=seed, name="memcached", **kw),
    "redis": lambda mc, fp, rs, seed=10, **kw: kv_store(
        mc, fp, rs, seed=seed, name="redis", **kw),
    "btree": btree,
    "hashjoin": hashjoin,
    "xsbench": xsbench,
    "bfs": bfs,
}


def trace_digest(tr: Trace) -> str:
    """Stable content hash of a trace (name excluded — two differently
    labelled but identical traces are the same simulation input).

    Memoized on the (immutable-by-convention) Trace object, so a burst of
    queries sharing one trace hashes its arrays once, not once per query.
    """
    cached = getattr(tr, "_content_digest", None)
    if cached is not None:
        return cached
    h = hashlib.blake2b(digest_size=16)
    for a in (np.asarray(tr.va, np.int32), np.asarray(tr.is_write, bool),
              np.asarray(tr.free_seg, np.int32),
              np.asarray(tr.llc, np.float32),
              np.asarray(tr.seg_of_map, np.int32)):
        h.update(str(a.shape).encode())
        h.update(np.ascontiguousarray(a))
    h.update(str(int(tr.populate_steps)).encode())
    digest = h.hexdigest()
    object.__setattr__(tr, "_content_digest", digest)   # frozen dataclass
    return digest


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Addressable recipe for a workload trace.

    ``build(mc)`` is deterministic, so a spec (plus the machine) fully
    identifies its trace without materializing it — service queries ship
    specs, brokers build each distinct spec once (LRU-memoized here) and
    key caches on ``digest(mc)``.

    ``workload`` names an ``ALL_WORKLOADS`` generator; ``kwargs`` carries
    extra generator keywords as a sorted tuple of pairs (hashable);
    ``pad_to`` idle-pads the built trace (0 = natural length) so specs can
    land in a shared shape bucket at build time.
    """

    workload: str
    footprint: int
    run_steps: int
    seed: Optional[int] = None          # generator default when None
    pad_to: int = 0
    kwargs: Tuple[Tuple[str, object], ...] = ()

    def __post_init__(self):
        if self.workload not in ALL_WORKLOADS:
            raise ValueError(f"unknown workload {self.workload!r}; known: "
                             f"{sorted(ALL_WORKLOADS)}")
        object.__setattr__(self, "kwargs", tuple(sorted(self.kwargs)))

    def build(self, mc: MachineConfig) -> Trace:
        key = (self, mc)
        hit = _SPEC_CACHE.get(key)
        if hit is not None:
            _SPEC_CACHE.move_to_end(key)
            return hit
        kw = dict(self.kwargs)
        if self.seed is not None:
            kw["seed"] = self.seed
        tr = ALL_WORKLOADS[self.workload](mc, self.footprint,
                                          self.run_steps, **kw)
        if self.pad_to:
            tr = pad_trace(tr, self.pad_to)
        _SPEC_CACHE[key] = tr
        while len(_SPEC_CACHE) > _SPEC_CACHE_MAX:
            _SPEC_CACHE.popitem(last=False)
        return tr

    def digest(self, mc: MachineConfig) -> str:
        """Cache key without materializing: hash of the recipe + machine
        shape knobs the generators read."""
        h = hashlib.blake2b(digest_size=16)
        h.update(repr((self.workload, self.footprint, self.run_steps,
                       self.seed, self.pad_to, self.kwargs,
                       mc)).encode())
        return h.hexdigest()


# Generated traces are FOOTPRINT-scale arrays; keep a bounded working set
# (same LRU discipline as sim._SCHED_CACHE / benchmarks.common).
_SPEC_CACHE: "collections.OrderedDict[tuple, Trace]" = \
    collections.OrderedDict()
_SPEC_CACHE_MAX = 32


def multi_tenant(mc: MachineConfig, bench: str, bench_footprint: int,
                 run_steps: int, seed: int = 7) -> Trace:
    """The paper's section 6.3 scenario.

    Segment 0 fills DRAM (fill apps), the benchmark app (segment 1) then
    populates — landing on NVMM — and runs; the fill apps exit mid-run,
    freeing DRAM and letting AutoNUMA promote the benchmark's hot data.
    """
    rng = np.random.default_rng(seed)
    T = mc.n_threads
    dram_total = 2 * mc.dram_pages_per_node
    leaf_granules = 1 << mc.radix_bits   # segment alignment: leaf boundary
    fill_pages = int(dram_total * 0.95) // leaf_granules * leaf_granules
    fill_pages = fill_pages // T * T
    bench_pages = min(bench_footprint, mc.va_pages - fill_pages)
    bench_pages = bench_pages // T * T

    seg_of_map = np.zeros((mc.n_map,), np.int32)
    seg_of_map[fill_pages:] = 1

    # phase 1: fill apps populate + touch their pages (keeps them "hot")
    fva, fwr = _populate_rows(rng, fill_pages, T, read_mix=0.3)
    # phase 2: benchmark populates its own (NVMM-bound) segment
    gen = ALL_WORKLOADS[bench]
    btr = gen(mc, bench_pages, run_steps)
    bva = np.where(btr.va >= 0, btr.va + fill_pages, -1).astype(np.int32)
    # fill apps exit once the benchmark enters its run phase
    exit_at = fva.shape[0] + btr.populate_steps + run_steps // 8

    va = np.concatenate([fva, bva])
    wr = np.concatenate([fwr, btr.is_write])
    steps = va.shape[0]
    free_seg = np.full((steps,), -1, np.int32)
    if exit_at < steps:
        free_seg[exit_at] = 0
    llc = np.concatenate([np.full((fva.shape[0],), 0.45, np.float32), btr.llc])
    return Trace(va=va, is_write=wr, free_seg=free_seg, llc=llc,
                 seg_of_map=seg_of_map, name=f"mt_{bench}",
                 populate_steps=fva.shape[0] + btr.populate_steps)
