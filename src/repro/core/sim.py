"""The vectorized tiered-memory simulator.

One ``lax.scan`` step simulates one memory access per CPU thread:

  Phase 0   process-exit events (segment frees) and the periodic AutoNUMA
            scan (+ Algorithm-1 triggers) — ``migrate.autonuma_scan``.
  Phase A   *vectorized across threads*: accesses to already-mapped pages.
            L1-TLB -> STLB -> hardware walk with PDE/PDPTE page-walk caches;
            per-level walk costs depend on the NUMA node of each PT page
            (the paper's object of study); data-access cost depends on the
            data page's node, LLC-filtered.
  Phase B   *batched over threads*: page-fault handling — PT-page and
            data-page allocation under the active policies, zeroing costs,
            PTE install, TLB fill.  Thread order remains the serialization
            order (matching zone-lock serialization in the kernel), but it
            is reproduced without a per-thread loop over the full state:

            1. Host-side, :func:`fault_schedule` extends the per-step
               fault predicate into a per-(step, thread) schedule: who
               faults, who merely waits on a page an earlier thread maps
               this step, and — via first-thread-wins masks over shared
               root/top/mid/leaf PT indices — which thread allocates each
               missing PT entry.  PT-entry conflicts are the only true
               cross-thread dependency besides the allocator counters,
               and both are trace-derivable (mapped-ness and PT-entry
               existence are policy-independent).
            2. Device-side, ``alloc.alloc_many`` serializes *only* the
               allocator counters (``node_free`` / ``node_reclaimable`` /
               ``interleave_ptr`` / the OOM latch, ~10 scalars) through a
               tiny ``lax.scan`` over threads; every heavy update — PT
               placement scatters, per-thread TLB fills, cycle and event
               counters — then commits vectorized across all threads at
               once.  The result is bit-identical (placements, counters;
               cycles to f32 rounding) to the retained sequential
               ``fori_loop`` path (``phase_b="sequential"``) and to the
               pure-Python oracle; ``tests/test_fault_batch.py`` enforces
               all three pairings.

            Under a vmapped policy sweep the old per-thread ``lax.cond``
            lowered to a select that ran the fault handler for every
            thread of every lane (~1.5x/lane on fault-dominated traces);
            the batched engine has no per-thread control flow at all.

Cycle model: ``total = cpu_work + stall (+ fault/alloc/migration overheads)``
with ``stall = walk + data_stall_frac * data`` — page walks stall the
pipeline fully (the PMH serializes translations, paper section 6.7:
``walk_active/walk_pending -> stalls_mem_any``), data misses are partially
hidden by out-of-order execution.

Policy and cost knobs enter the compiled step as *traced pytree leaves*
(``PolicyConfig``/``CostConfig`` are registered dataclasses): the step is
policy-generic and vmap-able over a leading policy axis.  ``core.sweep``
uses that to run N policies (and M same-shape traces) in ONE compiled
``lax.scan``; the sequential path here shares the same compiled artifact
across every policy of equal trace shape.  Step-schedule predicates that
must stay un-batched for ``lax.cond`` to survive vmap — "a segment frees
this step", "the AutoNUMA scan fires", "some thread faults" — are
precomputed host-side from the trace, as is the per-(step, thread) fault
schedule that drives batched phase B (see :func:`fault_schedule` /
:func:`fault_step_mask`).

Time-blocked execution (``engine="blocked"``, the default): the paper's
steady-state hot path — TLB lookups and page walks on long fault-free,
scan-free stretches — used to pay the full per-step scan machinery (big
placement/counter state threaded through every iteration, the three
``lax.cond`` dispatches, fifteen per-step timeline reductions).  The
blocked engine tiles the trace into fixed ``[block, T]`` step-windows
(window count ``ceil(S / block)`` depends only on the trace *shape*) and
host-classifies each window from the schedule's exact event rows
(:func:`plan_windows`): event-free windows run as ONE outer-scan step
through :func:`_build_fast_window` — only the genuinely sequential
state (the four TLB/PWC arrays, per-thread cycle accumulators, three
hit counters) threads through a tiny inner scan while placement
gathers, Bernoulli draws and cost terms are precomputed vectorized over
the whole tile; a window whose only event is a single AutoNUMA/TPP scan
tick runs as fast-prefix -> hoisted scan op -> fast-suffix with *zero*
per-step rows (so a ``period=512, block=64`` cadence no longer demotes
one window in eight to per-step replay); a window with a narrow event
span runs fast prefix/suffix around a per-step replay of just the span;
only wide spans replay the whole window per-step.  Segment capacities
are quantized to per-class pow2 maxima and folded into the compile key
(``WindowPlan.geom``) with live lengths as traced data, so compiled
programs keep quantizing across trace contents — the property the
service broker's shape buckets rely on — and an all-fast program
compiles no per-step body at all.  Every branch replays the per-step
f32 expression tree in the per-step order, so the blocked engine is
**bit-identical** to the retained per-step path (``engine="per_step"``)
— cycles included, not just to rounding — which ``tests/test_blocked.py``
asserts exactly.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import alloc as alloc_mod
from . import migrate as migrate_mod
from . import tlbs
from ..obs import or_null
from .config import (CostConfig, MachineConfig, PolicyConfig, INTERLEAVE,
                     PT_BIND_HIGH, PT_FOLLOW_DATA)
from .state import SimState, init_state, is_dram

I32 = jnp.int32
F32 = jnp.float32
U32 = jnp.uint32

_MIX = (np.uint32(0x9E3779B1), np.uint32(0x85EBCA77), np.uint32(0xC2B2AE3D),
        np.uint32(0x27D4EB2F))


def bern(p, site: int, *keys) -> jax.Array:
    """Deterministic Bernoulli(p) from a multiplicative hash of the keys.

    ``p`` may be a traced scalar.  Replicated bit-for-bit by ``core.ref``
    (python ints masked to 32 bits).
    """
    h = jnp.asarray(np.uint32((0x811C9DC5 + 0x1000193 * site) & 0xFFFFFFFF), U32)
    for i, k in enumerate(keys):
        h = (h ^ jnp.asarray(k).astype(U32)) * _MIX[i % 4]
    h = (h >> 8) & jnp.asarray(np.uint32(0xFFFFFF), U32)
    thr = (jnp.asarray(p, F32) * (1 << 24)).astype(U32)
    return h < thr


@dataclasses.dataclass(frozen=True)
class Trace:
    """A pregenerated access trace (host-side numpy).

    va[s, t]     4-KiB virtual page accessed by thread t at step s (-1 idle)
    is_write     same shape
    free_seg[s]  segment id whose pages are freed at the start of step s (-1)
    llc[s]       data-access LLC hit probability at step s (phase-dependent)
    seg_of_map   segment id per mapping granule (for frees)
    """

    va: np.ndarray
    is_write: np.ndarray
    free_seg: np.ndarray
    llc: np.ndarray
    seg_of_map: np.ndarray
    name: str = "trace"
    populate_steps: int = 0      # steps belonging to the populate/startup phase

    @property
    def n_steps(self) -> int:
        return self.va.shape[0]


def pad_trace(tr: Trace, n_steps: int) -> Trace:
    """Idle-pad a trace to ``n_steps`` so policy sweeps share one compile."""
    cur = tr.n_steps
    if cur >= n_steps:
        return tr
    pad = n_steps - cur
    return dataclasses.replace(
        tr,
        va=np.concatenate([tr.va, np.full((pad, tr.va.shape[1]), -1, np.int32)]),
        is_write=np.concatenate([tr.is_write,
                                 np.zeros((pad, tr.va.shape[1]), bool)]),
        free_seg=np.concatenate([tr.free_seg, np.full((pad,), -1, np.int32)]),
        llc=np.concatenate([tr.llc, np.zeros((pad,), np.float32)]))


# fault_schedule bit layout (uint8 per (step, thread)):
#   DO      thread touches a page unmapped at step start (fault or wait)
#   WINNER  first DO-thread for its mapping granule -> runs the real fault
#   NEED_*  winner is the first to touch that missing PT entry -> allocates
SCHED_DO = np.uint8(1)
SCHED_WINNER = np.uint8(2)
SCHED_NEED_ROOT = np.uint8(4)
SCHED_NEED_TOP = np.uint8(8)
SCHED_NEED_MID = np.uint8(16)
SCHED_NEED_LEAF = np.uint8(32)

# Digest-keyed, LRU-bounded: the whole benchmark suite holds well under
# the cap, while long-lived processes sweeping many generated traces
# (property tests, trace-content grids) don't accumulate schedules forever.
_SCHED_CACHE: "collections.OrderedDict[Tuple, np.ndarray]" = \
    collections.OrderedDict()
_SCHED_CACHE_MAX = 64


def fault_schedule(tr: Trace, mc: MachineConfig) -> np.ndarray:
    """uint8[steps, threads]: the per-(step, thread) fault schedule.

    Mapped-ness and PT-entry *existence* are policy-independent (placement
    differs across policies, existence does not), so the whole conflict
    structure of phase B is derivable from the trace alone: which threads
    fault, which of them wins each shared mapping granule, and which
    winner allocates each missing root/top/mid/leaf PT entry
    (first-thread-wins, the serialization order of the kernel's zone
    lock).  Like :func:`fault_step_mask` — whose per-step predicate is
    just ``(schedule & SCHED_DO).any(axis=1)`` — this stays un-batched
    under a vmapped policy sweep.

    The batched engine consumes the DO/WINNER bits (masked by phase A's
    live miss set); the NEED bits document the host model's PT-entry
    conflict resolution and anchor its tests, while the engine recomputes
    those first-winner masks from live placement state, which stays exact
    even for a resumed pre-populated state (where a cross-segment free
    may have orphaned a leaf the host model cannot see).

    The host model assumes allocations succeed; past a lane's OOM point
    the bits over-approximate, and the device gates every request on its
    per-thread OOM latch (``alloc_many``'s ``gate``), under which the
    lane is inert anyway.  Results are memoized on a digest of the trace
    contents — figures sharing padded traces pay the host pass once.
    """
    shift, n_map, rb = mc.map_shift, mc.n_map, mc.radix_bits
    n_leaf, n_mid, n_top = mc.n_leaf_pages, mc.n_mid_pages, mc.n_top_pages
    va = np.asarray(tr.va)
    seg = np.asarray(tr.seg_of_map)
    free_seg = np.asarray(tr.free_seg)
    h = hashlib.blake2b(digest_size=16)
    for a in (va, free_seg, seg):
        h.update(np.ascontiguousarray(a))
    key = (h.digest(), va.shape, shift, n_map, rb, n_leaf, n_mid, n_top)
    hit = _SCHED_CACHE.get(key)
    if hit is not None:
        _SCHED_CACHE.move_to_end(key)
        return hit

    leaf_first = (np.arange(n_leaf, dtype=np.int64) << rb) % max(n_map, 1)
    seg_of_leaf = seg[leaf_first]
    mapped = np.zeros(n_map, bool)
    exists = {  # PT-entry existence per level (mid/top/root are never freed)
        "root": np.zeros(1, bool), "top": np.zeros(n_top, bool),
        "mid": np.zeros(n_mid, bool), "leaf": np.zeros(n_leaf, bool),
    }
    S, T = va.shape
    sched = np.zeros((S, T), np.uint8)
    for s in range(S):
        if free_seg[s] >= 0:
            mapped[seg == free_seg[s]] = False
            exists["leaf"][seg_of_leaf == free_seg[s]] = False
        row = va[s]
        act = row >= 0
        if not act.any():
            continue
        m = np.clip(row.astype(np.int64) >> shift, 0, n_map - 1)
        do = act & ~mapped[m]
        if not do.any():
            continue
        sched[s] |= np.where(do, SCHED_DO, np.uint8(0))
        do_t = np.where(do)[0]                       # ascending thread order
        _, first = np.unique(m[do_t], return_index=True)
        wt = np.sort(do_t[first])                    # first thread per granule
        sched[s, wt] |= SCHED_WINNER
        mw = m[wt]
        levels = (
            (SCHED_NEED_ROOT, "root", np.zeros(len(wt), np.int64)),
            (SCHED_NEED_TOP, "top", np.clip(mw >> (3 * rb), 0, n_top - 1)),
            (SCHED_NEED_MID, "mid", np.clip(mw >> (2 * rb), 0, n_mid - 1)),
            (SCHED_NEED_LEAF, "leaf", mw >> rb),
        )
        for bit, lvl, e in levels:
            miss = ~exists[lvl][e]
            if not miss.any():
                continue
            em, tm = e[miss], wt[miss]
            uniq, fidx = np.unique(em, return_index=True)
            sched[s, tm[fidx]] |= bit
            exists[lvl][uniq] = True
        mapped[mw] = True
    _SCHED_CACHE[key] = sched
    while len(_SCHED_CACHE) > _SCHED_CACHE_MAX:
        _SCHED_CACHE.popitem(last=False)
    return sched


def fault_step_mask(tr: Trace, mc: MachineConfig) -> np.ndarray:
    """bool[steps]: does ANY thread touch an unmapped page at step s?

    Drives the un-batched ``lax.cond`` that skips phase B entirely on
    fault-free steps even when the step is vmapped over policies.  For a
    simulation resumed from a pre-populated state this is an
    over-approximation (phase B runs and no-ops), never an
    under-approximation.
    """
    return np.asarray((fault_schedule(tr, mc) & SCHED_DO) > 0).any(axis=1)


def scan_step_mask(n_steps: int, period: int, enabled: bool = True,
                   start_step: int = 0) -> np.ndarray:
    """bool[steps]: does the periodic AutoNUMA scan fire at step s?"""
    s = np.arange(start_step, start_step + n_steps)
    return (s > 0) & (s % max(int(period), 1) == 0) & bool(enabled)


def pow2ceil(n: int, floor: int = 1) -> int:
    """Smallest power of two >= max(n, floor)."""
    p = max(int(floor), 1)
    while p < n:
        p <<= 1
    return p


# Step-window size of the time-blocked engine.  Fixed per compile; the
# window count ceil(S / block) depends only on the trace shape, never its
# content, so executables keep quantizing across trace mixes.
DEFAULT_BLOCK = 64


def fault_group_bound(sched: np.ndarray) -> int:
    """Max winners (allocating threads) in any single step of a schedule.

    This bounds the conflict-group count of ``alloc.alloc_many``'s
    serialized allocator scan: every thread that touches the allocator in
    a step carries the WINNER bit, and threads without requests commute
    with everything, so the per-step scan depth collapses from
    ``n_threads`` to this bound (each group = one allocating thread plus
    the non-allocating threads behind it).  Device-side winners are a
    subset of the host bits (resume masking), so the bound is safe for
    resumed states too.
    """
    if sched.size == 0:
        return 1
    w = (sched & SCHED_WINNER) > 0
    return max(int(w.sum(axis=1).max()), 1)


@dataclasses.dataclass
class RunResult:
    final_state: SimState          # host-side pytree of numpy arrays
    timeline: Dict[str, np.ndarray]
    trace_name: str
    policy_label: str

    def summary(self) -> Dict[str, float]:
        st = self.final_state
        cyc = st.cycles
        # Migration-daemon cycles were already spread into per-thread totals
        # inside the step function; ``migration_cycles`` is informational.
        total = float(np.sum(cyc.total))
        runtime = float(np.max(cyc.total))
        walk = float(np.sum(cyc.walk))
        stall = float(np.sum(cyc.stall))
        c = st.counters
        leaf_nodes = np.asarray(st.leaf_node)
        alive = leaf_nodes >= 0
        data = np.asarray(st.data_node)
        return {
            "runtime_cycles": runtime,
            "total_cycles": total,
            "walk_cycles": walk,
            "stall_cycles": stall,
            "data_mem_cycles": float(np.sum(cyc.data_mem)),
            "fault_cycles": float(np.sum(cyc.fault)),
            "migration_cycles": float(cyc.migration),
            "walk_share": walk / max(total, 1.0),
            "l1_hits": int(c.l1_hits), "stlb_hits": int(c.stlb_hits),
            "walks": int(c.walks), "walk_mem_reads": int(c.walk_mem_reads),
            "faults": int(c.faults),
            "slow_allocs": int(c.slow_allocs),
            "data_migrations": int(c.data_migrations),
            "demotions": int(c.demotions),
            "l4_mig_success": int(c.l4_mig_success),
            "l4_mig_already_dest": int(c.l4_mig_already_dest),
            "l4_mig_in_dram": int(c.l4_mig_in_dram),
            "l4_mig_sibling_guard": int(c.l4_mig_sibling_guard),
            "l4_mig_lock_skip": int(c.l4_mig_lock_skip),
            "oom_killed": bool(st.oom_killed), "oom_step": int(st.oom_step),
            "leaf_pages_dram": int(np.sum(alive & (leaf_nodes < 2))),
            "leaf_pages_nvmm": int(np.sum(alive & (leaf_nodes >= 2))),
            "data_pages_dram": int(np.sum((data >= 0) & (data < 2))),
            "data_pages_nvmm": int(np.sum(data >= 2)),
            # N-tier / policy-family extensions (tier t owns nodes 2t,
            # 2t+1; on the 2-tier machine the per-tier lists reduce to the
            # dram/nvmm pairs above).
            "data_pages_per_tier": [
                int(np.sum((data >= 2 * t) & (data < 2 * t + 2)))
                for t in range(np.asarray(st.node_free).shape[0] // 2)],
            "leaf_pages_per_tier": [
                int(np.sum(alive & (leaf_nodes >= 2 * t)
                           & (leaf_nodes < 2 * t + 2)))
                for t in range(np.asarray(st.node_free).shape[0] // 2)],
            "shadow_pages": int(np.sum(np.asarray(st.shadow_node) >= 0)),
            "nomad_retries": int(c.nomad_retries),
            "nomad_flip_demotions": int(c.nomad_flip_demotions),
            "nomad_shadow_drops": int(c.nomad_shadow_drops),
        }


_RUN_CACHE: Dict[Tuple, object] = {}

TIMELINE_KEYS = ("total_cycles", "walk_cycles", "stall_cycles", "faults",
                 "dram_free", "leaf_nvmm", "leaf_dram", "walks",
                 "data_migrations", "l4_mig_success", "migration_cycles",
                 "data_mem_cycles", "fault_cycles", "l1_hits", "stlb_hits")


def _build_scan_op(mc: MachineConfig, budget: int):
    """Build the standalone migration scan-tick operator.

    One AutoNUMA/TPP/Nomad periodic scan plus its cycle accounting,
    factored out of the per-step body so the blocked engine's *hoist*
    windows can run it between two fast segments without compiling any
    per-step machinery.  ``autonuma_scan`` self-gates on
    ``pc.autonuma & ~oom_killed``, so a shared schedule can fire for
    every lane of a mixed sweep.  The tick step's access row rides along
    as Nomad's concurrent-write abort condition (a no-op input for the
    other families).  The f32 accounting order is exactly the per-step
    path's, keeping hoisted ticks bit-identical to replayed ones.
    """
    T = mc.n_threads
    wm = alloc_mod.watermark_pages(mc)

    def scan_op(st: SimState, cc: CostConfig, pc: PolicyConfig,
                va_row, w_row) -> SimState:
        s2, cost = migrate_mod.autonuma_scan(st, mc, cc, pc, wm, budget,
                                             va_row, w_row)
        cyc = dataclasses.replace(
            s2.cycles,
            total=s2.cycles.total
            + cost * jnp.asarray(cc.mig_cost_scale, F32) / T,
            migration=s2.cycles.migration + cost)
        return dataclasses.replace(s2, cycles=cyc)

    return scan_op


def _build_step(mc: MachineConfig, budget: int, phase_b: str = "batched",
                group: Optional[int] = None):
    """Build the policy-generic simulator step.

    Only MachineConfig shapes, the AutoNUMA candidate bound ``budget``,
    the ``phase_b`` engine choice and the allocator conflict-group bound
    ``group`` are baked into the compile; every CostConfig/PolicyConfig
    value arrives per call as a traced leaf of the ``cc``/``pc`` pytrees.
    One compiled step therefore serves every policy bundle — and vmaps
    over a leading policy axis for batched sweeps (``core.sweep``).

    ``phase_b="batched"`` (default) uses the conflict-aware vectorized
    fault engine; ``"sequential"`` keeps the historical per-thread
    ``fori_loop``, retained as the differential-testing reference.

    ``group`` (``fault_group_bound``, power-of-two-quantized by callers
    so compile keys stay stable) caps the number of allocating threads
    per step and lets ``alloc.alloc_many`` compact its serialized
    allocator scan from ``n_threads`` to that many conflict-group slots;
    ``None`` keeps the full-depth scan.
    """
    assert phase_b in ("batched", "sequential"), phase_b
    T = mc.n_threads
    shift = mc.map_shift
    n_map = mc.n_map
    rb = mc.radix_bits
    nn = mc.n_nodes
    thp = mc.page_order > 0
    wm = alloc_mod.watermark_pages(mc)
    # tier per node, indexed node+1 (node -1 -> slowest tier): one gather
    # replaces the classic is_dram() select and generalizes to N tiers
    # with identical f32 latency bits on the 2-tier machine.
    text = jnp.asarray((mc.n_tiers - 1,) + mc.tier_of_node, I32)

    def f32(v):
        return jnp.asarray(v, F32)

    def read_lat(cc, node):
        return jnp.take(migrate_mod.tier_read_lat(cc, mc),
                        jnp.take(text, node + 1))

    def write_lat(cc, node):
        return jnp.take(migrate_mod.tier_write_lat(cc, mc),
                        jnp.take(text, node + 1))

    # ------------------------------ phase A --------------------------------
    def phase_a(st: SimState, cc: CostConfig, va_row, w_row, llc_rate):
        m = jnp.clip(jnp.where(va_row >= 0, va_row >> shift, 0), 0, n_map - 1)
        tid = jnp.arange(T, dtype=I32)
        mapped = jnp.take(st.data_node, m) >= 0
        active = (va_row >= 0) & ~st.oom_killed
        vec = active & mapped
        now = st.step

        hit1, way1 = tlbs.lookup(st.l1_tlb, m)
        hit2, way2 = tlbs.lookup(st.stlb, m)
        walkn = vec & ~hit1 & ~hit2

        leaf_id, mid_id = m >> rb, m >> (2 * rb)
        top_id = m >> (3 * rb)
        pde_hit, pde_way = tlbs.lookup(st.pde_pwc, leaf_id)
        pdpte_hit, pdpte_way = tlbs.lookup(st.pdpte_pwc, mid_id)

        leaf_n = jnp.take(st.leaf_node, leaf_id)
        mid_n = jnp.take(st.mid_node, jnp.clip(mid_id, 0, st.mid_node.shape[0] - 1))
        top_n = jnp.take(st.top_node, jnp.clip(top_id, 0, st.top_node.shape[0] - 1))

        leaf_llc = bern(cc.leaf_llc_hit, 1, m, now, tid)
        up1_llc = bern(cc.upper_llc_hit, 2, mid_id, now, tid)
        up2_llc = bern(cc.upper_llc_hit, 3, top_id, now, tid)

        leaf_read = jnp.where(leaf_llc, f32(cc.llc_hit), read_lat(cc, leaf_n))
        mid_read = jnp.where(pde_hit, 0.0,
                             jnp.where(up1_llc, f32(cc.llc_hit),
                                       read_lat(cc, mid_n)))
        full = ~pde_hit & ~pdpte_hit
        if thp:
            top_read = jnp.zeros((T,), F32)
        else:
            top_read = jnp.where(full,
                                 jnp.where(up2_llc, f32(cc.llc_hit),
                                           read_lat(cc, top_n)), 0.0)
        root_read = jnp.where(full, f32(cc.llc_hit), 0.0)
        walk_cost = jnp.where(walkn, leaf_read + mid_read + top_read + root_read, 0.0)
        walk_reads = jnp.where(
            walkn,
            (~leaf_llc).astype(I32) + (~pde_hit & ~up1_llc).astype(I32)
            + ((full & ~up2_llc).astype(I32) if not thp else 0),
            0)

        data_n = jnp.take(st.data_node, m)
        data_llc = bern(llc_rate, 4, m, now, tid)
        mem_lat = jnp.where(w_row, write_lat(cc, data_n), read_lat(cc, data_n))
        data_cost = jnp.where(vec, jnp.where(data_llc, f32(cc.llc_hit),
                                             mem_lat), 0.0)

        tlb_penalty = jnp.where(vec & ~hit1, f32(cc.stlb_hit), 0.0)
        stall = walk_cost + f32(cc.data_stall_frac) * data_cost
        total = jnp.where(vec, f32(cc.cpu_work), 0.0) + tlb_penalty + stall

        l1_tlb = tlbs.update(st.l1_tlb, m, way1, now, vec)
        stlb = tlbs.update(st.stlb, m, way2, now, vec & ~hit1)
        pde = tlbs.update(st.pde_pwc, leaf_id, pde_way, now, walkn)
        pdpte = tlbs.update(st.pdpte_pwc, mid_id, pdpte_way, now, walkn)

        access_recent = st.access_recent.at[
            jnp.where(vec, m, n_map)].add(1, mode="drop")
        written_recent = st.written_recent.at[
            jnp.where(vec & w_row, m, n_map)].add(1, mode="drop")

        cyc = st.cycles
        cyc = dataclasses.replace(
            cyc, total=cyc.total + total, walk=cyc.walk + walk_cost,
            stall=cyc.stall + stall, data_mem=cyc.data_mem + data_cost)
        c = st.counters
        c = dataclasses.replace(
            c,
            l1_hits=c.l1_hits + jnp.sum((vec & hit1).astype(I32)),
            stlb_hits=c.stlb_hits + jnp.sum((vec & ~hit1 & hit2).astype(I32)),
            walks=c.walks + jnp.sum(walkn.astype(I32)),
            walk_mem_reads=c.walk_mem_reads + jnp.sum(walk_reads))
        st = dataclasses.replace(st, l1_tlb=l1_tlb, stlb=stlb, pde_pwc=pde,
                                 pdpte_pwc=pdpte, access_recent=access_recent,
                                 written_recent=written_recent,
                                 cycles=cyc, counters=c)
        return st, active & ~mapped

    # ------------------------------ phase B --------------------------------
    def _alloc_pt_level(st: SimState, cc: CostConfig, pc: PolicyConfig, t,
                        node_arr, idx, is_upper: bool, cost_acc):
        missing = node_arr[idx] < 0
        # recompute per allocation: the interleave cursor advances with
        # every page handed out (PT pages consume round-robin slots too,
        # paper section 3.2 / Fig. 5)
        data_prefs = alloc_mod.data_prefs_for(pc.data_policy, t, mc,
                                              st.interleave_ptr)
        prefs, ignore_wm = alloc_mod.pt_prefs_for(
            pc.pt_policy, is_upper, t, mc, data_prefs, thp)
        node, slow, nf, nr, ok = alloc_mod.alloc_one(
            st.node_free, st.node_reclaimable, prefs, wm, ignore_wm)
        if is_upper or thp:
            # BHi falls back to the data policy when DRAM is exhausted.
            # Both allocations are computed and the fallback selected per
            # (possibly vmapped) lane so the branch stays traced.
            node2, slow2, nf2, nr2, ok2 = alloc_mod.alloc_one(
                st.node_free, st.node_reclaimable, data_prefs, wm,
                jnp.asarray(False))
            is_bhi = jnp.asarray(pc.pt_policy) == PT_BIND_HIGH
            use_fb = is_bhi & ~ok
            node = jnp.where(use_fb, node2, node)
            slow = jnp.where(use_fb, slow2, slow)
            nf = jnp.where(use_fb, nf2, nf)
            nr = jnp.where(use_fb, nr2, nr)
            ok = ok | (is_bhi & ok2)
        oom = missing & ~ok            # bind_all pathology (section 3.5)
        do = missing & ok
        node_arr = node_arr.at[idx].set(jnp.where(do, node, node_arr[idx]))
        zero_cost = jnp.where(do, cc.zero_lines * write_lat(cc, node), 0.0)
        acost = jnp.where(do, jnp.where(slow, f32(cc.alloc_slow),
                                        f32(cc.alloc_fast)), 0.0)
        adv = do & (jnp.asarray(pc.pt_policy) == PT_FOLLOW_DATA) \
            & (jnp.asarray(pc.data_policy) == INTERLEAVE)
        st = dataclasses.replace(
            st,
            node_free=jnp.where(do, nf, st.node_free),
            node_reclaimable=jnp.where(do, nr, st.node_reclaimable),
            interleave_ptr=st.interleave_ptr + adv.astype(I32),
            oom_killed=st.oom_killed | oom,
            oom_step=jnp.where(oom & (st.oom_step < 0), st.step, st.oom_step),
            counters=dataclasses.replace(
                st.counters,
                pt_allocs=st.counters.pt_allocs.at[
                    jnp.clip(node, 0, nn - 1)].add(jnp.where(do, 1, 0)),
                slow_allocs=st.counters.slow_allocs + jnp.where(do & slow, 1, 0),
                oom_kills=st.counters.oom_kills + oom.astype(I32)))
        cost_acc = cost_acc + zero_cost + acost + jnp.where(
            oom, f32(cc.oom_scan), 0.0)
        return st, node_arr, cost_acc

    def phase_b_body(t, carry):
        st, cc, pc, va_row, w_row, fault_mask = carry
        va_t = va_row[t]
        m = jnp.clip(jnp.where(va_t >= 0, va_t >> shift, 0), 0, n_map - 1)
        do = fault_mask[t] & ~st.oom_killed
        now = st.step

        now_mapped = st.data_node[m] >= 0
        wait = do & now_mapped
        fault = do & ~now_mapped
        wait_cost = jnp.where(wait, cc.fault_base + f32(cc.llc_hit), 0.0)

        tI = jnp.asarray(t, I32)

        def run_fault(st):
            c = jnp.zeros((), F32)
            st2, root, c = _alloc_pt_level(st, cc, pc, tI, st.root_node, 0,
                                           True, c)
            st2 = dataclasses.replace(st2, root_node=root)
            st2, top, c = _alloc_pt_level(
                st2, cc, pc, tI, st2.top_node,
                jnp.clip(m >> (3 * rb), 0, st2.top_node.shape[0] - 1), True, c)
            st2 = dataclasses.replace(st2, top_node=top)
            st2, mid, c = _alloc_pt_level(
                st2, cc, pc, tI, st2.mid_node,
                jnp.clip(m >> (2 * rb), 0, st2.mid_node.shape[0] - 1), True, c)
            st2 = dataclasses.replace(st2, mid_node=mid)
            st2, leaf, c = _alloc_pt_level(st2, cc, pc, tI, st2.leaf_node,
                                           m >> rb, False, c)
            st2 = dataclasses.replace(st2, leaf_node=leaf)

            dprefs = alloc_mod.data_prefs_for(
                pc.data_policy, tI, mc, st2.interleave_ptr)
            node, slow, nf, nr, ok = alloc_mod.alloc_one(
                st2.node_free, st2.node_reclaimable, dprefs, wm,
                jnp.asarray(False))
            oom = ~ok
            data_node = st2.data_node.at[m].set(jnp.where(ok, node, -1))
            ldc = st2.leaf_dram_children.at[m >> rb].add(
                jnp.where(ok & is_dram(node), 1, 0))
            adv = (jnp.asarray(pc.data_policy) == INTERLEAVE) & ok
            c = c + jnp.where(ok, cc.zero_lines * write_lat(cc, node)
                              + jnp.where(slow, f32(cc.alloc_slow),
                                          f32(cc.alloc_fast)),
                              f32(cc.oom_scan))
            mid_n = st2.mid_node[jnp.clip(m >> (2 * rb), 0, st2.mid_node.shape[0] - 1)]
            leaf_n = st2.leaf_node[m >> rb]
            c = c + cc.fault_base + read_lat(cc, mid_n) + write_lat(cc, leaf_n)
            st2 = dataclasses.replace(
                st2, data_node=data_node, leaf_dram_children=ldc,
                node_free=jnp.where(ok, nf, st2.node_free),
                node_reclaimable=jnp.where(ok, nr, st2.node_reclaimable),
                interleave_ptr=st2.interleave_ptr + adv.astype(I32),
                oom_killed=st2.oom_killed | oom,
                oom_step=jnp.where(oom & (st2.oom_step < 0), st2.step,
                                   st2.oom_step),
                counters=dataclasses.replace(
                    st2.counters,
                    data_allocs=st2.counters.data_allocs.at[
                        jnp.clip(node, 0, nn - 1)].add(jnp.where(ok, 1, 0)),
                    faults=st2.counters.faults + 1,
                    oom_kills=st2.counters.oom_kills + oom.astype(I32)))
            return st2, c

        st, fcost = jax.lax.cond(fault, run_fault,
                                 lambda s: (s, jnp.zeros((), F32)), st)

        handled = wait | fault
        l1 = tlbs.update_one(st.l1_tlb, tI, m, now, handled)
        stlb_ = tlbs.update_one(st.stlb, tI, m, now, handled)
        pde = tlbs.update_one(st.pde_pwc, tI, m >> rb, now, handled)
        pdpte = tlbs.update_one(st.pdpte_pwc, tI, m >> (2 * rb), now, handled)
        access_recent = st.access_recent.at[m].add(jnp.where(handled, 1, 0))
        written_recent = st.written_recent.at[m].add(
            jnp.where(handled & w_row[t], 1, 0))

        all_cost = fcost + wait_cost
        cyc = st.cycles
        cyc = dataclasses.replace(
            cyc,
            total=cyc.total.at[t].add(all_cost),
            fault=cyc.fault.at[t].add(all_cost),
            data_mem=cyc.data_mem.at[t].add(jnp.where(wait, f32(cc.llc_hit),
                                                      0.0)))
        st = dataclasses.replace(st, l1_tlb=l1, stlb=stlb_, pde_pwc=pde,
                                 pdpte_pwc=pdpte, access_recent=access_recent,
                                 written_recent=written_recent, cycles=cyc)
        return st, cc, pc, va_row, w_row, fault_mask

    # ------------------------- phase B, batched ------------------------------
    def phase_b_batched(st: SimState, cc: CostConfig, pc: PolicyConfig,
                        va_row, w_row, sched_row, fault_mask):
        """Conflict-aware vectorized fault engine.

        Host-precomputed first-thread-wins masks (``sched_row``) resolve
        threads faulting the same PT entry or data page; ``alloc_many``
        serializes the allocator counters through a tiny scan; everything
        else — PT placement scatters, TLB fills, cycle/event accounting —
        commits vectorized.  Bit-identical to ``phase_b_body`` run over
        threads in index order (cycles to f32 rounding).

        For a simulation resumed from a pre-populated state the host DO /
        WINNER bits over-approximate (the schedule starts from an empty
        address space) and are masked by phase A's actual miss set —
        host-mapped is always a subset of device-mapped, so the masked
        winner set is exactly the sequential fault set.  The per-PT-entry
        first-winner masks are *not* taken from the host NEED bits here:
        a resumed state can hold a truly-missing leaf whose host bit was
        latched onto a masked-off winner (a cross-segment free can clear
        a leaf while a sibling granule's data page stays mapped), so they
        are recomputed from live state — a scatter-min of thread ids over
        each (small) PT-level array, which is cheap next to the n_map
        commits below and exact in every case.
        """
        m = jnp.clip(jnp.where(va_row >= 0, va_row >> shift, 0), 0, n_map - 1)
        do = ((sched_row & SCHED_DO) > 0) & fault_mask
        winner = ((sched_row & SCHED_WINNER) > 0) & fault_mask
        now = st.step
        tid = jnp.arange(T, dtype=I32)

        top_idx = jnp.clip(m >> (3 * rb), 0, st.top_node.shape[0] - 1)
        mid_idx = jnp.clip(m >> (2 * rb), 0, st.mid_node.shape[0] - 1)
        leaf_idx = m >> rb
        pt_idx = (jnp.zeros((T,), I32), top_idx, mid_idx, leaf_idx)
        pt_arrs = (st.root_node, st.top_node, st.mid_node, st.leaf_node)
        need_cols = []
        for lvl in range(4):
            idx = pt_idx[lvl]
            n_e = pt_arrs[lvl].shape[0]
            cand = winner & (pt_arrs[lvl][idx] < 0)
            first = jnp.full((n_e,), T, I32).at[
                jnp.where(cand, idx, n_e)].min(tid, mode="drop")
            need_cols.append(cand & (first[idx] == tid))
        need_pt = jnp.stack(need_cols, axis=-1)                 # bool[T, 4]

        # Conflict-group compaction of the allocator scan: only host
        # WINNER threads ever touch the allocator carry (everyone else is
        # the identity and commutes), so the serialized scan runs over
        # ``group`` winner slots instead of all T threads.  Slot ids are
        # the host schedule's winner prefix count; device-side winners
        # (masked by phase A on resume) are a subset of the host bits, so
        # every requesting thread owns a slot.
        if group is not None:
            host_w = (sched_row & SCHED_WINNER) > 0
            slot = jnp.cumsum(host_w.astype(I32)) - 1
            slot_thread = jnp.full((group,), T, I32).at[
                jnp.where(host_w & (slot < group), slot, group)].set(
                    tid, mode="drop")
        else:
            slot_thread = None
        nodes, slow, ok, act, gate, nfree, nrec, ptr, oom = \
            alloc_mod.alloc_many(st.node_free, st.node_reclaimable,
                                 st.interleave_ptr, st.oom_killed, wm,
                                 pc.data_policy, pc.pt_policy, mc,
                                 need_pt, winner, slot_thread=slot_thread)
        fault = winner & gate          # threads that run the fault handler
        wait = do & ~winner & gate     # an earlier thread mapped m this step
        handled = wait | fault

        # ---- commit PT placements (one first-winner per entry: no scatter
        # conflicts) and the data pages ----------------------------------
        commit = act & ok
        new_pt = []
        for lvl, arr in enumerate((st.root_node, st.top_node, st.mid_node,
                                   st.leaf_node)):
            oob = jnp.asarray(arr.shape[0], pt_idx[lvl].dtype)
            new_pt.append(arr.at[
                jnp.where(commit[:, lvl], pt_idx[lvl], oob)].set(
                    nodes[:, lvl], mode="drop"))
        root_node, top_node, mid_node, leaf_node = new_pt

        node_d, ok_d = nodes[:, 4], ok[:, 4]
        commit_d = commit[:, 4]
        data_node = st.data_node.at[
            jnp.where(commit_d, m, n_map)].set(node_d, mode="drop")
        ldc = st.leaf_dram_children.at[leaf_idx].add(
            jnp.where(commit_d & is_dram(node_d), 1, 0))

        # ---- cost model: replicate the sequential per-thread f32 chains ----
        c = jnp.zeros((T,), F32)
        for lvl in range(4):
            do_l = commit[:, lvl]
            zero_cost = jnp.where(do_l,
                                  cc.zero_lines * write_lat(cc, nodes[:, lvl]),
                                  0.0)
            acost = jnp.where(do_l, jnp.where(slow[:, lvl], f32(cc.alloc_slow),
                                              f32(cc.alloc_fast)), 0.0)
            c = c + zero_cost + acost + jnp.where(act[:, lvl] & ~ok[:, lvl],
                                                  f32(cc.oom_scan), 0.0)
        c = c + jnp.where(ok_d,
                          cc.zero_lines * write_lat(cc, node_d)
                          + jnp.where(slow[:, 4], f32(cc.alloc_slow),
                                      f32(cc.alloc_fast)),
                          f32(cc.oom_scan))
        mid_n = mid_node[mid_idx]      # post-commit == value the thread saw
        leaf_n = leaf_node[leaf_idx]
        c = c + cc.fault_base + read_lat(cc, mid_n) + write_lat(cc, leaf_n)
        fcost = jnp.where(fault, c, 0.0)
        wait_cost = jnp.where(wait, cc.fault_base + f32(cc.llc_hit), 0.0)
        all_cost = fcost + wait_cost

        # ---- TLB fills: thread-private structures, so the per-thread
        # touch-or-insert vectorizes directly -----------------------------
        _, way1 = tlbs.lookup(st.l1_tlb, m)
        l1 = tlbs.update(st.l1_tlb, m, way1, now, handled)
        _, way2 = tlbs.lookup(st.stlb, m)
        stlb_ = tlbs.update(st.stlb, m, way2, now, handled)
        _, way3 = tlbs.lookup(st.pde_pwc, m >> rb)
        pde = tlbs.update(st.pde_pwc, m >> rb, way3, now, handled)
        _, way4 = tlbs.lookup(st.pdpte_pwc, m >> (2 * rb))
        pdpte = tlbs.update(st.pdpte_pwc, m >> (2 * rb), way4, now, handled)
        access_recent = st.access_recent.at[
            jnp.where(handled, m, n_map)].add(1, mode="drop")
        written_recent = st.written_recent.at[
            jnp.where(handled & w_row, m, n_map)].add(1, mode="drop")

        # ---- counters and OOM latch -------------------------------------
        fails = act & ~ok
        any_fail = jnp.any(fails)
        pt_commit = commit[:, :4]
        cnt = st.counters
        cnt = dataclasses.replace(
            cnt,
            pt_allocs=cnt.pt_allocs.at[
                jnp.clip(nodes[:, :4], 0, nn - 1).ravel()].add(
                    pt_commit.ravel().astype(I32)),
            data_allocs=cnt.data_allocs.at[jnp.clip(node_d, 0, nn - 1)].add(
                jnp.where(commit_d, 1, 0)),
            slow_allocs=cnt.slow_allocs
            + jnp.sum((pt_commit & slow[:, :4]).astype(I32)),
            faults=cnt.faults + jnp.sum(fault.astype(I32)),
            oom_kills=cnt.oom_kills + jnp.sum(fails.astype(I32)))
        cyc = st.cycles
        cyc = dataclasses.replace(
            cyc, total=cyc.total + all_cost, fault=cyc.fault + all_cost,
            data_mem=cyc.data_mem + jnp.where(wait, f32(cc.llc_hit), 0.0))
        return dataclasses.replace(
            st, root_node=root_node, top_node=top_node, mid_node=mid_node,
            leaf_node=leaf_node, data_node=data_node,
            leaf_dram_children=ldc, node_free=nfree, node_reclaimable=nrec,
            interleave_ptr=ptr, oom_killed=oom,
            oom_step=jnp.where(any_fail & (st.oom_step < 0), st.step,
                               st.oom_step),
            l1_tlb=l1, stlb=stlb_, pde_pwc=pde, pdpte_pwc=pdpte,
            access_recent=access_recent, written_recent=written_recent,
            cycles=cyc, counters=cnt)

    # ------------------------------ frees -----------------------------------
    def free_segment(st: SimState, fid, seg_of_map, seg_of_leaf):
        mask_map = (seg_of_map == fid) & (st.data_node >= 0)
        freed_per_node = jnp.zeros((nn,), I32).at[
            jnp.clip(st.data_node, 0, nn - 1)].add(mask_map.astype(I32))
        freed_dram = mask_map & is_dram(st.data_node)
        ldc = st.leaf_dram_children.at[jnp.arange(n_map) >> rb].add(
            -freed_dram.astype(I32))
        data_node = jnp.where(mask_map, -1, st.data_node)
        # Nomad shadows of freed granules are released with the segment.
        mask_shadow = (seg_of_map == fid) & (st.shadow_node >= 0)
        freed_shadow = jnp.zeros((nn,), I32).at[
            jnp.clip(st.shadow_node, 0, nn - 1)].add(mask_shadow.astype(I32))
        shadow_node = jnp.where(mask_shadow, -1, st.shadow_node)
        mask_leaf = (seg_of_leaf == fid) & (st.leaf_node >= 0)
        freed_leaf = jnp.zeros((nn,), I32).at[
            jnp.clip(st.leaf_node, 0, nn - 1)].add(mask_leaf.astype(I32))
        leaf_node = jnp.where(mask_leaf, -1, st.leaf_node)
        l1 = tlbs.invalidate_matching(st.l1_tlb, mask_map, 0)
        stlb_ = tlbs.invalidate_matching(st.stlb, mask_map, 0)
        pde = tlbs.invalidate_matching(st.pde_pwc, mask_leaf, 0)
        return dataclasses.replace(
            st, data_node=data_node, leaf_node=leaf_node,
            shadow_node=shadow_node,
            leaf_dram_children=jnp.maximum(ldc, 0),
            node_free=st.node_free + freed_per_node + freed_leaf
            + freed_shadow,
            access_recent=jnp.where(mask_map, 0, st.access_recent),
            written_recent=jnp.where(mask_map, 0, st.written_recent),
            l1_tlb=l1, stlb=stlb_, pde_pwc=pde)

    # ------------------------------ full step --------------------------------
    # The three schedule predicates (do_free / do_scan / has_fault) arrive
    # precomputed from the trace so they stay un-batched under vmap and the
    # lax.conds keep actually skipping work in a batched policy sweep; the
    # per-thread fault schedule row (``sched_row``, fault_schedule bits)
    # rides along as ordinary masked data.
    scan_op = _build_scan_op(mc, budget)

    def step(st: SimState, cc: CostConfig, pc: PolicyConfig, x,
             seg_of_map, seg_of_leaf):
        va_row, w_row, fid, llc_rate, sched_row, do_free, do_scan, \
            has_fault, valid = x
        st = jax.lax.cond(do_free,
                          lambda s: free_segment(s, fid, seg_of_map, seg_of_leaf),
                          lambda s: s, st)
        st = jax.lax.cond(do_scan,
                          lambda s: scan_op(s, cc, pc, va_row, w_row),
                          lambda s: s, st)

        st, fault_mask = phase_a(st, cc, va_row, w_row, llc_rate)

        if phase_b == "batched":
            def run_phase_b(st):
                return phase_b_batched(st, cc, pc, va_row, w_row, sched_row,
                                       fault_mask)
        else:
            def run_phase_b(st):
                st2, _, _, _, _, _ = jax.lax.fori_loop(
                    0, T, phase_b_body, (st, cc, pc, va_row, w_row,
                                         fault_mask))
                return st2
        # faults are bursty (populate) or rare (steady state): skip the
        # fault engine entirely on fault-free steps
        st = jax.lax.cond(has_fault, run_phase_b, lambda s: s, st)
        # idle pad rows of a time-blocked window carry valid=False and
        # must not advance the step clock (it stamps TLB LRU and bern)
        st = dataclasses.replace(
            st, step=st.step + jnp.asarray(valid).astype(I32))

        out = (jnp.sum(st.cycles.total), jnp.sum(st.cycles.walk),
               jnp.sum(st.cycles.stall), st.counters.faults,
               st.node_free[0] + st.node_free[1],
               jnp.sum((st.leaf_node >= 2).astype(I32)),
               jnp.sum(((st.leaf_node >= 0) & (st.leaf_node < 2)).astype(I32)),
               st.counters.walks, st.counters.data_migrations,
               st.counters.l4_mig_success, st.cycles.migration,
               jnp.sum(st.cycles.data_mem), jnp.sum(st.cycles.fault),
               st.counters.l1_hits, st.counters.stlb_hits)
        return st, out

    return step


def _build_fast_window(mc: MachineConfig):
    """Build the event-free-window executor of the time-blocked engine.

    Executes a ``[block, T]`` tile of steps with no segment frees, no
    AutoNUMA ticks and no faults as one scan step.  Placement arrays are
    constant across such a tile (only phase B, frees and migrations move
    them), so every gather, Bernoulli draw and latency term is
    precomputed vectorized over the whole tile; the inner ``lax.scan``
    threads only the genuinely sequential state — the four TLB/PWC
    structures (LRU contents chain step to step), the per-thread f32
    cycle accumulators and the three hit counters the timeline reports —
    and replays the per-step cost expressions in per-step order, so the
    result is bit-identical to running ``phase_a`` row by row (cycles
    included, not just to f32 rounding).

    Mapped-ness needs no check: a window is only event-free when no
    thread touches a host-unmapped page, and host-mapped is a subset of
    device-mapped (resume masking, ``fault_schedule``), so every active
    access hits a mapped page exactly as the per-step path would see it.
    """
    T = mc.n_threads
    shift = mc.map_shift
    n_map = mc.n_map
    rb = mc.radix_bits
    thp = mc.page_order > 0
    text = jnp.asarray((mc.n_tiers - 1,) + mc.tier_of_node, I32)

    def f32(v):
        return jnp.asarray(v, F32)

    def read_lat(cc, node):
        return jnp.take(migrate_mod.tier_read_lat(cc, mc),
                        jnp.take(text, node + 1))

    def write_lat(cc, node):
        return jnp.take(migrate_mod.tier_write_lat(cc, mc),
                        jnp.take(text, node + 1))

    def fast_window(st: SimState, cc: CostConfig, va_blk, wr_blk, llc_blk,
                    valid_blk):
        B = va_blk.shape[0]
        m = jnp.clip(jnp.where(va_blk >= 0, va_blk >> shift, 0), 0,
                     n_map - 1)
        tid = jnp.arange(T, dtype=I32)
        active = (va_blk >= 0) & valid_blk[:, None] & ~st.oom_killed
        now_rows = st.step + jnp.arange(B, dtype=I32)
        nowc = now_rows[:, None]

        leaf_id, mid_id = m >> rb, m >> (2 * rb)
        top_id = m >> (3 * rb)
        leaf_n = jnp.take(st.leaf_node, leaf_id)
        mid_n = jnp.take(st.mid_node,
                         jnp.clip(mid_id, 0, st.mid_node.shape[0] - 1))
        top_n = jnp.take(st.top_node,
                         jnp.clip(top_id, 0, st.top_node.shape[0] - 1))
        data_n = jnp.take(st.data_node, m)

        leaf_llc = bern(cc.leaf_llc_hit, 1, m, nowc, tid)
        up1_llc = bern(cc.upper_llc_hit, 2, mid_id, nowc, tid)
        up2_llc = bern(cc.upper_llc_hit, 3, top_id, nowc, tid)
        data_llc = bern(llc_blk[:, None], 4, m, nowc, tid)

        # Latency terms that don't depend on the TLB outcome — selected
        # (never summed) until the inner scan, so f32 bits match phase_a.
        leaf_read = jnp.where(leaf_llc, f32(cc.llc_hit),
                              read_lat(cc, leaf_n))
        mid_read_miss = jnp.where(up1_llc, f32(cc.llc_hit),
                                  read_lat(cc, mid_n))
        top_read_miss = jnp.where(up2_llc, f32(cc.llc_hit),
                                  read_lat(cc, top_n))
        mem_lat = jnp.where(wr_blk, write_lat(cc, data_n),
                            read_lat(cc, data_n))
        data_cost = jnp.where(active, jnp.where(data_llc, f32(cc.llc_hit),
                                                mem_lat), 0.0)
        zerosT = jnp.zeros((T,), F32)

        def row(carry, xr):
            (l1, stlb_c, pde, pdpte, ct, cwk, cst, cdm,
             n_l1, n_stlb, n_walk, n_wmr) = carry
            (m_r, act_r, now_s, leaf_r, mid_r, lread_r, mread_r, tread_r,
             dcost_r, leaf_llc_r, up1_r, up2_r) = xr
            hit1, way1 = tlbs.lookup(l1, m_r)
            hit2, way2 = tlbs.lookup(stlb_c, m_r)
            walkn = act_r & ~hit1 & ~hit2
            pde_hit, pde_way = tlbs.lookup(pde, leaf_r)
            pdpte_hit, pdpte_way = tlbs.lookup(pdpte, mid_r)

            mid_read = jnp.where(pde_hit, 0.0, mread_r)
            full = ~pde_hit & ~pdpte_hit
            if thp:
                top_read = zerosT
            else:
                top_read = jnp.where(full, tread_r, 0.0)
            root_read = jnp.where(full, f32(cc.llc_hit), 0.0)
            walk_cost = jnp.where(
                walkn, lread_r + mid_read + top_read + root_read, 0.0)
            walk_reads = jnp.where(
                walkn,
                (~leaf_llc_r).astype(I32) + (~pde_hit & ~up1_r).astype(I32)
                + ((full & ~up2_r).astype(I32) if not thp else 0),
                0)
            tlb_penalty = jnp.where(act_r & ~hit1, f32(cc.stlb_hit), 0.0)
            stall = walk_cost + f32(cc.data_stall_frac) * dcost_r
            total = jnp.where(act_r, f32(cc.cpu_work), 0.0) \
                + tlb_penalty + stall

            l1 = tlbs.update(l1, m_r, way1, now_s, act_r)
            stlb_c = tlbs.update(stlb_c, m_r, way2, now_s, act_r & ~hit1)
            pde = tlbs.update(pde, leaf_r, pde_way, now_s, walkn)
            pdpte = tlbs.update(pdpte, mid_r, pdpte_way, now_s, walkn)

            ct = ct + total
            cwk = cwk + walk_cost
            cst = cst + stall
            cdm = cdm + dcost_r
            n_l1 = n_l1 + jnp.sum((act_r & hit1).astype(I32))
            n_stlb = n_stlb + jnp.sum((act_r & ~hit1 & hit2).astype(I32))
            n_walk = n_walk + jnp.sum(walkn.astype(I32))
            n_wmr = n_wmr + jnp.sum(walk_reads)
            carry = (l1, stlb_c, pde, pdpte, ct, cwk, cst, cdm,
                     n_l1, n_stlb, n_walk, n_wmr)
            out = (jnp.sum(ct), jnp.sum(cwk), jnp.sum(cst), jnp.sum(cdm),
                   n_l1, n_stlb, n_walk)
            return carry, out

        cyc, cnt = st.cycles, st.counters
        carry0 = (st.l1_tlb, st.stlb, st.pde_pwc, st.pdpte_pwc,
                  cyc.total, cyc.walk, cyc.stall, cyc.data_mem,
                  cnt.l1_hits, cnt.stlb_hits, cnt.walks, cnt.walk_mem_reads)
        xs = (m, active, now_rows, leaf_id, mid_id, leaf_read,
              mid_read_miss, top_read_miss, data_cost, leaf_llc, up1_llc,
              up2_llc)
        carry, rows = jax.lax.scan(row, carry0, xs)
        (l1, stlb_c, pde, pdpte, ct, cwk, cst, cdm,
         n_l1, n_stlb, n_walk, n_wmr) = carry
        tot_r, walk_r, stall_r, dmem_r, l1_r, stlb_r, walks_r = rows

        access_recent = st.access_recent.at[
            jnp.where(active, m, n_map)].add(1, mode="drop")
        # Per-row adds commute (integer), so one whole-tile scatter equals
        # the per-step path bit-for-bit; no scan tick can observe a
        # mid-window value (event-free windows have no scans).
        written_recent = st.written_recent.at[
            jnp.where(active & wr_blk, m, n_map)].add(1, mode="drop")
        cyc = dataclasses.replace(cyc, total=ct, walk=cwk, stall=cst,
                                  data_mem=cdm)
        cnt = dataclasses.replace(cnt, l1_hits=n_l1, stlb_hits=n_stlb,
                                  walks=n_walk, walk_mem_reads=n_wmr)
        st = dataclasses.replace(
            st, l1_tlb=l1, stlb=stlb_c, pde_pwc=pde, pdpte_pwc=pdpte,
            access_recent=access_recent, written_recent=written_recent,
            cycles=cyc, counters=cnt,
            step=st.step + jnp.sum(valid_blk.astype(I32)))

        def const(v):
            return jnp.broadcast_to(v, (B,))

        # Per-row cumulative timeline, same order as step()'s out tuple;
        # quantities phase A cannot move are window constants.
        out = (tot_r, walk_r, stall_r,
               const(st.counters.faults),
               const(st.node_free[0] + st.node_free[1]),
               const(jnp.sum((st.leaf_node >= 2).astype(I32))),
               const(jnp.sum(((st.leaf_node >= 0)
                              & (st.leaf_node < 2)).astype(I32))),
               walks_r,
               const(st.counters.data_migrations),
               const(st.counters.l4_mig_success),
               const(st.cycles.migration),
               dmem_r,
               const(jnp.sum(st.cycles.fault)),
               l1_r, stlb_r)
        return st, out

    return fast_window


def _geom_out_rows(geom, block: int) -> int:
    """Rows each compiled window emits (``R_out``): every branch of a
    geometry pads its concatenated segment outputs to one shared width so
    ``lax.switch`` arms agree on shapes."""
    r = block
    if geom is not None:
        _, hoist, split = geom
        if hoist is not None:
            r = max(r, hoist[0] + hoist[1])
        if split is not None:
            r = max(r, split[0] + split[1] + split[2])
    return r


def _geom_rows_in(geom, block: int) -> int:
    """Host row-padding of each window's input tile.  The hoist/split
    branches carve segments with ``dynamic_slice`` at traced offsets;
    slices must never clamp (clamping would misalign rows) and must never
    read the next window's rows, so each window is padded independently
    to ``2 * block`` rows whenever such a branch exists."""
    if geom is not None and (geom[1] is not None or geom[2] is not None):
        return 2 * block
    return block


def _normalize_blocked(budget: int, phase_b: str, group: Optional[int],
                       geom):
    """Canonicalize compile-key components a blocked geometry provably
    never feeds into the compiled program, so distinct callers share one
    executable: without a full/split branch no per-step body is built
    (the phase-B engine choice and allocator group bound are dead), and
    without any scan-capable branch the AutoNUMA candidate bound is dead
    too."""
    needs_step = geom is not None and (bool(geom[0]) or geom[2] is not None)
    needs_scan = needs_step or (geom is not None and geom[1] is not None)
    if not needs_step:
        phase_b, group = "batched", None
    if not needs_scan:
        budget = 0
    return budget, phase_b, group


def _build_blocked_body(mc: MachineConfig, budget: int, phase_b: str,
                        group: Optional[int], block: int, geom,
                        lanes: bool):
    """Build the per-window body of the time-blocked engine, shared by
    the solo runner (``_compiled_run``) and the lane sweep
    (``sweep._sweep_runner``, ``lanes=True``).

    ``geom`` is the host-quantized split geometry from
    :func:`plan_windows` — ``None`` (every window is fast: the compiled
    program contains no dispatch, no per-step body and no scan op at
    all) or ``(has_full, (Ph, Qh) | None, (Ps, Es, Qs) | None)``.  The
    body dispatches over at most four window kinds via ``lax.switch``
    (the kind index is host data shared by every lane, so the branch
    survives a vmapped sweep):

      fast    the whole window as one ``fast_window`` call;
      full    whole-window per-step replay (wide event spans, and
              partial tail windows with faults);
      hoist   fast prefix -> one hoisted scan tick -> fast suffix, with
              *zero* per-step rows — the AutoNUMA-cadence fast path;
      split   fast prefix -> per-step replay of the (narrow) event span
              -> fast suffix.

    Segment capacities come from ``geom``; each segment's live length
    arrives as traced offsets (``a_idx``/``b_idx``) and is enforced
    in-body by masking ``valid`` (and ``va`` for the split span) — rows
    beyond a live segment are exact no-ops of the same form as the
    window pad rows, so a branch is bit-identical to replaying its
    window per-step.  Branch outputs are zero-padded to a shared
    ``R_out`` row count; :func:`plan_windows` emits the matching
    ``emit_valid`` mask that maps emitted rows back to trace steps.
    """
    fast_window = _build_fast_window(mc)
    has_full = bool(geom[0]) if geom is not None else False
    hoist = geom[1] if geom is not None else None
    split = geom[2] if geom is not None else None
    needs_step = has_full or split is not None
    step = _build_step(mc, budget, phase_b, group) if needs_step else None
    scan_op = _build_scan_op(mc, budget) if hoist is not None else None
    r_out = _geom_out_rows(geom, block)

    if lanes:
        def run_fast(s, cc, va, wr, llc, vl):
            def lane(st1, cc1, va1, w1, llc1):
                return fast_window(st1, cc1, va1, w1, llc1, vl)
            st2, outs = jax.vmap(lane, in_axes=(0, 0, 1, 1, 1))(
                s, cc, va, wr, llc)
            # back to rows-major [rows, L] so the flattened timeline
            # keeps per-step semantics per lane
            return st2, jax.tree.map(lambda a: jnp.swapaxes(a, 0, 1), outs)

        def run_steps(s, cc, pc, arrs, seg_of_map, seg_of_leaf):
            def per_step_row(s2, xr):
                va_r, wr_r, fid_r, llc_r, sched_r, fr, sc, hf_r, vl_r = xr

                def lane(st1, cc1, pc1, va1, w1, fid1, llc1, sched1,
                         sm, sl):
                    return step(st1, cc1, pc1,
                                (va1, w1, fid1, llc1, sched1, fr, sc,
                                 hf_r, vl_r), sm, sl)
                return jax.vmap(lane)(s2, cc, pc, va_r, wr_r, fid_r,
                                      llc_r, sched_r, seg_of_map,
                                      seg_of_leaf)
            return jax.lax.scan(per_step_row, s, arrs)

        def run_scan(s, cc, pc, va_row, w_row):
            return jax.vmap(scan_op)(s, cc, pc, va_row, w_row)
    else:
        def run_fast(s, cc, va, wr, llc, vl):
            return fast_window(s, cc, va, wr, llc, vl)

        def run_steps(s, cc, pc, arrs, seg_of_map, seg_of_leaf):
            def per_step_row(s2, xr):
                return step(s2, cc, pc, xr, seg_of_map, seg_of_leaf)
            return jax.lax.scan(per_step_row, s, arrs)

        def run_scan(s, cc, pc, va_row, w_row):
            return scan_op(s, cc, pc, va_row, w_row)

    def dsl(a, start, size):
        return jax.lax.dynamic_slice_in_dim(a, start, size, axis=0)

    def pad_rows(outs, have):
        n = r_out - have
        if n == 0:
            return outs
        return jax.tree.map(
            lambda a: jnp.concatenate(
                [a, jnp.zeros((n,) + a.shape[1:], a.dtype)]), outs)

    def cat_rows(chunks):
        if len(chunks) == 1:
            return chunks[0]
        return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                            *chunks)

    def window(carry, xw, cc, pc, seg_of_map, seg_of_leaf):
        (va_w, wr_w, fid_w, llc_w, sched_w, vl_w, df_w, ds_w, hf_w,
         kind, a_idx, b_idx) = xw

        def fast_whole(s):
            s, o = run_fast(s, cc, va_w[:block], wr_w[:block],
                            llc_w[:block], vl_w[:block])
            return s, pad_rows(o, block)

        branches = [fast_whole]

        if has_full:
            def full_replay(s):
                arrs = (va_w[:block], wr_w[:block], fid_w[:block],
                        llc_w[:block], sched_w[:block], df_w[:block],
                        ds_w[:block], hf_w[:block], vl_w[:block])
                s, o = run_steps(s, cc, pc, arrs, seg_of_map, seg_of_leaf)
                return s, pad_rows(o, block)
            branches.append(full_replay)

        if hoist is not None:
            ph, qh = hoist

            def hoist_window(s):
                chunks = []
                if ph:
                    pv = vl_w[:ph] & (jnp.arange(ph) < a_idx)
                    s, o = run_fast(s, cc, va_w[:ph], wr_w[:ph],
                                    llc_w[:ph], pv)
                    chunks.append(o)
                s = run_scan(s, cc, pc, jnp.take(va_w, a_idx, axis=0),
                             jnp.take(wr_w, a_idx, axis=0))
                if qh:
                    s, o = run_fast(s, cc, dsl(va_w, b_idx, qh),
                                    dsl(wr_w, b_idx, qh),
                                    dsl(llc_w, b_idx, qh),
                                    dsl(vl_w, b_idx, qh))
                    chunks.append(o)
                return s, pad_rows(cat_rows(chunks), ph + qh)
            branches.append(hoist_window)

        if split is not None:
            ps, es, qs = split

            def split_window(s):
                chunks = []
                if ps:
                    pv = vl_w[:ps] & (jnp.arange(ps) < a_idx)
                    s, o = run_fast(s, cc, va_w[:ps], wr_w[:ps],
                                    llc_w[:ps], pv)
                    chunks.append(o)
                # rows of the capacity slice beyond the live span are
                # real suffix rows: mask va to -1 and valid to False so
                # they replay as exact no-ops here and execute once, in
                # the fast suffix (their event masks are False already —
                # events end at the span by construction)
                span = jnp.arange(es) < (b_idx - a_idx)
                va_e = jnp.where(
                    span.reshape((es,) + (1,) * (va_w.ndim - 1)),
                    dsl(va_w, a_idx, es), -1)
                arrs = (va_e, dsl(wr_w, a_idx, es), dsl(fid_w, a_idx, es),
                        dsl(llc_w, a_idx, es), dsl(sched_w, a_idx, es),
                        dsl(df_w, a_idx, es), dsl(ds_w, a_idx, es),
                        dsl(hf_w, a_idx, es),
                        dsl(vl_w, a_idx, es) & span)
                s, o = run_steps(s, cc, pc, arrs, seg_of_map, seg_of_leaf)
                chunks.append(o)
                if qs:
                    s, o = run_fast(s, cc, dsl(va_w, b_idx, qs),
                                    dsl(wr_w, b_idx, qs),
                                    dsl(llc_w, b_idx, qs),
                                    dsl(vl_w, b_idx, qs))
                    chunks.append(o)
                return s, pad_rows(cat_rows(chunks), ps + es + qs)
            branches.append(split_window)

        if len(branches) == 1:
            return fast_whole(carry)
        return jax.lax.switch(kind, branches, carry)

    return window


def _compiled_run(mc: MachineConfig, budget: int, phase_b: str = "batched",
                  engine: str = "blocked", block: int = DEFAULT_BLOCK,
                  group: Optional[int] = None, geom=None):
    """One jitted runner per (machine shape, AutoNUMA bound, phase-B
    engine, execution engine, window size, allocator group bound, split
    geometry).

    Policy and cost configs are traced arguments, so every policy bundle —
    and every CostConfig variation — reuses the same compiled artifact for
    a given trace shape.  ``engine="blocked"`` scans window tiles through
    the kind-dispatched body of :func:`_build_blocked_body` (``geom`` is
    the quantized split geometry from :func:`plan_windows`, part of the
    compile key); ``"per_step"`` is the retained step-at-a-time
    reference.  Blocked keys are normalized first: parameters a geometry
    never compiles (phase-B engine / group without a per-step branch,
    budget without any scan) collapse to canonical values so those
    programs keep quantizing across trace mixes.
    """
    assert engine in ("blocked", "per_step"), engine
    if engine == "blocked":
        budget, phase_b, group = _normalize_blocked(budget, phase_b, group,
                                                    geom)
    key = (mc, budget, phase_b, engine, block, group, geom)
    if key not in _RUN_CACHE:
        if engine == "per_step":
            step = _build_step(mc, budget, phase_b, group)

            @jax.jit
            def run_all(st, cc, pc, xs, seg_of_map, seg_of_leaf):
                def body(s, x):
                    return step(s, cc, pc, x, seg_of_map, seg_of_leaf)
                return jax.lax.scan(body, st, xs)
        else:
            window = _build_blocked_body(mc, budget, phase_b, group,
                                         block, geom, lanes=False)

            @jax.jit
            def run_all(st, cc, pc, xs, seg_of_map, seg_of_leaf):
                def body(s, xw):
                    return window(s, xw, cc, pc, seg_of_map, seg_of_leaf)
                return jax.lax.scan(body, st, xs)

        _RUN_CACHE[key] = run_all
    return _RUN_CACHE[key]


def seg_of_leaf_table(trace: Trace, mc: MachineConfig) -> jax.Array:
    seg_of_map = jnp.asarray(trace.seg_of_map, I32)
    n_leaf = mc.n_leaf_pages
    leaf_first = (np.arange(n_leaf, dtype=np.int64) << mc.radix_bits) \
        % max(mc.n_map, 1)
    return seg_of_map[jnp.asarray(leaf_first, I32)]


def trace_xs(trace: Trace, mc: MachineConfig, pc: PolicyConfig,
             start_step: int = 0, sched: Optional[np.ndarray] = None):
    """Per-step scan inputs for one trace: rows + schedule predicates."""
    do_free = np.asarray(trace.free_seg) >= 0
    do_scan = scan_step_mask(trace.n_steps, int(pc.autonuma_period),
                             enabled=bool(pc.autonuma), start_step=start_step)
    if sched is None:
        sched = fault_schedule(trace, mc)
    return (jnp.asarray(trace.va, I32), jnp.asarray(trace.is_write),
            jnp.asarray(trace.free_seg, I32), jnp.asarray(trace.llc, F32),
            jnp.asarray(sched), jnp.asarray(do_free), jnp.asarray(do_scan),
            jnp.asarray((sched & SCHED_DO).any(axis=1)),
            jnp.ones((trace.n_steps,), jnp.bool_))


# Idle-pad fill values for the nine per-step window arrays, in xs order:
# (va, is_write, free_seg, llc, sched, valid, do_free, do_scan,
# has_fault).  Load-bearing: sched=0 carries no DO/WINNER bits, fid=-1
# frees nothing, valid=False gates the step clock — shared by the solo
# (blocked_xs) and sweep (sweep_lanes) tilings so pad-row semantics can
# never diverge between them.
WINDOW_PAD_FILLS = (-1, False, -1, 0.0, 0, False, False, False, False)


def window_tiles(arrays, n_steps: int, block: int,
                 fills=WINDOW_PAD_FILLS, rows_to: Optional[int] = None):
    """Idle-pad per-step host arrays to a multiple of ``block`` and tile
    them ``[n_windows, rows, ...]``.  The window count depends only on
    the step count, never the trace content — the property that keeps
    compiled blocked programs quantizing across trace mixes.  ``rows_to``
    (``WindowPlan.rows_in``) additionally idle-pads every window's row
    axis past ``block``: headroom for the hoist/split branches' dynamic
    segment slices, padded *per window* so a slice never reads the next
    window's rows."""
    n_w = -(-n_steps // block)
    pad = n_w * block - n_steps
    rpad = (rows_to or block) - block
    out = []
    for a, fill in zip(arrays, fills):
        a = np.asarray(a)
        if pad:
            a = np.concatenate(
                [a, np.full((pad,) + a.shape[1:], fill, a.dtype)])
        a = a.reshape((n_w, block) + a.shape[1:])
        if rpad:
            a = np.concatenate(
                [a, np.full((n_w, rpad) + a.shape[2:], fill, a.dtype)],
                axis=1)
        out.append(a)
    return out


# Semantic window kinds of the blocked engine's host classification.  The
# compiled dispatch table only contains the kinds a geometry needs
# ([fast] + [full][hoist][split], in that order) and ``WindowPlan.kind``
# stores the *branch index* under that ordering — geometry lives in the
# compile key, so dispatch table and data can never disagree.
WIN_FAST, WIN_FULL, WIN_HOIST, WIN_SPLIT = range(4)


@dataclasses.dataclass(frozen=True)
class WindowPlan:
    """Host-side execution plan for one blocked run.

    ``geom`` is the quantized split geometry (hashable; part of the
    compile key): ``None`` when every window is fast, else
    ``(has_full, (Ph, Qh) | None, (Ps, Es, Qs) | None)`` with pow2
    segment capacities.  ``kind``/``seg_a``/``seg_b`` are per-window
    device inputs (branch index, event/tick start row, suffix start
    row); ``emit_valid`` (``[n_windows, R_out]`` bool) maps emitted
    output rows back to trace steps in step order; ``counts`` reports
    the semantic classification (fast, full, hoist, split) for
    telemetry."""
    geom: Optional[tuple]
    kind: np.ndarray
    seg_a: np.ndarray
    seg_b: np.ndarray
    emit_valid: np.ndarray
    rows_in: int
    block: int
    counts: Tuple[int, int, int, int]

    @property
    def n_windows(self) -> int:
        return len(self.kind)


def _q2(n: int) -> int:
    return 0 if n <= 0 else pow2ceil(int(n))


def plan_windows(do_free, do_scan, has_fault, n_steps: int,
                 block: int) -> WindowPlan:
    """Classify each ``block``-step window of a trace and quantize the
    split geometry.

    The host schedule knows the exact event rows (segment frees, scan
    ticks, faults — for a sweep, the union over lanes), so a window
    needn't replay per-step just because it *contains* an event:

      fast    no event rows at all;
      hoist   no frees/faults and exactly one scan tick at row ``t`` —
              runs fast[0:t), the hoisted scan op, fast[t:block);
      split   a narrow event span (``<= block // 2``) — runs fast
              prefix, per-step replay of the span, fast suffix;
      full    wide spans, plus every partial tail window containing
              fault rows: there the span end *is* the trace's last
              faulting step, and letting trace content pick the split
              geometry would fracture the compile-key quantization the
              broker's shape buckets rely on.

    Segment capacities are per-class maxima rounded up to powers of two
    (``Ph``/``Qh`` hoist prefix/suffix, ``Ps``/``Es``/``Qs`` split
    prefix/event/suffix), so traces with different event rows but the
    same quantized geometry share one executable; live lengths travel as
    device data (``seg_a``/``seg_b``) and are masked in-body.
    """
    n_w = -(-n_steps // block)
    pad = n_w * block - n_steps

    def tile(m):
        m = np.asarray(m, bool)
        if pad:
            m = np.concatenate([m, np.zeros(pad, bool)])
        return m.reshape(n_w, block)

    df, ds, hf = tile(do_free), tile(do_scan), tile(has_fault)
    vl = tile(np.ones(n_steps, bool))
    ev = df | ds | hf

    kinds = np.full(n_w, WIN_FAST, np.int32)
    seg_a = np.zeros(n_w, np.int32)
    seg_b = np.zeros(n_w, np.int32)
    hoist_rows, split_rows = [], []
    for w in range(n_w):
        if not ev[w].any():
            continue
        if not (df[w] | hf[w]).any() and int(ds[w].sum()) == 1:
            t = int(np.argmax(ds[w]))
            kinds[w] = WIN_HOIST
            seg_a[w] = seg_b[w] = t
            hoist_rows.append(t)
            continue
        idx = np.flatnonzero(ev[w])
        f, l = int(idx[0]), int(idx[-1])
        if (l - f + 1) > block // 2 or (hf[w].any() and not vl[w].all()):
            kinds[w] = WIN_FULL
        else:
            kinds[w] = WIN_SPLIT
            seg_a[w], seg_b[w] = f, l + 1
            split_rows.append((f, l - f + 1, block - 1 - l))

    has_full = bool((kinds == WIN_FULL).any())
    hoist_g = (_q2(max(hoist_rows)), _q2(block - min(hoist_rows))) \
        if hoist_rows else None
    split_g = (_q2(max(r[0] for r in split_rows)),
               _q2(max(r[1] for r in split_rows)),
               _q2(max(r[2] for r in split_rows))) if split_rows else None
    geom = (has_full, hoist_g, split_g) \
        if (has_full or hoist_g or split_g) else None

    branch = {WIN_FAST: 0}
    for k, present in ((WIN_FULL, has_full),
                       (WIN_HOIST, hoist_g is not None),
                       (WIN_SPLIT, split_g is not None)):
        if present:
            branch[k] = len(branch)
    kind = np.array([branch[int(k)] for k in kinds], np.int32)

    r_out = _geom_out_rows(geom, block)
    rows_in = _geom_rows_in(geom, block)
    emit = np.zeros((n_w, r_out), bool)
    vlx = np.concatenate([vl, np.zeros_like(vl)], axis=1)
    for w in range(n_w):
        k = int(kinds[w])
        if k in (WIN_FAST, WIN_FULL):
            emit[w, :block] = vl[w]
            continue
        a, b = int(seg_a[w]), int(seg_b[w])
        if k == WIN_HOIST:
            ph, qh = hoist_g
            pre = vlx[w, :ph] & (np.arange(ph) < a)
            emit[w, :ph + qh] = np.concatenate([pre, vlx[w, b:b + qh]])
        else:
            ps, es, qs = split_g
            pre = vlx[w, :ps] & (np.arange(ps) < a)
            mid = vlx[w, a:a + es] & (np.arange(es) < (b - a))
            emit[w, :ps + es + qs] = np.concatenate(
                [pre, mid, vlx[w, b:b + qs]])
    assert int(emit.sum()) == n_steps, \
        f"window plan emits {int(emit.sum())} rows for {n_steps} steps"
    return WindowPlan(
        geom=geom, kind=kind, seg_a=seg_a, seg_b=seg_b, emit_valid=emit,
        rows_in=rows_in, block=block,
        counts=tuple(int((kinds == k).sum()) for k in range(4)))


def blocked_xs(trace: Trace, mc: MachineConfig, pc: PolicyConfig,
               start_step: int = 0, block: int = DEFAULT_BLOCK,
               sched: Optional[np.ndarray] = None):
    """Window-tiled scan inputs for the time-blocked engine.

    Returns ``(xs, plan)``: ``xs`` carries every per-step row (windows
    row-padded to ``plan.rows_in``) plus the plan's per-window branch
    index and segment offsets; ``plan`` is the :class:`WindowPlan`
    whose ``emit_valid`` maps the scan's ``[n_windows, R_out]`` outputs
    back to trace steps (idle pad and capacity-slack rows are dropped
    when the per-step timeline is reassembled).
    """
    S = trace.n_steps
    if sched is None:
        sched = fault_schedule(trace, mc)
    do_free = np.asarray(trace.free_seg) >= 0
    do_scan = scan_step_mask(S, int(pc.autonuma_period),
                             enabled=bool(pc.autonuma),
                             start_step=start_step)
    has_fault = np.asarray((sched & SCHED_DO) > 0).any(axis=1)
    plan = plan_windows(do_free, do_scan, has_fault, S, block)
    va, wr, fid, llc, sch, vl, df, ds, hf = window_tiles(
        (trace.va.astype(np.int32), np.asarray(trace.is_write, bool),
         np.asarray(trace.free_seg, np.int32),
         np.asarray(trace.llc, np.float32), sched, np.ones((S,), bool),
         do_free, do_scan, has_fault),
        S, block, rows_to=plan.rows_in)
    xs = (jnp.asarray(va), jnp.asarray(wr), jnp.asarray(fid),
          jnp.asarray(llc), jnp.asarray(sch), jnp.asarray(vl),
          jnp.asarray(df), jnp.asarray(ds), jnp.asarray(hf),
          jnp.asarray(plan.kind), jnp.asarray(plan.seg_a),
          jnp.asarray(plan.seg_b))
    return xs, plan


class TieredMemSimulator:
    """Public facade: configure once, run traces under a policy bundle.

    ``phase_b`` selects the fault engine: ``"batched"`` (default, the
    conflict-aware vectorized path) or ``"sequential"`` (the per-thread
    ``fori_loop`` reference the batched engine is tested against).

    ``engine`` selects the stepper: ``"blocked"`` (default — the
    time-blocked fast path over ``block``-step windows, bit-identical to
    per-step execution) or ``"per_step"`` (the retained one-step-per-scan
    reference).

    The reference paths (``engine="per_step"`` / ``phase_b="sequential"``)
    are differential-testing oracles, not production engines: after two
    PRs of soak they are gated behind ``debug=True`` so production code
    cannot silently run the slow paths (``tests/test_blocked.py`` and the
    oracle suites still exercise them).

    ``telemetry`` (optional :class:`repro.obs.Telemetry`) records run
    counters, the fast/event window classification and — when tracing —
    a ``sim.run`` span plus per-window ``window.fast`` / ``window.event``
    spans.  All hooks are host-side: the compiled program and its
    outputs are bitwise-identical with telemetry on or off.
    """

    def __init__(self, mc: MachineConfig = MachineConfig(),
                 cc: CostConfig = CostConfig(),
                 pc: PolicyConfig = PolicyConfig(),
                 phase_b: str = "batched",
                 engine: str = "blocked",
                 block: int = DEFAULT_BLOCK,
                 debug: bool = False,
                 telemetry=None):
        assert engine in ("blocked", "per_step"), engine
        if (engine != "blocked" or phase_b != "batched") and not debug:
            raise ValueError(
                f"engine={engine!r} phase_b={phase_b!r} are reference "
                f"(oracle) paths; pass debug=True to run them")
        self.mc, self.cc, self.pc = mc, cc, pc
        self.phase_b = phase_b
        self.engine = engine
        self.block = int(block)
        self.debug = bool(debug)
        self.telemetry = or_null(telemetry)

    def run(self, trace: Trace, state: Optional[SimState] = None) -> RunResult:
        tel = self.telemetry
        run_t0 = tel.now()
        mc = self.mc
        assert trace.va.shape[1] == mc.n_threads, \
            f"trace has {trace.va.shape[1]} threads, machine {mc.n_threads}"
        budget = min(int(self.pc.autonuma_budget), mc.n_map)
        sched = fault_schedule(trace, mc)      # memoized; computed once
        group = None
        if self.phase_b == "batched":
            group = min(pow2ceil(fault_group_bound(sched)), mc.n_threads)

        seg_of_map = jnp.asarray(trace.seg_of_map, I32)
        seg_of_leaf = seg_of_leaf_table(trace, mc)

        st0 = state if state is not None else init_state(mc)
        start = int(np.asarray(state.step)) if state is not None else 0

        if self.engine == "blocked":
            block = min(self.block, pow2ceil(trace.n_steps))
            xs, plan = blocked_xs(trace, mc, self.pc, start_step=start,
                                  block=block, sched=sched)
            win_kind = None
            if tel.enabled:
                # the host-side window classification is exactly the
                # fast/full/hoist/split dispatch the blocked engine ran
                win_kind = plan.kind        # branch 0 == fast path
                n_fast, _, n_hoist, n_split = plan.counts
                tel.counter("sim.windows_event").inc(
                    plan.n_windows - n_fast)
                tel.counter("sim.windows_fast").inc(n_fast)
                tel.counter("sim.windows_hoist").inc(n_hoist)
                tel.counter("sim.windows_split").inc(n_split)
            run_all = _compiled_run(mc, budget, self.phase_b, "blocked",
                                    block, group, plan.geom)
            dev_t0 = tel.now()
            final, outs = run_all(st0, self.cc, self.pc, xs, seg_of_map,
                                  seg_of_leaf)
            timeline = {k: np.asarray(v)[plan.emit_valid]
                        for k, v in zip(TIMELINE_KEYS, outs)}
            if dev_t0 is not None:
                # the compiled scan is opaque: device time attributes
                # uniformly across windows, the classification is exact
                dev_t1 = tel.now()
                w_dur = (dev_t1 - dev_t0) / max(len(win_kind), 1)
                for i, k in enumerate(win_kind):
                    tel.add_span(
                        "window.event" if k else "window.fast",
                        dev_t0 + i * w_dur, dev_t0 + (i + 1) * w_dur,
                        cat="engine", tid=1, args={"window": i})
        else:
            if tel.enabled:
                tel.counter("sim.steps").inc(trace.n_steps)
            xs = trace_xs(trace, mc, self.pc, start_step=start, sched=sched)
            run_all = _compiled_run(mc, budget, self.phase_b, "per_step",
                                    0, group)
            final, outs = run_all(st0, self.cc, self.pc, xs, seg_of_map,
                                  seg_of_leaf)
            timeline = {k: np.asarray(v) for k, v in zip(TIMELINE_KEYS, outs)}
        final = jax.device_get(final)
        if tel.enabled:
            tel.counter("sim.runs", engine=self.engine).inc()
            if run_t0 is not None:
                tel.add_span("sim.run", run_t0, tel.now(), cat="engine",
                             args={"steps": trace.n_steps,
                                   "engine": self.engine,
                                   "trace": trace.name})
        return RunResult(final_state=final, timeline=timeline,
                         trace_name=trace.name, policy_label=self.pc.label())
