"""Simulator state: placement arrays, allocator, translation caches, metrics.

The page-table radix tree is *implicit*: for mapping granule ``m`` (a 4 KiB
page, or a 2 MiB page under THP) the PT pages touched by a walk are

    leaf page  ``m >> radix_bits``       (PTE page; PMD page under THP)
    mid  page  ``m >> 2*radix_bits``     (PMD page; PUD page under THP)
    top  page  ``m >> 3*radix_bits``     (PUD page; PGD under THP)
    root page  ``0``        (PGD)

so one int32 "NUMA node or -1" array per level encodes the whole tree.  This
is exact for x86-style 512-ary radix tables and lets walks, placement
queries, and Algorithm-1 conditions vectorize as gathers.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import tlbs
from .config import MachineConfig

I32 = jnp.int32
F32 = jnp.float32


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Counters:
    """Cumulative event counters (int32; exact at test scales)."""

    l1_hits: jax.Array
    stlb_hits: jax.Array
    walks: jax.Array                 # hardware page walks (both-TLB misses)
    walk_mem_reads: jax.Array        # PT-page memory reads issued by walks
    faults: jax.Array
    data_allocs: jax.Array           # i32[4] per node
    pt_allocs: jax.Array             # i32[4] per node
    slow_allocs: jax.Array
    data_migrations: jax.Array       # successful data-page migrations
    demotions: jax.Array
    l4_mig_success: jax.Array        # Table-5 "Successful migration"
    l4_mig_already_dest: jax.Array   # Table-5 "Already in destination"
    l4_mig_in_dram: jax.Array        # Table-5 "With in DRAM" (same-tier skip)
    l4_mig_sibling_guard: jax.Array  # Alg.1 line 18: a child is still in DRAM
    l4_mig_lock_skip: jax.Array      # Alg.1/§5.3: PMD try_lock failed
    oom_kills: jax.Array
    nomad_retries: jax.Array         # Nomad: promotions aborted by a write
    nomad_flip_demotions: jax.Array  # Nomad: demotions served by a shadow flip
    nomad_shadow_drops: jax.Array    # Nomad: shadows invalidated by a write


def zero_counters(n_nodes: int = 4) -> Counters:
    z = jnp.zeros((), I32)
    return Counters(l1_hits=z, stlb_hits=z, walks=z, walk_mem_reads=z,
                    faults=z, data_allocs=jnp.zeros((n_nodes,), I32),
                    pt_allocs=jnp.zeros((n_nodes,), I32), slow_allocs=z,
                    data_migrations=z, demotions=z, l4_mig_success=z,
                    l4_mig_already_dest=z, l4_mig_in_dram=z,
                    l4_mig_sibling_guard=z, l4_mig_lock_skip=z, oom_kills=z,
                    nomad_retries=z, nomad_flip_demotions=z,
                    nomad_shadow_drops=z)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Cycles:
    """Cumulative cycle accounting (float32: exact below 2^24 for oracle
    tests; ~1e-7 relative at benchmark scales)."""

    total: jax.Array        # f32[T] per-thread total cycles
    walk: jax.Array         # f32[T] cycles the PMH spent walking
    stall: jax.Array        # f32[T] memory-stall cycles (walk + exposed data)
    data_mem: jax.Array     # f32[T] raw data-access memory cycles
    fault: jax.Array        # f32[T] fault-handler cycles (incl. alloc, zero)
    migration: jax.Array    # f32[]  background migration work (all threads)


def zero_cycles(n_threads: int) -> Cycles:
    z = jnp.zeros((n_threads,), F32)
    return Cycles(total=z, walk=z, stall=z, data_mem=z, fault=z,
                  migration=jnp.zeros((), F32))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SimState:
    # --- placement: NUMA node per page, -1 = unallocated -------------------
    data_node: jax.Array          # i32[n_map]
    leaf_node: jax.Array          # i32[n_leaf]   PTE pages (PMD under THP)
    mid_node: jax.Array           # i32[n_mid]
    top_node: jax.Array           # i32[n_top]
    root_node: jax.Array          # i32[1]
    leaf_dram_children: jax.Array  # i32[n_leaf]  #mapped children on DRAM
    # Nomad non-exclusive tiering: a committed promotion keeps a clean
    # shadow copy on its source node (-1 = none).  A later demotion of the
    # same page "flips" to the shadow for free; a write invalidates it.
    shadow_node: jax.Array        # i32[n_map]

    # --- allocator ----------------------------------------------------------
    node_free: jax.Array          # i32[n_nodes]
    node_reclaimable: jax.Array   # i32[n_nodes] page-cache style reserve
    interleave_ptr: jax.Array     # i32[] round-robin cursor
    oom_killed: jax.Array         # bool[] OOM handler fired
    oom_step: jax.Array           # i32[] step at which it fired (-1)

    # --- hotness (AutoNUMA input) -------------------------------------------
    access_recent: jax.Array      # i32[n_map], periodically halved
    # Writes since the last balancing scan (Nomad's transactional-abort
    # and shadow-invalidation input); cleared at every Nomad scan tick.
    written_recent: jax.Array     # i32[n_map]

    # --- translation caches -------------------------------------------------
    l1_tlb: tlbs.TlbArray
    stlb: tlbs.TlbArray
    pde_pwc: tlbs.TlbArray
    pdpte_pwc: tlbs.TlbArray

    # --- accounting ----------------------------------------------------------
    cycles: Cycles
    counters: Counters
    step: jax.Array               # i32[] global step (LRU timestamp)


def init_state(mc: MachineConfig) -> SimState:
    cap = jnp.asarray(mc.node_capacity(), I32)
    reclaim = (cap.astype(F32) * mc.reclaimable_frac).astype(I32)
    n_map = mc.n_map
    n_leaf = mc.n_leaf_pages
    n_mid = mc.n_mid_pages
    n_top = mc.n_top_pages
    return SimState(
        data_node=jnp.full((n_map,), -1, I32),
        leaf_node=jnp.full((n_leaf,), -1, I32),
        mid_node=jnp.full((n_mid,), -1, I32),
        top_node=jnp.full((n_top,), -1, I32),
        root_node=jnp.full((1,), -1, I32),
        leaf_dram_children=jnp.zeros((n_leaf,), I32),
        shadow_node=jnp.full((n_map,), -1, I32),
        node_free=cap - reclaim,
        node_reclaimable=reclaim,
        interleave_ptr=jnp.zeros((), I32),
        oom_killed=jnp.zeros((), jnp.bool_),
        oom_step=jnp.full((), -1, I32),
        access_recent=jnp.zeros((n_map,), I32),
        written_recent=jnp.zeros((n_map,), I32),
        l1_tlb=tlbs.make_tlb(mc.n_threads, mc.l1_tlb_sets, mc.l1_tlb_ways),
        stlb=tlbs.make_tlb(mc.n_threads, mc.stlb_sets, mc.stlb_ways),
        pde_pwc=tlbs.make_tlb(mc.n_threads, 1, mc.pde_pwc_entries),
        pdpte_pwc=tlbs.make_tlb(mc.n_threads, 1, mc.pdpte_pwc_entries),
        cycles=zero_cycles(mc.n_threads),
        counters=zero_counters(mc.n_nodes),
        step=jnp.zeros((), I32),
    )


def is_dram(node: jax.Array) -> jax.Array:
    """True for DRAM nodes.  Node numbering is tier-major with two nodes
    per tier, so tier 0 (DRAM) is always nodes (0, 1) — valid for any
    tier count."""
    return (node >= 0) & (node < 2)


def same_tier(a: jax.Array, b: jax.Array) -> jax.Array:
    return is_dram(a) == is_dram(b)
