"""Data-page balancing (AutoNUMA model) and Algorithm-1 leaf-PT migration.

Semantics (deterministic, mirrored exactly by ``core.ref`` and the paper's
description in sections 4.3-4.4 / 5.2-5.3):

  * Every ``autonuma_period`` steps a scan runs: the hottest NVMM-resident
    data pages (``access_recent`` >= threshold) are promoted to DRAM, bounded
    by the scan budget and free DRAM above the watermark; optionally the
    coldest DRAM pages are demoted first to make room (exchange mode).
  * All data migrations of a scan are applied as one batch (the kernel also
    batches via ``migrate_pages``), then each completed migration *triggers*
    Algorithm 1 for its leaf PT page, in batch order:
      - only the first trigger per leaf page evaluates/migrates (the paper's
        "first data page migrated triggers; the other 511 find the PTE page
        already in the destination" — Table 5);
      - skip if already on the destination node, or on the same tier
        ("with in DRAM"), or if demoting while any sibling data page is
        still DRAM-resident (Alg. 1 line 18);
      - concurrent triggers under one mid-level (PMD) page model the
        ``try_lock`` race: the earliest wins, later ones are lock-skips
        (section 5.3).
  * Migrated leaf pages cost a page copy + fixed overhead + a TLB/PWC
    shootdown; affected translation-cache entries are invalidated.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import tlbs
from .config import CostConfig, MachineConfig, PolicyConfig
from .state import SimState, is_dram, same_tier

I32 = jnp.int32
F32 = jnp.float32


def _read_lat(cc: CostConfig, node: jax.Array) -> jax.Array:
    return jnp.where(is_dram(node), cc.dram_read, cc.nvmm_read).astype(F32)


def _write_lat(cc: CostConfig, node: jax.Array) -> jax.Array:
    return jnp.where(is_dram(node), cc.dram_write, cc.nvmm_write).astype(F32)


def _split_two(n: jax.Array, cap_a: jax.Array, cap_b: jax.Array
               ) -> jax.Array:
    """How many of ``n`` items go to the first of two nodes.

    Fills the node with more headroom first; deterministic and
    capacity-respecting given n <= cap_a + cap_b.
    """
    a_first = cap_a >= cap_b
    share_a = jnp.where(a_first, jnp.minimum(cap_a, n),
                        n - jnp.minimum(cap_b, n))
    return jnp.maximum(share_a, 0)


def _rank_key(count: jax.Array, idx_bits: int) -> jax.Array:
    """Composite int32 sort key: clipped count then low index tie-break."""
    n = 1 << idx_bits
    idx = jnp.arange(count.shape[0], dtype=I32)
    return (jnp.clip(count, 0, 255) << idx_bits) | (n - 1 - idx)


def autonuma_scan(st: SimState, mc: MachineConfig, cc: CostConfig,
                  pc: PolicyConfig, wm: jax.Array,
                  budget: int) -> Tuple[SimState, jax.Array]:
    """One AutoNUMA scan + (optionally) Algorithm-1 triggers.

    Returns the new state and the total migration cycles of this scan (the
    caller spreads them over threads: the migration daemon steals CPU time).

    ``budget`` is the static upper bound on candidates (it shapes the
    ``top_k`` calls); the PolicyConfig knobs — ``autonuma`` on/off,
    ``autonuma_budget``, threshold, exchange, ``mig`` — may all be traced
    scalars (a vmap policy sweep), so they gate through masks: a disabled
    lane's scan is a bit-exact no-op rather than a skipped branch.
    """
    n_map = st.data_node.shape[0]
    B = min(int(budget), n_map)
    idx_bits = max(n_map - 1, 1).bit_length()
    enabled = jnp.asarray(pc.autonuma) & ~st.oom_killed
    budget_t = jnp.minimum(jnp.asarray(pc.autonuma_budget, I32), n_map)

    on_nvmm = (st.data_node >= 2)
    hot_count = jnp.where(on_nvmm & (st.access_recent >= pc.autonuma_threshold),
                          st.access_recent, 0)
    hot_key = jnp.where(hot_count > 0, _rank_key(hot_count, idx_bits), -1)
    _, hot_pages = jax.lax.top_k(hot_key, B)
    hot_valid = jnp.take(hot_key, hot_pages) > 0
    n_hot = jnp.minimum(jnp.sum(hot_valid.astype(I32)), budget_t)

    # Cold DRAM victims (exchange mode only).
    on_dram = is_dram(st.data_node)
    cold_score = jnp.where(on_dram, 255 - jnp.clip(st.access_recent, 0, 255), 0)
    cold_key = jnp.where(on_dram, _rank_key(cold_score, idx_bits), -1)
    _, cold_pages = jax.lax.top_k(cold_key, B)
    cold_valid = jnp.take(cold_key, cold_pages) >= 0

    excess0 = jnp.maximum(st.node_free[0] - wm[0], 0)
    excess1 = jnp.maximum(st.node_free[1] - wm[1], 0)
    dram_excess = excess0 + excess1

    n_promote_want = jnp.minimum(n_hot, budget_t)
    need_demote = jnp.maximum(n_promote_want - dram_excess, 0)
    n_victims = jnp.minimum(jnp.sum(cold_valid.astype(I32)), budget_t)
    nvmm_room = jnp.maximum(st.node_free[2], 0) + jnp.maximum(st.node_free[3], 0)
    n_demote = jnp.where(enabled & jnp.asarray(pc.autonuma_exchange),
                         jnp.minimum(jnp.minimum(need_demote, n_victims),
                                     nvmm_room), 0)
    n_promote = jnp.where(enabled,
                          jnp.minimum(n_promote_want, dram_excess + n_demote),
                          0)

    # ---- apply demotions ---------------------------------------------------
    k = jnp.arange(B, dtype=I32)
    dem_mask = k < n_demote
    dem_pages = cold_pages
    share2 = _split_two(n_demote, st.node_free[2], st.node_free[3])
    dem_dest = jnp.where(k < share2, 2, 3).astype(I32)
    dem_src = jnp.take(st.data_node, dem_pages)

    data_node = st.data_node.at[dem_pages].set(
        jnp.where(dem_mask, dem_dest, jnp.take(st.data_node, dem_pages)))
    free_delta = (jnp.zeros((4,), I32)
                  .at[jnp.clip(dem_src, 0, 3)].add(dem_mask.astype(I32))
                  .at[dem_dest].add(-dem_mask.astype(I32)))
    ldc = st.leaf_dram_children.at[dem_pages >> mc.radix_bits].add(
        jnp.where(dem_mask, -1, 0))

    # ---- apply promotions ----------------------------------------------------
    pro_mask = (k < n_promote) & hot_valid
    pro_pages = hot_pages
    excess0b = jnp.maximum(st.node_free[0] + free_delta[0] - wm[0], 0)
    excess1b = jnp.maximum(st.node_free[1] + free_delta[1] - wm[1], 0)
    share0 = _split_two(n_promote, excess0b, excess1b)
    pro_dest = jnp.where(k < share0, 0, 1).astype(I32)
    pro_src = jnp.take(data_node, pro_pages)

    data_node = data_node.at[pro_pages].set(
        jnp.where(pro_mask, pro_dest, jnp.take(data_node, pro_pages)))
    free_delta = (free_delta
                  .at[jnp.clip(pro_src, 0, 3)].add(pro_mask.astype(I32))
                  .at[pro_dest].add(-pro_mask.astype(I32)))
    ldc = ldc.at[pro_pages >> mc.radix_bits].add(jnp.where(pro_mask, 1, 0))

    n_data_migs = jnp.sum(dem_mask.astype(I32)) + jnp.sum(pro_mask.astype(I32))
    mig_cost = jnp.sum(jnp.where(dem_mask, cc.migrate_fixed + cc.tlb_flush +
                                 cc.copy_lines * (_read_lat(cc, dem_src) +
                                                  _write_lat(cc, dem_dest)), 0.0))
    mig_cost += jnp.sum(jnp.where(pro_mask, cc.migrate_fixed + cc.tlb_flush +
                                  cc.copy_lines * (_read_lat(cc, pro_src) +
                                                   _write_lat(cc, pro_dest)), 0.0))

    # TLB shootdown for migrated data pages (non-migrated entries are routed
    # out of range and dropped to avoid duplicate-scatter hazards).
    map_flushed = jnp.zeros((n_map,), jnp.bool_)
    map_flushed = map_flushed.at[jnp.where(dem_mask, dem_pages, n_map)].set(
        True, mode="drop")
    map_flushed = map_flushed.at[jnp.where(pro_mask, pro_pages, n_map)].set(
        True, mode="drop")
    l1_tlb = tlbs.invalidate_matching(st.l1_tlb, map_flushed, 0)
    stlb = tlbs.invalidate_matching(st.stlb, map_flushed, 0)

    counters = st.counters
    counters = dataclasses_replace(counters,
                                   data_migrations=counters.data_migrations + n_data_migs,
                                   demotions=counters.demotions +
                                   jnp.sum(dem_mask.astype(I32)))

    st = dataclasses_replace(
        st, data_node=data_node, leaf_dram_children=ldc,
        node_free=st.node_free + free_delta, l1_tlb=l1_tlb, stlb=stlb,
        counters=counters,
        # hotness decay after the scan (disabled lanes keep their counts)
        access_recent=jnp.where(enabled, st.access_recent // 2,
                                st.access_recent))

    # ---- Algorithm-1 triggers ------------------------------------------------
    # Masking the trigger batch with the (possibly traced) ``mig`` flag makes
    # the whole Algorithm-1 pass a no-op for non-Mig lanes of a sweep.
    trig_pages = jnp.concatenate([dem_pages, pro_pages])
    trig_dest = jnp.concatenate([dem_dest, pro_dest])
    trig_mask = jnp.concatenate([dem_mask, pro_mask]) & jnp.asarray(pc.mig)
    st, l4_cost = migrate_leaf_batch(st, mc, cc, trig_pages, trig_dest,
                                     trig_mask)
    mig_cost = mig_cost + l4_cost
    return st, mig_cost


def migrate_leaf_batch(st: SimState, mc: MachineConfig, cc: CostConfig,
                       pages: jax.Array, dest: jax.Array, mask: jax.Array
                       ) -> Tuple[SimState, jax.Array]:
    """Vectorized Algorithm 1 over a batch of completed data migrations.

    ``pages``/``dest``/``mask`` are i32[K]/i32[K]/bool[K] in trigger order.
    """
    K = pages.shape[0]
    pos = jnp.arange(K, dtype=I32)
    leaf = pages >> mc.radix_bits
    lock_dom = leaf >> mc.lock_domain_shift   # PMD try-lock conflict domain
    n_leaf = st.leaf_node.shape[0]

    # First trigger per leaf page (in batch order) evaluates Algorithm 1.
    order_key = jnp.where(mask, leaf * K + pos, jnp.iinfo(jnp.int32).max)
    sort_idx = jnp.argsort(order_key)
    sorted_leaf = jnp.take(jnp.where(mask, leaf, -1), sort_idx)
    first_sorted = jnp.concatenate([jnp.ones((1,), jnp.bool_),
                                    sorted_leaf[1:] != sorted_leaf[:-1]])
    is_first = jnp.zeros((K,), jnp.bool_).at[sort_idx].set(first_sorted) & mask

    l4_node = jnp.take(st.leaf_node, leaf)
    already_dest = l4_node == dest
    in_same_tier = same_tier(l4_node, dest) & ~already_dest
    children_dram = jnp.take(st.leaf_dram_children, leaf)
    sibling_guard = (~is_dram(dest)) & (children_dram > 0)

    want = is_first & (l4_node >= 0) & ~already_dest & ~in_same_tier & ~sibling_guard

    # PMD try_lock: among wants sharing a lock domain, earliest wins.
    mid_key = jnp.where(want, lock_dom * K + pos, jnp.iinfo(jnp.int32).max)
    mid_sort = jnp.argsort(mid_key)
    sorted_mid = jnp.take(jnp.where(want, lock_dom, -1), mid_sort)
    first_mid = jnp.concatenate([jnp.ones((1,), jnp.bool_),
                                 sorted_mid[1:] != sorted_mid[:-1]])
    lock_ok = jnp.zeros((K,), jnp.bool_).at[mid_sort].set(first_mid) & want
    lock_skip = want & ~lock_ok

    # Destination must have a free page (alloc_pages_node on dest).
    dest_free = jnp.take(st.node_free, jnp.clip(dest, 0, 3))
    can_alloc = dest_free > 0          # approximation: per-batch headroom
    winner = lock_ok & can_alloc
    alloc_fail = lock_ok & ~can_alloc

    src = jnp.where(winner, l4_node, 0)
    # winners are unique per leaf; non-winners are routed out of range so
    # duplicate leaf ids cannot revert a winner's write
    leaf_node = st.leaf_node.at[jnp.where(winner, leaf, n_leaf)].set(
        dest, mode="drop")
    free_delta = (jnp.zeros((4,), I32)
                  .at[jnp.clip(src, 0, 3)].add(winner.astype(I32))
                  .at[jnp.clip(dest, 0, 3)].add(-winner.astype(I32)))

    cost = jnp.sum(jnp.where(winner,
                             cc.migrate_fixed + cc.tlb_flush + cc.alloc_fast +
                             cc.copy_lines * (_read_lat(cc, src) +
                                              _write_lat(cc, dest)), 0.0))

    # Shoot down translations covered by migrated leaf pages.  Winners are
    # unique per leaf, so routing non-winners out of range avoids duplicate
    # scatter hazards.
    leaf_flushed = jnp.zeros((n_leaf,), jnp.bool_)
    leaf_flushed = leaf_flushed.at[jnp.where(winner, leaf, n_leaf)].set(
        True, mode="drop")
    l1_tlb = tlbs.invalidate_matching(st.l1_tlb, leaf_flushed, mc.radix_bits)
    stlb = tlbs.invalidate_matching(st.stlb, leaf_flushed, mc.radix_bits)
    pde_pwc = tlbs.invalidate_matching(st.pde_pwc, leaf_flushed, 0)

    # Skip-reason accounting (paper Table 5).  First triggers were judged
    # against the pre-batch page table; the remaining triggers per leaf run
    # "later" and are judged against the post-migration table — exactly the
    # paper's "the first data page migrated triggers a PTE migration; for the
    # rest, migration is not required as it is already in DRAM".
    first_eval = is_first & (l4_node >= 0)
    others = mask & ~is_first & (leaf >= 0)
    new_l4 = jnp.take(leaf_node, leaf)
    o_already = others & (new_l4 == dest)
    o_tier = others & ~o_already & same_tier(new_l4, dest)
    o_sibling = others & ~o_already & ~o_tier & (~is_dram(dest)) & (children_dram > 0)

    c = st.counters
    c = dataclasses_replace(
        c,
        l4_mig_success=c.l4_mig_success + jnp.sum(winner.astype(I32)),
        l4_mig_already_dest=c.l4_mig_already_dest +
        jnp.sum((first_eval & already_dest).astype(I32)) +
        jnp.sum(o_already.astype(I32)),
        l4_mig_in_dram=c.l4_mig_in_dram +
        jnp.sum((first_eval & in_same_tier).astype(I32)) +
        jnp.sum(o_tier.astype(I32)),
        l4_mig_sibling_guard=c.l4_mig_sibling_guard +
        jnp.sum((first_eval & ~already_dest & ~in_same_tier &
                 sibling_guard).astype(I32)) + jnp.sum(o_sibling.astype(I32)),
        l4_mig_lock_skip=c.l4_mig_lock_skip +
        jnp.sum((lock_skip | alloc_fail).astype(I32)))

    st = dataclasses_replace(st, leaf_node=leaf_node,
                             node_free=st.node_free + free_delta,
                             l1_tlb=l1_tlb, stlb=stlb, pde_pwc=pde_pwc,
                             counters=c)
    return st, cost


def dataclasses_replace(obj, **kw):
    import dataclasses as _dc
    return _dc.replace(obj, **kw)
