"""Data-page balancing (AutoNUMA model) and Algorithm-1 leaf-PT migration.

Semantics (deterministic, mirrored exactly by ``core.ref`` and the paper's
description in sections 4.3-4.4 / 5.2-5.3):

  * Every ``autonuma_period`` steps a scan runs: the hottest NVMM-resident
    data pages (``access_recent`` >= threshold) are promoted to DRAM, bounded
    by the scan budget and free DRAM above the watermark; optionally the
    coldest DRAM pages are demoted first to make room (exchange mode).
  * All data migrations of a scan are applied as one batch (the kernel also
    batches via ``migrate_pages``), then each completed migration *triggers*
    Algorithm 1 for its leaf PT page, in batch order:
      - only the first trigger per leaf page evaluates/migrates (the paper's
        "first data page migrated triggers; the other 511 find the PTE page
        already in the destination" — Table 5);
      - skip if already on the destination node, or on the same tier
        ("with in DRAM"), or if demoting while any sibling data page is
        still DRAM-resident (Alg. 1 line 18);
      - concurrent triggers under one mid-level (PMD) page model the
        ``try_lock`` race: the earliest wins, later ones are lock-skips
        (section 5.3).
  * Migrated leaf pages cost a page copy + fixed overhead + a TLB/PWC
    shootdown; affected translation-cache entries are invalidated.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import tlbs
from .config import (MIG_NOMAD, MIG_TPP, CostConfig, MachineConfig,
                     PolicyConfig)
from .state import SimState, is_dram

I32 = jnp.int32
F32 = jnp.float32


def tier_ext(mc: MachineConfig) -> jax.Array:
    """i32[n_nodes+1] tier per node, indexed by ``node + 1`` so node -1
    (unallocated) maps to the slowest tier — matching the classic
    ``is_dram(-1) -> NVMM latency`` convention."""
    return jnp.asarray((mc.n_tiers - 1,) + mc.tier_of_node, I32)


def tier_read_lat(cc: CostConfig, mc: MachineConfig) -> jax.Array:
    """f32[n_tiers] read latency per tier: DRAM, CXL..., NVMM."""
    vals = [jnp.asarray(cc.dram_read, F32)] + \
        [jnp.asarray(cc.cxl_read, F32)] * (mc.n_tiers - 2) + \
        [jnp.asarray(cc.nvmm_read, F32)]
    return jnp.stack(vals)


def tier_write_lat(cc: CostConfig, mc: MachineConfig) -> jax.Array:
    vals = [jnp.asarray(cc.dram_write, F32)] + \
        [jnp.asarray(cc.cxl_write, F32)] * (mc.n_tiers - 2) + \
        [jnp.asarray(cc.nvmm_write, F32)]
    return jnp.stack(vals)


def _read_lat(cc: CostConfig, mc: MachineConfig, node: jax.Array) -> jax.Array:
    return jnp.take(tier_read_lat(cc, mc), jnp.take(tier_ext(mc), node + 1))


def _write_lat(cc: CostConfig, mc: MachineConfig, node: jax.Array) -> jax.Array:
    return jnp.take(tier_write_lat(cc, mc), jnp.take(tier_ext(mc), node + 1))


def _split_two(n: jax.Array, cap_a: jax.Array, cap_b: jax.Array
               ) -> jax.Array:
    """How many of ``n`` items go to the first of two nodes.

    Fills the node with more headroom first; deterministic and
    capacity-respecting given n <= cap_a + cap_b.
    """
    a_first = cap_a >= cap_b
    share_a = jnp.where(a_first, jnp.minimum(cap_a, n),
                        n - jnp.minimum(cap_b, n))
    return jnp.maximum(share_a, 0)


def _rank_key(count: jax.Array, idx_bits: int) -> jax.Array:
    """Composite int32 sort key: clipped count then low index tie-break."""
    n = 1 << idx_bits
    idx = jnp.arange(count.shape[0], dtype=I32)
    return (jnp.clip(count, 0, 255) << idx_bits) | (n - 1 - idx)


def _top_k_ranked(key: jax.Array, B: int, idx_bits: int) -> jax.Array:
    """Bit-exact replacement for ``jax.lax.top_k(key, B)[1]`` on
    ``_rank_key`` keys.

    XLA's CPU ``top_k`` lowers to a full sort of the whole key array
    (~100 ms at n=256k), which priced one migration-scan tick at ~60
    simulated steps and capped the blocked engine's cadence win.  Rank
    keys are structured — an 8-bit clipped count in the high bits with
    a low-index tie-break below, invalid entries exactly -1 — so the
    top-B falls out of a binary-searched count cutoff plus O(n)
    elementwise passes and one B-element sort.  Exactness: keys are
    distinct except at the shared -1, where ``top_k``'s stable tie
    order is index order, which the cumsum selection reproduces.
    """
    n = key.shape[0]
    if B <= 0:
        return jnp.zeros((0,), I32)
    bucket = (key >> idx_bits) + 1        # 0 invalid (-1 key), 1.. counts
    B_t = jnp.asarray(B, I32)

    # Largest v in [0, 257] with #(bucket >= v) >= B; count_ge is
    # monotone in v and count_ge(0) = n >= B (B is clipped to n_map).
    def half(_, lh):
        lo, hi = lh
        mid = (lo + hi + 1) >> 1
        ge = jnp.sum((bucket >= mid).astype(I32)) >= B_t
        return jnp.where(ge, mid, lo), jnp.where(ge, hi, mid - 1)
    vstar, _ = jax.lax.fori_loop(0, 9, half,           # 2^9 > 258
                                 (jnp.asarray(0, I32),
                                  jnp.asarray(257, I32)))

    sel_gt = bucket > vstar               # all of these are in the top-B
    n_gt = jnp.sum(sel_gt.astype(I32))
    eq = bucket == vstar                  # ties at the cutoff: lowest
    sel_eq = eq & (jnp.cumsum(eq.astype(I32)) <= B_t - n_gt)  # index first
    sel = sel_gt | sel_eq                 # exactly B elements
    # j-th selected index (index order) = first i with cumsum(sel)[i] > j;
    # searchsorted keeps this a handful of gathers instead of an
    # n-update scatter (XLA CPU scatters are serial).
    idxs = jnp.searchsorted(jnp.cumsum(sel.astype(I32)),
                            jnp.arange(1, B + 1, dtype=I32)).astype(I32)
    order = jnp.argsort(-jnp.take(key, idxs), stable=True)
    return jnp.take(idxs, order)


def autonuma_scan(st: SimState, mc: MachineConfig, cc: CostConfig,
                  pc: PolicyConfig, wm: jax.Array, budget: int,
                  va_row: jax.Array, w_row: jax.Array
                  ) -> Tuple[SimState, jax.Array]:
    """One balancing scan + (optionally) Algorithm-1 triggers.

    Runs whichever migration family ``pc.mig_policy`` selects — AutoNUMA
    (the classic promote/exchange scan), TPP (active/inactive split with
    headroom demotion to the next-slower tier) or Nomad (transactional
    promotion with non-exclusive shadow copies) — all through one masked
    dataflow so a vmap sweep can mix families per lane.

    Returns the new state and the total migration cycles of this scan (the
    caller spreads them over threads: the migration daemon steals CPU time).

    ``budget`` is the static upper bound on candidates (it shapes the
    ``top_k`` calls); the PolicyConfig knobs — ``autonuma`` on/off,
    ``autonuma_budget``, threshold, exchange, ``mig``, ``mig_policy`` —
    may all be traced scalars (a vmap policy sweep), so they gate through
    masks: a disabled lane's scan is a bit-exact no-op rather than a
    skipped branch.  ``va_row``/``w_row`` are the current step's access row
    (Nomad's concurrent-write abort condition).
    """
    n_map = st.data_node.shape[0]
    n_nodes = st.node_free.shape[0]
    B = min(int(budget), n_map)
    idx_bits = max(n_map - 1, 1).bit_length()
    enabled = jnp.asarray(pc.autonuma) & ~st.oom_killed
    budget_t = jnp.minimum(jnp.asarray(pc.autonuma_budget, I32), n_map)
    en_tpp = jnp.asarray(pc.mig_policy) == MIG_TPP
    en_nomad = jnp.asarray(pc.mig_policy) == MIG_NOMAD

    # ---- Nomad shadow invalidation ----------------------------------------
    # A write since the last scan dirties the primary copy; its shadow (if
    # any) is stale and is dropped, freeing the shadow's page.  Surviving
    # shadows are clean and eligible to serve a demotion for free.
    shadow = st.shadow_node
    written = st.written_recent
    drop = enabled & en_nomad & (shadow >= 0) & (written > 0)
    free0 = st.node_free + (jnp.zeros((n_nodes,), I32)
                            .at[jnp.clip(shadow, 0, n_nodes - 1)]
                            .add(drop.astype(I32)))
    shadow = jnp.where(drop, -1, shadow)
    n_drops = jnp.sum(drop.astype(I32))

    # ---- hot candidates (promotion) ---------------------------------------
    # "Hot"/"active" is the same recent-access test in every family (TPP's
    # active list == pages at/above the NUMA-hint threshold).
    on_nvmm = (st.data_node >= 2)
    hot_count = jnp.where(on_nvmm & (st.access_recent >= pc.autonuma_threshold),
                          st.access_recent, 0)
    hot_key = jnp.where(hot_count > 0, _rank_key(hot_count, idx_bits), -1)
    hot_pages = _top_k_ranked(hot_key, B, idx_bits)
    hot_valid = jnp.take(hot_key, hot_pages) > 0
    n_hot = jnp.minimum(jnp.sum(hot_valid.astype(I32)), budget_t)

    # Cold DRAM victims.  TPP demotes only *inactive* pages (below the
    # activity threshold); AutoNUMA exchange considers every DRAM page,
    # coldest first.
    on_dram = is_dram(st.data_node)
    elig = on_dram & jnp.where(en_tpp,
                               st.access_recent < pc.autonuma_threshold, True)
    cold_score = jnp.where(elig, 255 - jnp.clip(st.access_recent, 0, 255), 0)
    cold_key = jnp.where(elig, _rank_key(cold_score, idx_bits), -1)
    cold_pages = _top_k_ranked(cold_key, B, idx_bits)
    cold_valid = jnp.take(cold_key, cold_pages) >= 0

    excess0 = jnp.maximum(free0[0] - wm[0], 0)
    excess1 = jnp.maximum(free0[1] - wm[1], 0)
    dram_excess = excess0 + excess1

    n_promote_want = jnp.minimum(n_hot, budget_t)
    need_demote = jnp.maximum(n_promote_want - dram_excess, 0)
    n_victims = jnp.minimum(jnp.sum(cold_valid.astype(I32)), budget_t)

    # TPP demotes ahead of reclaim pressure: keep the low watermark plus a
    # configurable headroom fraction of tier-0 capacity free, independent
    # of promotion demand.
    cap0 = 2 * mc.tier_capacities[0]
    tpp_extra = (jnp.asarray(pc.tpp_demote_wm, F32) * cap0).astype(I32)
    need_tpp = jnp.maximum(wm[0] + wm[1] + tpp_extra - (free0[0] + free0[1]),
                           0)
    need_eff = jnp.where(en_tpp, jnp.maximum(need_tpp, need_demote),
                         need_demote)

    # Demotion destination tier: TPP steps to the *next-slower* non-empty
    # tier; AutoNUMA/Nomad demote straight to the slowest (the classic
    # NVMM pair).  Both node pairs are static; the pick is a traced select.
    caps = mc.tier_capacities
    tpp_t = next(t for t in range(1, mc.n_tiers) if caps[t] > 0)
    dest_a = jnp.where(en_tpp, 2 * tpp_t, 2 * (mc.n_tiers - 1)).astype(I32)
    dest_b = dest_a + 1
    cap_a = jnp.take(free0, dest_a)
    cap_b = jnp.take(free0, dest_b)
    room = jnp.maximum(cap_a, 0) + jnp.maximum(cap_b, 0)
    dem_en = jnp.where(en_tpp, True, jnp.asarray(pc.autonuma_exchange))
    n_demote = jnp.where(enabled & dem_en,
                         jnp.minimum(jnp.minimum(need_eff, n_victims),
                                     room), 0)
    n_promote = jnp.where(enabled,
                          jnp.minimum(n_promote_want, dram_excess + n_demote),
                          0)

    # ---- apply demotions ---------------------------------------------------
    k = jnp.arange(B, dtype=I32)
    dem_mask = k < n_demote
    dem_pages = cold_pages
    share_a = _split_two(n_demote, cap_a, cap_b)
    dem_dest = jnp.where(k < share_a, dest_a, dest_b).astype(I32)
    dem_src = jnp.take(st.data_node, dem_pages)

    # Nomad flip: a demoted page whose (clean) shadow survived skips the
    # copy — the stale-free shadow *becomes* the page, so the destination
    # node gains no new occupancy and the shadow slot is consumed.
    shadow_at_dem = jnp.take(shadow, dem_pages)
    flip = dem_mask & en_nomad & (shadow_at_dem >= 0)
    dem_dest_eff = jnp.where(flip, shadow_at_dem, dem_dest)

    data_node = st.data_node.at[dem_pages].set(
        jnp.where(dem_mask, dem_dest_eff, jnp.take(st.data_node, dem_pages)))
    free_delta = (jnp.zeros((n_nodes,), I32)
                  .at[jnp.clip(dem_src, 0, n_nodes - 1)]
                  .add(dem_mask.astype(I32))
                  .at[jnp.clip(dem_dest_eff, 0, n_nodes - 1)]
                  .add(-(dem_mask & ~flip).astype(I32)))
    shadow = shadow.at[dem_pages].set(
        jnp.where(flip, -1, shadow_at_dem))
    ldc = st.leaf_dram_children.at[dem_pages >> mc.radix_bits].add(
        jnp.where(dem_mask, -1, 0))

    # ---- apply promotions ----------------------------------------------------
    pro_mask = (k < n_promote) & hot_valid
    pro_pages = hot_pages
    excess0b = jnp.maximum(free0[0] + free_delta[0] - wm[0], 0)
    excess1b = jnp.maximum(free0[1] + free_delta[1] - wm[1], 0)
    share0 = _split_two(n_promote, excess0b, excess1b)
    pro_dest = jnp.where(k < share0, 0, 1).astype(I32)
    pro_src = jnp.take(data_node, pro_pages)

    # Nomad transactional abort: a page written *this step* (while the copy
    # is in flight) fails its promotion and retries at a later scan.
    m_row = jnp.clip(va_row >> mc.map_shift, 0, n_map - 1)
    conc_w = jnp.zeros((n_map,), jnp.bool_).at[
        jnp.where((va_row >= 0) & w_row, m_row, n_map)].set(True, mode="drop")
    abort = pro_mask & en_nomad & jnp.take(conc_w, pro_pages)
    commit = pro_mask & ~abort
    # Committed Nomad promotions keep the source copy as a clean shadow
    # (non-exclusive tiering): the source page is NOT freed.
    keep_shadow = commit & en_nomad

    data_node = data_node.at[pro_pages].set(
        jnp.where(commit, pro_dest, jnp.take(data_node, pro_pages)))
    free_delta = (free_delta
                  .at[jnp.clip(pro_src, 0, n_nodes - 1)]
                  .add((commit & ~keep_shadow).astype(I32))
                  .at[pro_dest].add(-commit.astype(I32)))
    shadow = shadow.at[jnp.where(keep_shadow, pro_pages, n_map)].set(
        pro_src, mode="drop")
    ldc = ldc.at[pro_pages >> mc.radix_bits].add(jnp.where(commit, 1, 0))

    n_data_migs = jnp.sum(dem_mask.astype(I32)) + jnp.sum(commit.astype(I32))
    mig_cost = jnp.sum(jnp.where(
        dem_mask, cc.migrate_fixed + cc.tlb_flush +
        jnp.where(flip, jnp.asarray(0.0, F32),
                  cc.copy_lines * (_read_lat(cc, mc, dem_src) +
                                   _write_lat(cc, mc, dem_dest_eff))), 0.0))
    mig_cost += jnp.sum(jnp.where(
        commit, cc.migrate_fixed + cc.tlb_flush +
        cc.copy_lines * (_read_lat(cc, mc, pro_src) +
                         _write_lat(cc, mc, pro_dest)), 0.0))
    # An aborted transactional copy still paid the read half + bookkeeping.
    mig_cost += jnp.sum(jnp.where(
        abort, cc.migrate_fixed + cc.copy_lines * _read_lat(cc, mc, pro_src),
        0.0))

    # TLB shootdown for migrated data pages (non-migrated entries are routed
    # out of range and dropped to avoid duplicate-scatter hazards).
    map_flushed = jnp.zeros((n_map,), jnp.bool_)
    map_flushed = map_flushed.at[jnp.where(dem_mask, dem_pages, n_map)].set(
        True, mode="drop")
    map_flushed = map_flushed.at[jnp.where(commit, pro_pages, n_map)].set(
        True, mode="drop")
    l1_tlb = tlbs.invalidate_matching(st.l1_tlb, map_flushed, 0)
    stlb = tlbs.invalidate_matching(st.stlb, map_flushed, 0)

    counters = st.counters
    counters = dataclasses_replace(
        counters,
        data_migrations=counters.data_migrations + n_data_migs,
        demotions=counters.demotions + jnp.sum(dem_mask.astype(I32)),
        nomad_retries=counters.nomad_retries + jnp.sum(abort.astype(I32)),
        nomad_flip_demotions=counters.nomad_flip_demotions +
        jnp.sum(flip.astype(I32)),
        nomad_shadow_drops=counters.nomad_shadow_drops + n_drops)

    st = dataclasses_replace(
        st, data_node=data_node, leaf_dram_children=ldc,
        node_free=free0 + free_delta, shadow_node=shadow,
        l1_tlb=l1_tlb, stlb=stlb, counters=counters,
        # Nomad's write-tracking window resets at its scan tick; hotness
        # decay after the scan (disabled lanes keep their counts).
        written_recent=jnp.where(enabled & en_nomad, 0, written),
        access_recent=jnp.where(enabled, st.access_recent // 2,
                                st.access_recent))

    # ---- Algorithm-1 triggers ------------------------------------------------
    # Masking the trigger batch with the (possibly traced) ``mig`` flag makes
    # the whole Algorithm-1 pass a no-op for non-Mig lanes of a sweep.
    trig_pages = jnp.concatenate([dem_pages, pro_pages])
    trig_dest = jnp.concatenate([dem_dest_eff, pro_dest])
    trig_mask = jnp.concatenate([dem_mask, commit]) & jnp.asarray(pc.mig)
    st, l4_cost = migrate_leaf_batch(st, mc, cc, trig_pages, trig_dest,
                                     trig_mask)
    mig_cost = mig_cost + l4_cost
    return st, mig_cost


def migrate_leaf_batch(st: SimState, mc: MachineConfig, cc: CostConfig,
                       pages: jax.Array, dest: jax.Array, mask: jax.Array
                       ) -> Tuple[SimState, jax.Array]:
    """Vectorized Algorithm 1 over a batch of completed data migrations.

    ``pages``/``dest``/``mask`` are i32[K]/i32[K]/bool[K] in trigger order.
    """
    K = pages.shape[0]
    pos = jnp.arange(K, dtype=I32)
    leaf = pages >> mc.radix_bits
    lock_dom = leaf >> mc.lock_domain_shift   # PMD try-lock conflict domain
    n_leaf = st.leaf_node.shape[0]

    # First trigger per leaf page (in batch order) evaluates Algorithm 1.
    order_key = jnp.where(mask, leaf * K + pos, jnp.iinfo(jnp.int32).max)
    sort_idx = jnp.argsort(order_key)
    sorted_leaf = jnp.take(jnp.where(mask, leaf, -1), sort_idx)
    first_sorted = jnp.concatenate([jnp.ones((1,), jnp.bool_),
                                    sorted_leaf[1:] != sorted_leaf[:-1]])
    is_first = jnp.zeros((K,), jnp.bool_).at[sort_idx].set(first_sorted) & mask

    text = tier_ext(mc)
    tier_of = lambda n: jnp.take(text, n + 1)   # noqa: E731
    l4_node = jnp.take(st.leaf_node, leaf)
    already_dest = l4_node == dest
    in_same_tier = (tier_of(l4_node) == tier_of(dest)) & ~already_dest
    children_dram = jnp.take(st.leaf_dram_children, leaf)
    dest_slower = tier_of(dest) > 0            # == ~is_dram(dest) for 2 tiers
    sibling_guard = dest_slower & (children_dram > 0)

    want = is_first & (l4_node >= 0) & ~already_dest & ~in_same_tier & ~sibling_guard

    # PMD try_lock: among wants sharing a lock domain, earliest wins.
    mid_key = jnp.where(want, lock_dom * K + pos, jnp.iinfo(jnp.int32).max)
    mid_sort = jnp.argsort(mid_key)
    sorted_mid = jnp.take(jnp.where(want, lock_dom, -1), mid_sort)
    first_mid = jnp.concatenate([jnp.ones((1,), jnp.bool_),
                                 sorted_mid[1:] != sorted_mid[:-1]])
    lock_ok = jnp.zeros((K,), jnp.bool_).at[mid_sort].set(first_mid) & want
    lock_skip = want & ~lock_ok

    # Destination must have a free page (alloc_pages_node on dest).
    n_nodes = st.node_free.shape[0]
    dest_free = jnp.take(st.node_free, jnp.clip(dest, 0, n_nodes - 1))
    can_alloc = dest_free > 0          # approximation: per-batch headroom
    winner = lock_ok & can_alloc
    alloc_fail = lock_ok & ~can_alloc

    src = jnp.where(winner, l4_node, 0)
    # winners are unique per leaf; non-winners are routed out of range so
    # duplicate leaf ids cannot revert a winner's write
    leaf_node = st.leaf_node.at[jnp.where(winner, leaf, n_leaf)].set(
        dest, mode="drop")
    free_delta = (jnp.zeros((n_nodes,), I32)
                  .at[jnp.clip(src, 0, n_nodes - 1)].add(winner.astype(I32))
                  .at[jnp.clip(dest, 0, n_nodes - 1)].add(-winner.astype(I32)))

    cost = jnp.sum(jnp.where(winner,
                             cc.migrate_fixed + cc.tlb_flush + cc.alloc_fast +
                             cc.copy_lines * (_read_lat(cc, mc, src) +
                                              _write_lat(cc, mc, dest)), 0.0))

    # Shoot down translations covered by migrated leaf pages.  Winners are
    # unique per leaf, so routing non-winners out of range avoids duplicate
    # scatter hazards.
    leaf_flushed = jnp.zeros((n_leaf,), jnp.bool_)
    leaf_flushed = leaf_flushed.at[jnp.where(winner, leaf, n_leaf)].set(
        True, mode="drop")
    l1_tlb = tlbs.invalidate_matching(st.l1_tlb, leaf_flushed, mc.radix_bits)
    stlb = tlbs.invalidate_matching(st.stlb, leaf_flushed, mc.radix_bits)
    pde_pwc = tlbs.invalidate_matching(st.pde_pwc, leaf_flushed, 0)

    # Skip-reason accounting (paper Table 5).  First triggers were judged
    # against the pre-batch page table; the remaining triggers per leaf run
    # "later" and are judged against the post-migration table — exactly the
    # paper's "the first data page migrated triggers a PTE migration; for the
    # rest, migration is not required as it is already in DRAM".
    first_eval = is_first & (l4_node >= 0)
    others = mask & ~is_first & (leaf >= 0)
    new_l4 = jnp.take(leaf_node, leaf)
    o_already = others & (new_l4 == dest)
    o_tier = others & ~o_already & (tier_of(new_l4) == tier_of(dest))
    o_sibling = others & ~o_already & ~o_tier & dest_slower & (children_dram > 0)

    c = st.counters
    c = dataclasses_replace(
        c,
        l4_mig_success=c.l4_mig_success + jnp.sum(winner.astype(I32)),
        l4_mig_already_dest=c.l4_mig_already_dest +
        jnp.sum((first_eval & already_dest).astype(I32)) +
        jnp.sum(o_already.astype(I32)),
        l4_mig_in_dram=c.l4_mig_in_dram +
        jnp.sum((first_eval & in_same_tier).astype(I32)) +
        jnp.sum(o_tier.astype(I32)),
        l4_mig_sibling_guard=c.l4_mig_sibling_guard +
        jnp.sum((first_eval & ~already_dest & ~in_same_tier &
                 sibling_guard).astype(I32)) + jnp.sum(o_sibling.astype(I32)),
        l4_mig_lock_skip=c.l4_mig_lock_skip +
        jnp.sum((lock_skip | alloc_fail).astype(I32)))

    st = dataclasses_replace(st, leaf_node=leaf_node,
                             node_free=st.node_free + free_delta,
                             l1_tlb=l1_tlb, stlb=stlb, pde_pwc=pde_pwc,
                             counters=c)
    return st, cost


def dataclasses_replace(obj, **kw):
    import dataclasses as _dc
    return _dc.replace(obj, **kw)
