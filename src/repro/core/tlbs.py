"""Vectorized, per-thread TLB and page-walk-cache (PWC) models.

Every simulated CPU thread owns a private translation hierarchy:

  * L1 dTLB           set-associative, tags are mapping-granule indices
  * STLB (L2 TLB)     set-associative, checked on an L1 miss
  * PDE  PWC          fully associative, caches pointers to *leaf* PT pages
                      (tag = map_idx >> 9); a hit skips all upper levels
  * PDPTE PWC         fully associative, caches pointers to mid-level pages
                      (tag = map_idx >> 18); a hit skips root/top reads

All structures are dense int32 arrays with a leading thread axis so lookups
and updates vectorize across threads.  LRU is kept as a monotonically
increasing timestamp (the global step counter); empty slots carry -1 so
``argmin`` naturally selects empty-then-oldest with deterministic (lowest
way) tie-breaking — a property the pure-Python oracle replicates exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TlbArray:
    """One set-associative, per-thread translation cache."""

    tags: jax.Array  # i32[T, sets, ways], -1 = invalid
    lru: jax.Array   # i32[T, sets, ways], -1 = empty, else last-use step


def make_tlb(n_threads: int, sets: int, ways: int) -> TlbArray:
    shape = (n_threads, sets, ways)
    return TlbArray(tags=jnp.full(shape, -1, jnp.int32),
                    lru=jnp.full(shape, -1, jnp.int32))


def _sets(tlb: TlbArray) -> int:
    return tlb.tags.shape[1]


def lookup(tlb: TlbArray, tag: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Vectorized lookup of one tag per thread.

    Returns (hit: bool[T], way_or_victim: i32[T]).  ``way_or_victim`` is the
    hitting way on a hit, else the LRU victim way for a subsequent insert.
    """
    set_idx = tag % _sets(tlb)                            # i32[T]
    t_idx = jnp.arange(tlb.tags.shape[0])
    set_tags = tlb.tags[t_idx, set_idx]                   # i32[T, ways]
    set_lru = tlb.lru[t_idx, set_idx]
    match = set_tags == tag[:, None]
    hit = jnp.any(match, axis=1)
    hit_way = jnp.argmax(match, axis=1)
    victim_way = jnp.argmin(set_lru, axis=1)
    return hit, jnp.where(hit, hit_way, victim_way)


def update(tlb: TlbArray, tag: jax.Array, way: jax.Array, now: jax.Array,
           active: jax.Array) -> TlbArray:
    """Touch-or-insert ``tag`` at ``way`` for threads with ``active`` set."""
    set_idx = tag % _sets(tlb)
    t_idx = jnp.arange(tlb.tags.shape[0])
    new_tags = tlb.tags.at[t_idx, set_idx, way].set(
        jnp.where(active, tag, tlb.tags[t_idx, set_idx, way]))
    new_lru = tlb.lru.at[t_idx, set_idx, way].set(
        jnp.where(active, now, tlb.lru[t_idx, set_idx, way]))
    return TlbArray(tags=new_tags, lru=new_lru)


def update_one(tlb: TlbArray, thread: jax.Array, tag: jax.Array,
               now: jax.Array, active: jax.Array) -> TlbArray:
    """Scalar touch-or-insert for a single thread (used in the fault path)."""
    sets = _sets(tlb)
    set_idx = tag % sets
    set_tags = jax.lax.dynamic_slice(tlb.tags, (thread, set_idx, 0),
                                     (1, 1, tlb.tags.shape[2]))[0, 0]
    set_lru = jax.lax.dynamic_slice(tlb.lru, (thread, set_idx, 0),
                                    (1, 1, tlb.lru.shape[2]))[0, 0]
    match = set_tags == tag
    hit = jnp.any(match)
    way = jnp.where(hit, jnp.argmax(match), jnp.argmin(set_lru))
    new_tags = tlb.tags.at[thread, set_idx, way].set(
        jnp.where(active, tag, tlb.tags[thread, set_idx, way]))
    new_lru = tlb.lru.at[thread, set_idx, way].set(
        jnp.where(active, now, tlb.lru[thread, set_idx, way]))
    return TlbArray(tags=new_tags, lru=new_lru)


def lookup_one(tlb: TlbArray, thread: jax.Array, tag: jax.Array) -> jax.Array:
    """Scalar hit test for a single thread (no state change)."""
    set_idx = tag % _sets(tlb)
    set_tags = jax.lax.dynamic_slice(tlb.tags, (thread, set_idx, 0),
                                     (1, 1, tlb.tags.shape[2]))[0, 0]
    return jnp.any(set_tags == tag)


def invalidate_matching(tlb: TlbArray, flushed_lookup: jax.Array,
                        shift: int) -> TlbArray:
    """Invalidate every entry whose ``tag >> shift`` indexes a set bit.

    ``flushed_lookup`` is a bool[n] table; entry tags are right-shifted by
    ``shift`` before indexing it.  This models targeted TLB shootdowns after
    a data-page migration (shift=0, table over map granules) and after a
    leaf-PT-page migration (shift=9, table over leaf PT pages).
    """
    valid = tlb.tags >= 0
    idx = jnp.clip(tlb.tags >> shift, 0, flushed_lookup.shape[0] - 1)
    kill = valid & flushed_lookup[idx]
    return TlbArray(tags=jnp.where(kill, -1, tlb.tags),
                    lru=jnp.where(kill, -1, tlb.lru))


def flush_all(tlb: TlbArray) -> TlbArray:
    return TlbArray(tags=jnp.full_like(tlb.tags, -1),
                    lru=jnp.full_like(tlb.lru, -1))
