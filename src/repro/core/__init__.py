"""Radiant core: page-table placement & migration for tiered memory.

Faithful JAX reproduction of "Page Table Management for Heterogeneous
Memory Systems" (Kumar et al., 2021).  See DESIGN.md section 2, Pillar A.
"""
from .config import (CostConfig, MachineConfig, PolicyConfig, FIRST_TOUCH,
                     INTERLEAVE, MIG_AUTONUMA, MIG_NOMAD, MIG_TPP,
                     PT_BIND_ALL, PT_BIND_HIGH, PT_FOLLOW_DATA,
                     benchmark_machine, bhi, bhi_mig, bind_all, cxl_machine,
                     linux_default, nomad, tpp)
from .sim import (RunResult, TieredMemSimulator, Trace, fault_schedule,
                  fault_step_mask, pad_trace)
from .state import SimState, init_state, is_dram, same_tier
from .sweep import compile_count as sweep_compile_count
from .sweep import lane_mesh, stack_policies, sweep, sweep_lanes
from .workloads import TraceSpec, trace_digest
from . import workloads

__all__ = [
    "CostConfig", "MachineConfig", "PolicyConfig", "FIRST_TOUCH",
    "INTERLEAVE", "MIG_AUTONUMA", "MIG_NOMAD", "MIG_TPP",
    "PT_BIND_ALL", "PT_BIND_HIGH", "PT_FOLLOW_DATA",
    "benchmark_machine", "bhi", "bhi_mig", "bind_all", "cxl_machine",
    "linux_default", "nomad", "tpp",
    "RunResult", "TieredMemSimulator", "Trace", "TraceSpec",
    "fault_schedule", "fault_step_mask", "lane_mesh",
    "pad_trace", "SimState", "init_state", "is_dram", "same_tier",
    "stack_policies", "sweep", "sweep_compile_count", "sweep_lanes",
    "trace_digest", "workloads",
]
