"""Batched policy-sweep engine: N policies × M traces in ONE ``lax.scan``.

Every benchmark in the reproduction compares page-table placement policies
on identical access traces.  Running them as separate Python-loop
iterations compiles one scan per policy and pays a device round-trip each;
this module instead stacks the policies (and optionally several same-shape
padded traces) into a leading *lane* axis, vmaps the policy-generic
simulator step (``sim._build_step``) over it, and runs the whole grid as a
single compiled ``lax.scan`` — one compile per trace shape, one device
program per figure.

Two entry points share the engine:

  * :func:`sweep` — the figure-style cross product: N policies × M traces.
  * :func:`sweep_lanes` — one lane per independent ``(cost, policy,
    trace)`` tuple.  This is the microbatch primitive of the simulation
    service (``repro.service``): a broker bucketing arbitrary concurrent
    queries by trace shape flushes each bucket through one call here.

Execution is time-blocked by default (``engine="blocked"``, see
``core.sim``): the scan iterates fixed ``[block, T]`` step-windows,
host-classified from the *union* event schedule over lanes (frees,
AutoNUMA ticks, faults — union predicates, like the per-step schedule
bits before them, so block boundaries stay lane-shared and
policy-independent).  Event-free windows run as one vectorized
fast-path step per lane; a window whose only event is a single scan
tick hoists it between two fast segments; narrow event spans replay
per-step only inside the span; wide spans replay the whole window.
Window count depends only on the trace *shape* and the segment
capacities are pow2-quantized into the compile key
(``sim.plan_windows``), so the compiled-program quantization the
broker's shape buckets rely on is untouched.  ``engine="per_step"``
keeps the step-at-a-time reference scan.

Lanes can additionally be sharded across devices (``lane_sharding`` —
``jax.sharding`` over the lane axis): the state pytree and every per-lane
input are placed with a ``PartitionSpec`` over a 1-D ``"lanes"`` mesh, so
a policy grid spreads over all local devices with no change to the scan
body.  On a single-device host the mesh degenerates and results are
bit-identical to the unsharded path.

Correctness contract: a sweep lane is bit-identical (placements,
counters; cycles to float32 rounding — and bit-exact between the blocked
and per-step engines) to the corresponding sequential
``TieredMemSimulator`` run and to the pure-Python ``core.ref`` oracle —
``tests/test_sweep.py``, ``tests/test_blocked.py`` and
``tests/test_service.py`` enforce these.

Constraints inherited from the step being compiled once for all lanes:

  * all traces must share one ``[steps, threads]`` shape (``pad_trace``);
  * all AutoNUMA-enabled policies must share ``autonuma_period`` (the scan
    schedule is a host-precomputed, lane-shared predicate so ``lax.cond``
    survives vmap);
  * the AutoNUMA ``top_k`` bound is the max ``autonuma_budget`` over the
    swept policies (or the explicit ``budget`` override, which may only
    raise it); per-lane budgets gate through traced masks, so an
    over-provisioned bound never changes results — brokers quantize it to
    keep compile keys stable across bursts.  The allocator conflict-group
    bound (``group``) quantizes the same way: power-of-two of the batch
    maximum, overridable upward.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..obs import or_null
from .config import CostConfig, MachineConfig, PolicyConfig
from .sim import (DEFAULT_BLOCK, RunResult, SCHED_DO, TIMELINE_KEYS, Trace,
                  _build_blocked_body, _build_step, _normalize_blocked,
                  fault_group_bound, fault_schedule, plan_windows, pow2ceil,
                  scan_step_mask, seg_of_leaf_table, window_tiles)
from .state import init_state

I32 = jnp.int32
F32 = jnp.float32

# One jitted vmapped scan per (machine, budget, engines, block, group,
# split geometry); jax's jit cache then holds one executable per (lane
# count, trace shape, lane sharding).
_SWEEP_CACHE: Dict[Tuple, object] = {}
# Fallback compile accounting for jax versions without the (private)
# jit _cache_size API: one entry per distinct compiled signature.
_SIGNATURES = set()


def compile_count() -> int:
    """Number of XLA compilations performed by sweep()/sweep_lanes() so far.

    Counts entries in the underlying jit caches (one per distinct
    (machine, budget, engine, lane-count, trace-shape, sharding)
    combination) — tests assert a ≥4-policy sweep adds exactly one and
    that a service-cache hit adds zero.  Falls back to the engine's own
    signature accounting if the jit cache-size API is unavailable.
    """
    sizes = [getattr(fn, "_cache_size", None) for fn in _SWEEP_CACHE.values()]
    if all(s is not None for s in sizes):
        return int(sum(s() for s in sizes))
    return len(_SIGNATURES)


def stack_policies(policies: Sequence[PolicyConfig]) -> PolicyConfig:
    """Stack N PolicyConfigs into one whose leaves are ``[N]`` arrays."""
    return _stack_leaves(list(policies))


def _stack_leaves(objs):
    def stack(*leaves):
        a = np.stack([np.asarray(leaf) for leaf in leaves])
        if a.dtype.kind in "iu":
            return jnp.asarray(a, I32)
        if a.dtype.kind == "f":
            return jnp.asarray(a, F32)
        return jnp.asarray(a)
    return jax.tree.map(stack, *objs)


def _sweep_runner(mc: MachineConfig, budget: int, phase_b: str,
                  engine: str, block: int, group: Optional[int],
                  geom=None):
    if engine == "blocked":
        budget, phase_b, group = _normalize_blocked(budget, phase_b, group,
                                                    geom)
    key = (mc, budget, phase_b, engine, block, group, geom)
    if key not in _SWEEP_CACHE:
        if engine == "per_step":
            step = _build_step(mc, budget, phase_b, group)

            @jax.jit
            def run_sweep(st, cc, pc, xs, seg_of_map, seg_of_leaf):
                def body(carry, x):
                    va_row, w_row, fid, llc, sched, do_free, do_scan, \
                        has_fault, valid = x

                    def lane(st1, cc1, pc1, va1, w1, fid1, llc1, sched1,
                             sm, sl):
                        # the schedule predicates stay un-batched so the
                        # step's lax.conds keep skipping work under vmap;
                        # the per-thread fault-schedule row is per-lane
                        # (one per trace) and rides the vmap like the va
                        # row
                        return step(st1, cc1, pc1,
                                    (va1, w1, fid1, llc1, sched1, do_free,
                                     do_scan, has_fault, valid), sm, sl)
                    return jax.vmap(lane)(carry, cc, pc, va_row, w_row,
                                          fid, llc, sched, seg_of_map,
                                          seg_of_leaf)
                return jax.lax.scan(body, st, xs)
        else:
            # the window body (kind dispatch, fast/full/hoist/split
            # branches, lane vmaps) is shared with the solo runner —
            # sim._build_blocked_body, lanes=True
            window = _build_blocked_body(mc, budget, phase_b, group,
                                         block, geom, lanes=True)

            @jax.jit
            def run_sweep(st, cc, pc, xs, seg_of_map, seg_of_leaf):
                def body(carry, xw):
                    return window(carry, xw, cc, pc, seg_of_map,
                                  seg_of_leaf)
                return jax.lax.scan(body, st, xs)

        _SWEEP_CACHE[key] = run_sweep
    return _SWEEP_CACHE[key]


def lane_mesh(n_lanes: int, devices=None) -> Mesh:
    """A 1-D ``"lanes"`` mesh over the largest device prefix dividing
    ``n_lanes`` (every device on an evenly divisible lane count; one
    device — the degenerate mesh — when nothing divides)."""
    devices = list(jax.devices() if devices is None else devices)
    n = len(devices)
    while n > 1 and n_lanes % n:
        n -= 1
    return Mesh(np.asarray(devices[:n]), ("lanes",))


def _resolve_lane_sharding(lane_sharding, n_lanes: int) -> Optional[Mesh]:
    if lane_sharding is None:
        return None
    if lane_sharding == "auto":
        return lane_mesh(n_lanes)
    if isinstance(lane_sharding, Mesh):
        if n_lanes % lane_sharding.devices.size:
            raise ValueError(
                f"{n_lanes} lanes not divisible by the {lane_sharding.devices.size}-"
                "device lane mesh")
        return lane_sharding
    raise ValueError(f"lane_sharding must be None, 'auto' or a Mesh, got "
                     f"{lane_sharding!r}")


def sweep_lanes(mc: MachineConfig,
                ccs: Sequence[CostConfig],
                policies: Sequence[PolicyConfig],
                traces: Sequence[Trace],
                phase_b: str = "batched",
                budget: Optional[int] = None,
                lane_sharding=None,
                engine: str = "blocked",
                block: int = DEFAULT_BLOCK,
                group: Optional[int] = None,
                debug: bool = False,
                telemetry=None,
                ) -> List[RunResult]:
    """Run L independent (cost, policy, trace) lanes as one batched scan.

    The service-broker primitive: unlike :func:`sweep` there is no cross
    product — lane ``i`` simulates ``traces[i]`` under ``policies[i]`` /
    ``ccs[i]``.  All traces must share one ``[steps, threads]`` shape
    (shape-bucketing is the caller's job; see ``repro.service.broker``).

    ``budget`` (optional) raises the compiled AutoNUMA ``top_k`` bound
    above the per-lane maximum so repeated calls with different policy
    mixes reuse one executable; per-lane budgets still gate exactly.
    ``group`` raises the allocator conflict-group bound the same way (the
    computed bound is already power-of-two-quantized).

    ``engine`` / ``block`` select the stepper (see ``core.sim``):
    time-blocked windows by default, with event windows — the union over
    lanes, so block boundaries stay lane-shared and policy-independent —
    falling back to the exact per-step path.

    ``lane_sharding`` — ``None`` (single device), ``"auto"`` (shard the
    lane axis over every local device that divides the lane count), or an
    explicit 1-D ``"lanes"`` :class:`jax.sharding.Mesh`.

    The per-step engine and the sequential fault path are reference
    (oracle) configurations kept for differential testing; production
    callers get the blocked/batched fast path.  Pass ``debug=True`` to
    run a reference path deliberately.

    ``telemetry`` (optional :class:`repro.obs.Telemetry`) records
    host-side counters (lanes, fast vs event windows), a device-time
    histogram and — when tracing — ``sweep.prepare`` / ``sweep.device``
    spans plus one ``window.fast`` / ``window.event`` span per scan
    window (window classification is host data; device time is
    attributed uniformly across windows since the compiled scan is
    opaque).  Every hook is host-side Python: the compiled program and
    its outputs are bitwise-identical with telemetry on or off.
    """
    tel = or_null(telemetry)
    prep_t0 = tel.now()
    if engine not in ("blocked", "per_step"):
        raise ValueError(f"unknown engine {engine!r}")
    if (engine != "blocked" or phase_b != "batched") and not debug:
        raise ValueError(
            f"engine={engine!r} phase_b={phase_b!r} are reference (oracle) "
            "paths; pass debug=True to run them")
    policies = list(policies)
    ccs = list(ccs)
    tr_list = list(traces)
    L = len(policies)
    if L == 0:
        raise ValueError("sweep_lanes needs at least one lane")
    if not (len(ccs) == len(tr_list) == L):
        raise ValueError(
            f"lane lists disagree: {len(ccs)} costs, {L} policies, "
            f"{len(tr_list)} traces")

    shape = tr_list[0].va.shape
    for tr in tr_list:
        if tr.va.shape != shape:
            raise ValueError(
                f"sweep traces must share one shape; got {tr.va.shape} vs "
                f"{shape} — pad_trace() them first")
    if shape[1] != mc.n_threads:
        raise ValueError(f"traces have {shape[1]} threads, machine has "
                         f"{mc.n_threads}")

    periods = sorted({int(p.autonuma_period) for p in policies
                      if bool(p.autonuma)})
    if len(periods) > 1:
        raise ValueError(
            f"swept policies must share autonuma_period, got {periods}; the "
            "scan schedule is lane-shared")
    period = periods[0] if periods else int(policies[0].autonuma_period)
    lane_budget = min(max(int(p.autonuma_budget) for p in policies),
                      mc.n_map)
    if budget is not None and budget < lane_budget:
        raise ValueError(f"budget override {budget} below the lane maximum "
                         f"{lane_budget}; a smaller top_k bound changes "
                         "results")
    eff_budget = min(budget if budget is not None else lane_budget, mc.n_map)

    lane_pc = _stack_leaves(policies)
    lane_cc = _stack_leaves(ccs)

    # Host arrays are built per *unique trace object* and fanned out to
    # lanes by index, so a bucket of queries sharing one trace pays one
    # schedule pass and one stack.
    uniq: Dict[int, int] = {}
    uniq_traces: List[Trace] = []
    lane_of = np.empty((L,), np.int64)
    for i, tr in enumerate(tr_list):
        j = uniq.setdefault(id(tr), len(uniq_traces))
        if j == len(uniq_traces):
            uniq_traces.append(tr)
        lane_of[i] = j

    S = shape[0]
    scheds = [fault_schedule(tr, mc) for tr in uniq_traces]

    eff_group: Optional[int] = None
    if phase_b == "batched":
        lane_group = min(
            pow2ceil(max(fault_group_bound(sc) for sc in scheds)),
            mc.n_threads)
        if group is not None and group < lane_group:
            raise ValueError(f"group override {group} below the lane "
                             f"maximum {lane_group}; a smaller conflict-"
                             "group bound drops allocator requests")
        eff_group = min(group if group is not None else lane_group,
                        mc.n_threads)

    def lanes(per_trace, dtype):
        a = np.stack([np.asarray(x, dtype) for x in per_trace], axis=1)
        return a[:, lane_of]

    va = lanes([tr.va for tr in uniq_traces], np.int32)          # [S, L, T]
    wr = lanes([tr.is_write for tr in uniq_traces], bool)
    fid = lanes([tr.free_seg for tr in uniq_traces], np.int32)   # [S, L]
    llc = lanes([tr.llc for tr in uniq_traces], np.float32)
    sched = lanes(scheds, np.uint8)                              # [S, L, T]

    do_free = np.zeros((S,), bool)
    has_fault = np.zeros((S,), bool)
    for sc, tr in zip(scheds, uniq_traces):
        do_free |= np.asarray(tr.free_seg) >= 0
        has_fault |= (sc & SCHED_DO).any(axis=1)
    do_scan = scan_step_mask(S, period,
                             enabled=any(bool(p.autonuma) for p in policies))

    eff_block = min(int(block), pow2ceil(S))
    plan = None
    if engine == "per_step":
        xs = (jnp.asarray(va), jnp.asarray(wr), jnp.asarray(fid),
              jnp.asarray(llc), jnp.asarray(sched), jnp.asarray(do_free),
              jnp.asarray(do_scan), jnp.asarray(has_fault),
              jnp.ones((S,), jnp.bool_))
        lane_axis_of_x = (1, 1, 1, 1, 1, None, None, None, None)
    else:
        # window classification from the lane-union schedule; same
        # 9-array order and pad fills as sim.blocked_xs
        # (WINDOW_PAD_FILLS) — pad-row semantics must match the solo path
        plan = plan_windows(do_free, do_scan, has_fault, S, eff_block)
        va_w, wr_w, fid_w, llc_w, sched_w, vl_w, df_w, ds_w, hf_w = \
            window_tiles(
                (va, wr, fid, llc, sched, np.ones((S,), bool), do_free,
                 do_scan, has_fault),
                S, eff_block, rows_to=plan.rows_in)
        xs = tuple(jnp.asarray(a) for a in
                   (va_w, wr_w, fid_w, llc_w, sched_w, vl_w, df_w, ds_w,
                    hf_w, plan.kind, plan.seg_a, plan.seg_b))
        # windowed lane arrays carry the lane axis at position 2
        lane_axis_of_x = (2, 2, 2, 2, 2, None, None, None, None, None,
                          None, None)

    seg_maps = np.stack([np.asarray(tr.seg_of_map, np.int32)
                         for tr in uniq_traces])
    seg_of_map = jnp.asarray(seg_maps[lane_of])                  # [L, n_map]
    seg_leafs = np.stack([np.asarray(seg_of_leaf_table(tr, mc))
                          for tr in uniq_traces])
    seg_of_leaf = jnp.asarray(seg_leafs[lane_of])                # [L, n_leaf]

    st0 = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (L,) + a.shape),
                       init_state(mc))

    mesh = _resolve_lane_sharding(lane_sharding, L)
    shard_key = None
    if mesh is not None:
        shard_key = int(mesh.devices.size)
        lane_sh = NamedSharding(mesh, P("lanes"))
        rep_sh = NamedSharding(mesh, P())
        put = jax.device_put
        st0 = jax.tree.map(lambda a: put(a, lane_sh), st0)
        lane_cc = jax.tree.map(lambda a: put(a, lane_sh), lane_cc)
        lane_pc = jax.tree.map(lambda a: put(a, lane_sh), lane_pc)
        xs = tuple(
            put(x, rep_sh if ax is None else NamedSharding(
                mesh, P(*([None] * ax + ["lanes"]))))
            for x, ax in zip(xs, lane_axis_of_x))
        seg_of_map = put(seg_of_map, lane_sh)
        seg_of_leaf = put(seg_of_leaf, lane_sh)

    geom = plan.geom if plan is not None else None
    sig_budget, sig_phase_b, sig_group = eff_budget, phase_b, eff_group
    if engine == "blocked":
        sig_budget, sig_phase_b, sig_group = _normalize_blocked(
            eff_budget, phase_b, eff_group, geom)
    run_sweep = _sweep_runner(mc, eff_budget, phase_b, engine, eff_block,
                              eff_group, geom)
    _SIGNATURES.add((mc, sig_budget, sig_phase_b, engine, eff_block,
                     sig_group, geom, L, S, shard_key))

    if tel.enabled:
        tel.counter("sweep.calls", engine=engine).inc()
        tel.counter("sweep.lanes", engine=engine).inc(L)
        if engine == "blocked":
            n_fast, _, n_hoist, n_split = plan.counts
            tel.counter("sweep.windows_event").inc(plan.n_windows - n_fast)
            tel.counter("sweep.windows_fast").inc(n_fast)
            tel.counter("sweep.windows_hoist").inc(n_hoist)
            tel.counter("sweep.windows_split").inc(n_split)
        else:
            tel.counter("sweep.steps").inc(S)
        if prep_t0 is not None:
            tel.add_span("sweep.prepare", prep_t0, tel.now(), cat="engine",
                         args={"lanes": L, "steps": S, "engine": engine})

    dev_t0 = tel.now()
    wall_t0 = time.perf_counter()
    final, outs = run_sweep(st0, lane_cc, lane_pc, xs, seg_of_map,
                            seg_of_leaf)
    final = jax.device_get(final)
    outs = [np.asarray(o) for o in jax.device_get(outs)]
    if tel.enabled:
        tel.histogram("sweep.device_seconds").observe(
            time.perf_counter() - wall_t0)
    if dev_t0 is not None:
        dev_t1 = tel.now()
        tel.add_span("sweep.device", dev_t0, dev_t1, cat="engine",
                     args={"lanes": L, "steps": S, "engine": engine})
        if engine == "blocked":
            # The compiled scan is opaque, so device wall time is
            # attributed uniformly across windows; the window
            # classification itself is exact (host-side schedule;
            # branch 0 is the whole-window fast path).
            n_w = plan.n_windows
            w_dur = (dev_t1 - dev_t0) / max(n_w, 1)
            for i, k in enumerate(plan.kind):
                tel.add_span("window.event" if k else "window.fast",
                             dev_t0 + i * w_dur, dev_t0 + (i + 1) * w_dur,
                             cat="engine", tid=1, args={"window": i})
    if engine == "blocked":
        # [n_windows, R_out, L] -> [steps, L]: pad and capacity-slack
        # rows dropped in step order via the plan's emission mask
        outs = [o[plan.emit_valid] for o in outs]

    results: List[RunResult] = []
    for i, (pc, tr) in enumerate(zip(policies, tr_list)):
        st_lane = jax.tree.map(lambda a: a[i], final)
        timeline = {k: v[:, i] for k, v in zip(TIMELINE_KEYS, outs)}
        results.append(RunResult(final_state=st_lane, timeline=timeline,
                                 trace_name=tr.name,
                                 policy_label=pc.label()))
    return results


def sweep(mc: MachineConfig,
          cc: Union[CostConfig, Sequence[CostConfig]],
          policies: Sequence[PolicyConfig],
          traces: Union[Trace, Sequence[Trace]],
          phase_b: str = "batched",
          budget: Optional[int] = None,
          lane_sharding=None,
          engine: str = "blocked",
          block: int = DEFAULT_BLOCK,
          debug: bool = False,
          telemetry=None,
          ) -> Union[List[RunResult], List[List[RunResult]]]:
    """Run every (trace, policy) pair as one batched compiled scan.

    Returns a list of RunResults aligned with ``policies`` when ``traces``
    is a single Trace, else a list-of-lists indexed ``[trace][policy]``.
    ``cc`` may be a single CostConfig (shared) or one per policy.
    ``phase_b`` selects the fault engine and ``engine``/``block`` the
    stepper (see ``TieredMemSimulator``); the default batched fault
    engine removed the per-thread ``lax.cond`` vmap penalty, the default
    blocked stepper batches event-free step windows.  ``budget`` and
    ``lane_sharding`` pass through to :func:`sweep_lanes`.
    """
    single = isinstance(traces, Trace)
    tr_list = [traces] if single else list(traces)
    policies = list(policies)
    P_, M = len(policies), len(tr_list)
    if P_ == 0 or M == 0:
        raise ValueError("sweep needs at least one policy and one trace")

    ccs = list(cc) if isinstance(cc, (list, tuple)) else [cc] * P_
    if len(ccs) != P_:
        raise ValueError("need one CostConfig per policy (or a shared one)")

    # Lane layout: trace-major, policy-minor (lane = trace_idx * P + pol_idx).
    flat = sweep_lanes(
        mc,
        [c for _ in range(M) for c in ccs],
        [p for _ in range(M) for p in policies],
        [tr for tr in tr_list for _ in range(P_)],
        phase_b=phase_b, budget=budget, lane_sharding=lane_sharding,
        engine=engine, block=block, debug=debug, telemetry=telemetry)
    results = [flat[j * P_:(j + 1) * P_] for j in range(M)]
    return results[0] if single else results
