"""Batched policy-sweep engine: N policies × M traces in ONE ``lax.scan``.

Every benchmark in the reproduction compares page-table placement policies
on identical access traces.  Running them as separate Python-loop
iterations compiles one scan per policy and pays a device round-trip each;
this module instead stacks the policies (and optionally several same-shape
padded traces) into a leading *lane* axis, vmaps the policy-generic
simulator step (``sim._build_step``) over it, and runs the whole grid as a
single compiled ``lax.scan`` — one compile per trace shape, one device
program per figure.

Two entry points share the engine:

  * :func:`sweep` — the figure-style cross product: N policies × M traces.
  * :func:`sweep_lanes` — one lane per independent ``(cost, policy,
    trace)`` tuple.  This is the microbatch primitive of the simulation
    service (``repro.service``): a broker bucketing arbitrary concurrent
    queries by trace shape flushes each bucket through one call here.

Lanes can additionally be sharded across devices (``lane_sharding`` —
``jax.sharding`` over the lane axis): the state pytree and every per-lane
input are placed with a ``PartitionSpec`` over a 1-D ``"lanes"`` mesh, so
a policy grid spreads over all local devices with no change to the scan
body.  On a single-device host the mesh degenerates and results are
bit-identical to the unsharded path.

Correctness contract: a sweep lane is bit-identical (placements, counters;
cycles to float32 rounding) to the corresponding sequential
``TieredMemSimulator`` run and to the pure-Python ``core.ref`` oracle —
``tests/test_sweep.py`` and ``tests/test_service.py`` enforce both.

Constraints inherited from the step being compiled once for all lanes:

  * all traces must share one ``[steps, threads]`` shape (``pad_trace``);
  * all AutoNUMA-enabled policies must share ``autonuma_period`` (the scan
    schedule is a host-precomputed, lane-shared predicate so ``lax.cond``
    survives vmap);
  * the AutoNUMA ``top_k`` bound is the max ``autonuma_budget`` over the
    swept policies (or the explicit ``budget`` override, which may only
    raise it); per-lane budgets gate through traced masks, so an
    over-provisioned bound never changes results — brokers quantize it to
    keep compile keys stable across bursts.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .config import CostConfig, MachineConfig, PolicyConfig
from .sim import (RunResult, SCHED_DO, TIMELINE_KEYS, Trace, _build_step,
                  fault_schedule, scan_step_mask, seg_of_leaf_table)
from .state import init_state

I32 = jnp.int32
F32 = jnp.float32

# One jitted vmapped scan per (machine, budget); jax's jit cache then holds
# one executable per (lane count, trace shape, lane sharding).
_SWEEP_CACHE: Dict[Tuple, object] = {}
# Fallback compile accounting for jax versions without the (private)
# jit _cache_size API: one entry per distinct compiled signature.
_SIGNATURES = set()


def compile_count() -> int:
    """Number of XLA compilations performed by sweep()/sweep_lanes() so far.

    Counts entries in the underlying jit caches (one per distinct
    (machine, budget, lane-count, trace-shape, sharding) combination) —
    tests assert a ≥4-policy sweep adds exactly one and that a
    service-cache hit adds zero.  Falls back to the engine's own signature
    accounting if the jit cache-size API is unavailable.
    """
    sizes = [getattr(fn, "_cache_size", None) for fn in _SWEEP_CACHE.values()]
    if all(s is not None for s in sizes):
        return int(sum(s() for s in sizes))
    return len(_SIGNATURES)


def stack_policies(policies: Sequence[PolicyConfig]) -> PolicyConfig:
    """Stack N PolicyConfigs into one whose leaves are ``[N]`` arrays."""
    return _stack_leaves(list(policies))


def _stack_leaves(objs):
    def stack(*leaves):
        a = np.stack([np.asarray(leaf) for leaf in leaves])
        if a.dtype.kind in "iu":
            return jnp.asarray(a, I32)
        if a.dtype.kind == "f":
            return jnp.asarray(a, F32)
        return jnp.asarray(a)
    return jax.tree.map(stack, *objs)


def _sweep_runner(mc: MachineConfig, budget: int, phase_b: str):
    key = (mc, budget, phase_b)
    if key not in _SWEEP_CACHE:
        step = _build_step(mc, budget, phase_b)

        @jax.jit
        def run_sweep(st, cc, pc, xs, seg_of_map, seg_of_leaf):
            def body(carry, x):
                va_row, w_row, fid, llc, sched, do_free, do_scan, \
                    has_fault = x

                def lane(st1, cc1, pc1, va1, w1, fid1, llc1, sched1, sm, sl):
                    # the schedule predicates stay un-batched so the
                    # step's lax.conds keep skipping work under vmap; the
                    # per-thread fault-schedule row is per-lane (one per
                    # trace) and rides the vmap like the va row
                    return step(st1, cc1, pc1,
                                (va1, w1, fid1, llc1, sched1, do_free,
                                 do_scan, has_fault), sm, sl)
                return jax.vmap(lane)(carry, cc, pc, va_row, w_row, fid,
                                      llc, sched, seg_of_map, seg_of_leaf)
            return jax.lax.scan(body, st, xs)

        _SWEEP_CACHE[key] = run_sweep
    return _SWEEP_CACHE[key]


def lane_mesh(n_lanes: int, devices=None) -> Mesh:
    """A 1-D ``"lanes"`` mesh over the largest device prefix dividing
    ``n_lanes`` (every device on an evenly divisible lane count; one
    device — the degenerate mesh — when nothing divides)."""
    devices = list(jax.devices() if devices is None else devices)
    n = len(devices)
    while n > 1 and n_lanes % n:
        n -= 1
    return Mesh(np.asarray(devices[:n]), ("lanes",))


def _resolve_lane_sharding(lane_sharding, n_lanes: int) -> Optional[Mesh]:
    if lane_sharding is None:
        return None
    if lane_sharding == "auto":
        return lane_mesh(n_lanes)
    if isinstance(lane_sharding, Mesh):
        if n_lanes % lane_sharding.devices.size:
            raise ValueError(
                f"{n_lanes} lanes not divisible by the {lane_sharding.devices.size}-"
                "device lane mesh")
        return lane_sharding
    raise ValueError(f"lane_sharding must be None, 'auto' or a Mesh, got "
                     f"{lane_sharding!r}")


def sweep_lanes(mc: MachineConfig,
                ccs: Sequence[CostConfig],
                policies: Sequence[PolicyConfig],
                traces: Sequence[Trace],
                phase_b: str = "batched",
                budget: Optional[int] = None,
                lane_sharding=None,
                ) -> List[RunResult]:
    """Run L independent (cost, policy, trace) lanes as one batched scan.

    The service-broker primitive: unlike :func:`sweep` there is no cross
    product — lane ``i`` simulates ``traces[i]`` under ``policies[i]`` /
    ``ccs[i]``.  All traces must share one ``[steps, threads]`` shape
    (shape-bucketing is the caller's job; see ``repro.service.broker``).

    ``budget`` (optional) raises the compiled AutoNUMA ``top_k`` bound
    above the per-lane maximum so repeated calls with different policy
    mixes reuse one executable; per-lane budgets still gate exactly.

    ``lane_sharding`` — ``None`` (single device), ``"auto"`` (shard the
    lane axis over every local device that divides the lane count), or an
    explicit 1-D ``"lanes"`` :class:`jax.sharding.Mesh`.
    """
    policies = list(policies)
    ccs = list(ccs)
    tr_list = list(traces)
    L = len(policies)
    if L == 0:
        raise ValueError("sweep_lanes needs at least one lane")
    if not (len(ccs) == len(tr_list) == L):
        raise ValueError(
            f"lane lists disagree: {len(ccs)} costs, {L} policies, "
            f"{len(tr_list)} traces")

    shape = tr_list[0].va.shape
    for tr in tr_list:
        if tr.va.shape != shape:
            raise ValueError(
                f"sweep traces must share one shape; got {tr.va.shape} vs "
                f"{shape} — pad_trace() them first")
    if shape[1] != mc.n_threads:
        raise ValueError(f"traces have {shape[1]} threads, machine has "
                         f"{mc.n_threads}")

    periods = sorted({int(p.autonuma_period) for p in policies
                      if bool(p.autonuma)})
    if len(periods) > 1:
        raise ValueError(
            f"swept policies must share autonuma_period, got {periods}; the "
            "scan schedule is lane-shared")
    period = periods[0] if periods else int(policies[0].autonuma_period)
    lane_budget = min(max(int(p.autonuma_budget) for p in policies),
                      mc.n_map)
    if budget is not None and budget < lane_budget:
        raise ValueError(f"budget override {budget} below the lane maximum "
                         f"{lane_budget}; a smaller top_k bound changes "
                         "results")
    eff_budget = min(budget if budget is not None else lane_budget, mc.n_map)

    lane_pc = _stack_leaves(policies)
    lane_cc = _stack_leaves(ccs)

    # Host arrays are built per *unique trace object* and fanned out to
    # lanes by index, so a bucket of queries sharing one trace pays one
    # schedule pass and one stack.
    uniq: Dict[int, int] = {}
    uniq_traces: List[Trace] = []
    lane_of = np.empty((L,), np.int64)
    for i, tr in enumerate(tr_list):
        j = uniq.setdefault(id(tr), len(uniq_traces))
        if j == len(uniq_traces):
            uniq_traces.append(tr)
        lane_of[i] = j

    S = shape[0]
    scheds = [fault_schedule(tr, mc) for tr in uniq_traces]

    def lanes(per_trace, dtype):
        a = np.stack([np.asarray(x, dtype) for x in per_trace], axis=1)
        return jnp.asarray(a[:, lane_of])

    va = lanes([tr.va for tr in uniq_traces], np.int32)          # [S, L, T]
    wr = lanes([tr.is_write for tr in uniq_traces], bool)
    fid = lanes([tr.free_seg for tr in uniq_traces], np.int32)   # [S, L]
    llc = lanes([tr.llc for tr in uniq_traces], np.float32)
    sched = lanes(scheds, np.uint8)                              # [S, L, T]

    do_free = np.zeros((S,), bool)
    has_fault = np.zeros((S,), bool)
    for sc, tr in zip(scheds, uniq_traces):
        do_free |= np.asarray(tr.free_seg) >= 0
        has_fault |= (sc & SCHED_DO).any(axis=1)
    do_scan = scan_step_mask(S, period,
                             enabled=any(bool(p.autonuma) for p in policies))
    xs = (va, wr, fid, llc, sched, jnp.asarray(do_free),
          jnp.asarray(do_scan), jnp.asarray(has_fault))

    seg_maps = np.stack([np.asarray(tr.seg_of_map, np.int32)
                         for tr in uniq_traces])
    seg_of_map = jnp.asarray(seg_maps[lane_of])                  # [L, n_map]
    seg_leafs = np.stack([np.asarray(seg_of_leaf_table(tr, mc))
                          for tr in uniq_traces])
    seg_of_leaf = jnp.asarray(seg_leafs[lane_of])                # [L, n_leaf]

    st0 = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (L,) + a.shape),
                       init_state(mc))

    mesh = _resolve_lane_sharding(lane_sharding, L)
    shard_key = None
    if mesh is not None:
        shard_key = int(mesh.devices.size)
        lane_sh = NamedSharding(mesh, P("lanes"))
        row_sh = NamedSharding(mesh, P(None, "lanes"))
        rep_sh = NamedSharding(mesh, P())
        put = jax.device_put
        st0 = jax.tree.map(lambda a: put(a, lane_sh), st0)
        lane_cc = jax.tree.map(lambda a: put(a, lane_sh), lane_cc)
        lane_pc = jax.tree.map(lambda a: put(a, lane_sh), lane_pc)
        xs = tuple(put(x, row_sh if x.ndim > 1 else rep_sh) for x in xs)
        seg_of_map = put(seg_of_map, lane_sh)
        seg_of_leaf = put(seg_of_leaf, lane_sh)

    run_sweep = _sweep_runner(mc, eff_budget, phase_b)
    _SIGNATURES.add((mc, eff_budget, phase_b, L, S, shard_key))
    final, outs = run_sweep(st0, lane_cc, lane_pc, xs, seg_of_map,
                            seg_of_leaf)
    final = jax.device_get(final)
    outs = [np.asarray(o) for o in jax.device_get(outs)]

    results: List[RunResult] = []
    for i, (pc, tr) in enumerate(zip(policies, tr_list)):
        st_lane = jax.tree.map(lambda a: a[i], final)
        timeline = {k: v[:, i] for k, v in zip(TIMELINE_KEYS, outs)}
        results.append(RunResult(final_state=st_lane, timeline=timeline,
                                 trace_name=tr.name,
                                 policy_label=pc.label()))
    return results


def sweep(mc: MachineConfig,
          cc: Union[CostConfig, Sequence[CostConfig]],
          policies: Sequence[PolicyConfig],
          traces: Union[Trace, Sequence[Trace]],
          phase_b: str = "batched",
          budget: Optional[int] = None,
          lane_sharding=None,
          ) -> Union[List[RunResult], List[List[RunResult]]]:
    """Run every (trace, policy) pair as one batched compiled scan.

    Returns a list of RunResults aligned with ``policies`` when ``traces``
    is a single Trace, else a list-of-lists indexed ``[trace][policy]``.
    ``cc`` may be a single CostConfig (shared) or one per policy.
    ``phase_b`` selects the fault engine (see ``TieredMemSimulator``);
    the default batched engine removes the per-thread ``lax.cond`` that
    used to cost fault-dominated sweeps ~1.5x per vmap lane.  ``budget``
    and ``lane_sharding`` pass through to :func:`sweep_lanes`.
    """
    single = isinstance(traces, Trace)
    tr_list = [traces] if single else list(traces)
    policies = list(policies)
    P_, M = len(policies), len(tr_list)
    if P_ == 0 or M == 0:
        raise ValueError("sweep needs at least one policy and one trace")

    ccs = list(cc) if isinstance(cc, (list, tuple)) else [cc] * P_
    if len(ccs) != P_:
        raise ValueError("need one CostConfig per policy (or a shared one)")

    # Lane layout: trace-major, policy-minor (lane = trace_idx * P + pol_idx).
    flat = sweep_lanes(
        mc,
        [c for _ in range(M) for c in ccs],
        [p for _ in range(M) for p in policies],
        [tr for tr in tr_list for _ in range(P_)],
        phase_b=phase_b, budget=budget, lane_sharding=lane_sharding)
    results = [flat[j * P_:(j + 1) * P_] for j in range(M)]
    return results[0] if single else results
