"""Batched policy-sweep engine: N policies × M traces in ONE ``lax.scan``.

Every benchmark in the reproduction compares page-table placement policies
on identical access traces.  Running them as separate Python-loop
iterations compiles one scan per policy and pays a device round-trip each;
this module instead stacks the policies (and optionally several same-shape
padded traces) into a leading *lane* axis, vmaps the policy-generic
simulator step (``sim._build_step``) over it, and runs the whole grid as a
single compiled ``lax.scan`` — one compile per trace shape, one device
program per figure.

Correctness contract: a sweep lane is bit-identical (placements, counters;
cycles to float32 rounding) to the corresponding sequential
``TieredMemSimulator`` run and to the pure-Python ``core.ref`` oracle —
``tests/test_sweep.py`` enforces both.

Constraints inherited from the step being compiled once for all lanes:

  * all traces must share one ``[steps, threads]`` shape (``pad_trace``);
  * all AutoNUMA-enabled policies must share ``autonuma_period`` (the scan
    schedule is a host-precomputed, lane-shared predicate so ``lax.cond``
    survives vmap);
  * the AutoNUMA ``top_k`` bound is the max ``autonuma_budget`` over the
    swept policies; per-lane budgets gate through traced masks.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .config import CostConfig, MachineConfig, PolicyConfig
from .sim import (RunResult, SCHED_DO, TIMELINE_KEYS, Trace, _build_step,
                  fault_schedule, scan_step_mask, seg_of_leaf_table)
from .state import init_state

I32 = jnp.int32
F32 = jnp.float32

# One jitted vmapped scan per (machine, budget); jax's jit cache then holds
# one executable per (lane count, trace shape).
_SWEEP_CACHE: Dict[Tuple, object] = {}
# Fallback compile accounting for jax versions without the (private)
# jit _cache_size API: one entry per distinct compiled signature.
_SIGNATURES = set()


def compile_count() -> int:
    """Number of XLA compilations performed by sweep() so far.

    Counts entries in the underlying jit caches (one per distinct
    (machine, budget, lane-count, trace-shape) combination) — tests assert
    a ≥4-policy sweep adds exactly one.  Falls back to sweep()'s own
    signature accounting if the jit cache-size API is unavailable.
    """
    sizes = [getattr(fn, "_cache_size", None) for fn in _SWEEP_CACHE.values()]
    if all(s is not None for s in sizes):
        return int(sum(s() for s in sizes))
    return len(_SIGNATURES)


def stack_policies(policies: Sequence[PolicyConfig]) -> PolicyConfig:
    """Stack N PolicyConfigs into one whose leaves are ``[N]`` arrays."""
    return _stack_leaves(list(policies))


def _stack_leaves(objs):
    def stack(*leaves):
        a = np.stack([np.asarray(leaf) for leaf in leaves])
        if a.dtype.kind in "iu":
            return jnp.asarray(a, I32)
        if a.dtype.kind == "f":
            return jnp.asarray(a, F32)
        return jnp.asarray(a)
    return jax.tree.map(stack, *objs)


def _sweep_runner(mc: MachineConfig, budget: int, phase_b: str):
    key = (mc, budget, phase_b)
    if key not in _SWEEP_CACHE:
        step = _build_step(mc, budget, phase_b)

        @jax.jit
        def run_sweep(st, cc, pc, xs, seg_of_map, seg_of_leaf):
            def body(carry, x):
                va_row, w_row, fid, llc, sched, do_free, do_scan, \
                    has_fault = x

                def lane(st1, cc1, pc1, va1, w1, fid1, llc1, sched1, sm, sl):
                    # the schedule predicates stay un-batched so the
                    # step's lax.conds keep skipping work under vmap; the
                    # per-thread fault-schedule row is per-lane (one per
                    # trace) and rides the vmap like the va row
                    return step(st1, cc1, pc1,
                                (va1, w1, fid1, llc1, sched1, do_free,
                                 do_scan, has_fault), sm, sl)
                return jax.vmap(lane)(carry, cc, pc, va_row, w_row, fid,
                                      llc, sched, seg_of_map, seg_of_leaf)
            return jax.lax.scan(body, st, xs)

        _SWEEP_CACHE[key] = run_sweep
    return _SWEEP_CACHE[key]


def sweep(mc: MachineConfig,
          cc: Union[CostConfig, Sequence[CostConfig]],
          policies: Sequence[PolicyConfig],
          traces: Union[Trace, Sequence[Trace]],
          phase_b: str = "batched",
          ) -> Union[List[RunResult], List[List[RunResult]]]:
    """Run every (trace, policy) pair as one batched compiled scan.

    Returns a list of RunResults aligned with ``policies`` when ``traces``
    is a single Trace, else a list-of-lists indexed ``[trace][policy]``.
    ``cc`` may be a single CostConfig (shared) or one per policy.
    ``phase_b`` selects the fault engine (see ``TieredMemSimulator``);
    the default batched engine removes the per-thread ``lax.cond`` that
    used to cost fault-dominated sweeps ~1.5x per vmap lane.
    """
    single = isinstance(traces, Trace)
    tr_list = [traces] if single else list(traces)
    policies = list(policies)
    P, M = len(policies), len(tr_list)
    if P == 0 or M == 0:
        raise ValueError("sweep needs at least one policy and one trace")

    shape = tr_list[0].va.shape
    for tr in tr_list:
        if tr.va.shape != shape:
            raise ValueError(
                f"sweep traces must share one shape; got {tr.va.shape} vs "
                f"{shape} — pad_trace() them first")
    if shape[1] != mc.n_threads:
        raise ValueError(f"traces have {shape[1]} threads, machine has "
                         f"{mc.n_threads}")

    ccs = list(cc) if isinstance(cc, (list, tuple)) else [cc] * P
    if len(ccs) != P:
        raise ValueError("need one CostConfig per policy (or a shared one)")

    periods = sorted({int(p.autonuma_period) for p in policies
                      if bool(p.autonuma)})
    if len(periods) > 1:
        raise ValueError(
            f"swept policies must share autonuma_period, got {periods}; the "
            "scan schedule is lane-shared")
    period = periods[0] if periods else int(policies[0].autonuma_period)
    budget = min(max(int(p.autonuma_budget) for p in policies), mc.n_map)

    # Lane layout: trace-major, policy-minor (lane = trace_idx * P + pol_idx).
    L = P * M
    lane_pc = _stack_leaves([p for _ in range(M) for p in policies])
    lane_cc = _stack_leaves([c for _ in range(M) for c in ccs])

    def lane_rows(per_trace, dtype):
        a = np.stack([np.asarray(x, dtype) for x in per_trace], axis=1)
        return jnp.asarray(np.repeat(a, P, axis=1))

    S = shape[0]
    scheds = [fault_schedule(tr, mc) for tr in tr_list]
    va = lane_rows([tr.va for tr in tr_list], np.int32)          # [S, L, T]
    wr = lane_rows([tr.is_write for tr in tr_list], bool)
    fid = lane_rows([tr.free_seg for tr in tr_list], np.int32)   # [S, L]
    llc = lane_rows([tr.llc for tr in tr_list], np.float32)
    sched = lane_rows(scheds, np.uint8)                          # [S, L, T]

    do_free = np.zeros((S,), bool)
    has_fault = np.zeros((S,), bool)
    for sc, tr in zip(scheds, tr_list):
        do_free |= np.asarray(tr.free_seg) >= 0
        has_fault |= (sc & SCHED_DO).any(axis=1)
    do_scan = scan_step_mask(S, period,
                             enabled=any(bool(p.autonuma) for p in policies))
    xs = (va, wr, fid, llc, sched, jnp.asarray(do_free),
          jnp.asarray(do_scan), jnp.asarray(has_fault))

    seg_maps = np.stack([np.asarray(tr.seg_of_map, np.int32)
                         for tr in tr_list])                     # [M, n_map]
    seg_of_map = jnp.asarray(np.repeat(seg_maps, P, axis=0))     # [L, n_map]
    seg_leafs = np.stack([np.asarray(seg_of_leaf_table(tr, mc))
                          for tr in tr_list])                    # [M, n_leaf]
    seg_of_leaf = jnp.asarray(np.repeat(seg_leafs, P, axis=0))

    st0 = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (L,) + a.shape),
                       init_state(mc))

    run_sweep = _sweep_runner(mc, budget, phase_b)
    _SIGNATURES.add((mc, budget, phase_b, L, S))
    final, outs = run_sweep(st0, lane_cc, lane_pc, xs, seg_of_map,
                            seg_of_leaf)
    final = jax.device_get(final)
    outs = [np.asarray(o) for o in jax.device_get(outs)]

    results: List[List[RunResult]] = []
    for j, tr in enumerate(tr_list):
        row = []
        for i, pc in enumerate(policies):
            lane_idx = j * P + i
            st_lane = jax.tree.map(lambda a: a[lane_idx], final)
            timeline = {k: v[:, lane_idx]
                        for k, v in zip(TIMELINE_KEYS, outs)}
            row.append(RunResult(final_state=st_lane, timeline=timeline,
                                 trace_name=tr.name,
                                 policy_label=pc.label()))
        results.append(row)
    return results[0] if single else results
