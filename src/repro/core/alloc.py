"""Per-node page allocator with watermarks, slow path, reclaim and OOM.

Mirrors the Linux buddy-allocator behaviors the paper measures:

  * fast path when a node is above its low watermark,
  * slow path (direct-reclaim attempt, ``alloc_slow`` cycles) below it,
  * a small "reclaimable" reserve per node standing in for clean page cache,
  * OOM when a *bound* allocation (PT bind-all) cannot be satisfied from the
    allowed nodes even after reclaim (paper section 3.5, Fig. 7).

Allocation preferences are length-``n_nodes`` node orders with -1 padding, so
the same scalar routine serves first-touch (local then remote node of each
tier, fastest tier first), interleave (rotating start over the allocatable
nodes), and DRAM-only binds.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import (MachineConfig, INTERLEAVE, PT_BIND_ALL, PT_BIND_HIGH,
                     PT_FOLLOW_DATA)

I32 = jnp.int32


def watermark_pages(mc: MachineConfig) -> jax.Array:
    cap = jnp.asarray(mc.node_capacity(), jnp.float32)
    return (cap * mc.low_watermark).astype(I32)


def first_touch_prefs(thread: jax.Array, mc: MachineConfig) -> jax.Array:
    """Zonelist order for a thread: local then remote node of each tier,
    fastest tier first (paper Fig. 2 topology; tiers beyond DRAM extend
    the classic local-DRAM, remote-DRAM, local-NVMM, remote-NVMM order)."""
    local = jnp.where(thread < mc.n_threads // 2, 0, 1).astype(I32)
    pairs = []
    for t in range(mc.n_tiers):
        pairs.append(2 * t + local)
        pairs.append(2 * t + (1 - local))
    return jnp.stack(pairs)


def interleave_prefs(ptr: jax.Array, mc: MachineConfig) -> jax.Array:
    """Round-robin start node with wrap-around fallback.  Rotates over the
    *allocatable* nodes only, so zero-capacity middle tiers never perturb
    the round-robin order (-1 pads to the machine's n_nodes)."""
    alloc = jnp.asarray(mc.alloc_nodes, I32)
    a = len(mc.alloc_nodes)
    start = (ptr % a).astype(I32)
    prefs = alloc[(start + jnp.arange(a, dtype=I32)) % a]
    if a < mc.n_nodes:
        prefs = jnp.concatenate(
            [prefs, jnp.full((mc.n_nodes - a,), -1, I32)])
    return prefs


def dram_prefs(thread: jax.Array, mc: MachineConfig) -> jax.Array:
    """DRAM-only preference (for PT binds); -1 entries are invalid."""
    local = jnp.where(thread < mc.n_threads // 2, 0, 1).astype(I32)
    pad = [jnp.asarray(-1, I32)] * (mc.n_nodes - 2)
    return jnp.stack([local, 1 - local] + pad)


def alloc_one(node_free: jax.Array, node_reclaimable: jax.Array,
              prefs: jax.Array, wm: jax.Array, ignore_wm: jax.Array
              ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Allocate a single page following ``prefs`` (i32[n_nodes], -1 = skip).

    Returns (node, slow, new_free, new_reclaimable, ok).  ``node`` is -1 on
    failure.  ``slow`` flags the watermark slow path (or a reclaim), charged
    ``alloc_slow`` cycles by the caller.  Deterministic: first acceptable
    node in preference order wins.
    """
    n = node_free.shape[0]
    valid = prefs >= 0
    safe_prefs = jnp.where(valid, prefs, 0)
    free_p = jnp.where(valid, node_free[safe_prefs], -1)
    wm_p = jnp.where(ignore_wm, 0, wm[safe_prefs])
    rec_p = jnp.where(valid, node_reclaimable[safe_prefs], 0)

    above = valid & (free_p > wm_p)
    has_page = valid & (free_p > 0)
    has_reclaim = valid & (rec_p > 0)

    fast_ok = jnp.any(above)
    slow_ok = jnp.any(has_page)
    rec_ok = jnp.any(has_reclaim)

    pick_fast = safe_prefs[jnp.argmax(above)]
    pick_slow = safe_prefs[jnp.argmax(has_page)]
    pick_rec = safe_prefs[jnp.argmax(has_reclaim)]

    node = jnp.where(fast_ok, pick_fast,
                     jnp.where(slow_ok, pick_slow,
                               jnp.where(rec_ok, pick_rec, -1)))
    ok = fast_ok | slow_ok | rec_ok
    slow = ok & ~fast_ok
    from_reclaim = ok & ~fast_ok & ~slow_ok

    dec = jnp.zeros((n,), I32).at[jnp.clip(node, 0, n - 1)].add(
        jnp.where(ok & ~from_reclaim, 1, 0))
    dec_rec = jnp.zeros((n,), I32).at[jnp.clip(node, 0, n - 1)].add(
        jnp.where(from_reclaim, 1, 0))
    return node, slow, node_free - dec, node_reclaimable - dec_rec, ok


def data_prefs_for(data_policy: jax.Array, thread: jax.Array,
                   mc: MachineConfig,
                   interleave_ptr: jax.Array) -> jax.Array:
    """Zonelist for a data-page allocation.  ``data_policy`` may be a traced
    int32 policy code (a vmap policy sweep), so both orders are computed and
    selected."""
    interleave = jnp.asarray(data_policy) == INTERLEAVE
    return jnp.where(interleave, interleave_prefs(interleave_ptr, mc),
                     first_touch_prefs(thread, mc))


def pt_prefs_for(pt_policy: jax.Array, level_is_upper: bool, thread: jax.Array,
                 mc: MachineConfig, data_prefs: jax.Array,
                 thp: bool) -> Tuple[jax.Array, jax.Array]:
    """Preference order for a PT page allocation.

    Returns (prefs, ignore_wm); ``pt_policy`` may be traced, so ``ignore_wm``
    is a traced bool.  ``level_is_upper`` marks root/top/mid pages (plus the
    leaf under THP, where the PMD *is* the leaf and BHi binds it — paper
    section 6.6); it is static because each walk level is a separate call.
    """
    pt_policy = jnp.asarray(pt_policy)
    bound = (pt_policy == PT_BIND_ALL) | \
        ((pt_policy == PT_BIND_HIGH) & (level_is_upper or thp))
    # Linux default: PT pages follow the data-page policy (paper section 3.2).
    prefs = jnp.where(bound, dram_prefs(thread, mc), data_prefs)
    return prefs, bound


# Request layout of one page fault, in allocation (= serialization) order:
# root, top and mid PT pages are "upper" levels (BHi-bound); the leaf PT
# page is upper only under THP (the PMD *is* the leaf, paper section 6.6);
# the data page comes last (request index 4).
_LEVEL_IS_UPPER = (True, True, True, False)


def alloc_many(node_free: jax.Array, node_reclaimable: jax.Array,
               interleave_ptr: jax.Array, oom_killed: jax.Array,
               wm: jax.Array, data_policy, pt_policy, mc: MachineConfig,
               need_pt: jax.Array, need_data: jax.Array,
               slot_thread=None):
    """Batched fault allocator: hand out pages to a whole thread vector.

    Reproduces the sequential thread-order semantics of
    ``sim.phase_b_body`` bit-for-bit.  The only state that genuinely
    chains through the per-thread fault loop is tiny — ``node_free[4]``,
    ``node_reclaimable[4]``, the interleave cursor and the OOM latch — so
    this runs a ``lax.scan`` over threads carrying just those ~10 scalars
    (each thread's 5 requests unrolled inside the body), while every heavy
    array update (PT placement scatters, TLB fills, counters) is left to
    the caller to commit vectorized from the returned per-request results.

    ``need_pt[T, 4]`` (root/top/mid/leaf) and ``need_data[T]`` are the
    host-precomputed first-thread-wins request masks from
    ``sim.fault_schedule``: threads faulting the same missing PT entry are
    resolved to the earliest thread, exactly as zone-lock serialization
    orders them in the sequential loop.  OOM gates at thread granularity:
    a thread whose allocation fails latches ``oom`` and every *later*
    thread goes inert, but the failing thread's own remaining requests
    still run (matching ``_alloc_pt_level``, which never re-checks the
    latch mid-fault).

    Returns ``(nodes[T,5], slow[T,5], ok[T,5], act[T,5], gate[T],
    node_free', node_reclaimable', interleave_ptr', oom')`` where ``act``
    marks requests actually attempted and ``gate`` marks threads that were
    not OOM-gated on entry.  ``ok`` is reported for *all* requests (it is
    what the sequential path's cost model reads), committed effects only
    for ``act & ok``.

    ``slot_thread`` (optional, i32[G] — ``n_threads`` marks a pad slot)
    compacts the serialized scan into *conflict groups*: a thread with no
    requests is the identity on the allocator carry and commutes with
    everything, so only the at-most-G allocating threads (the host
    schedule's WINNER bits, ``sim.fault_group_bound``) need a scan slot —
    each group is one allocating thread plus the silent threads behind
    it.  The scan runs over the G slots in thread order and results
    scatter back to the thread axis; per-thread OOM gates are
    reconstructed from the winners' failure prefix, which is exactly the
    thread-order latch (only allocating threads can trip it).  Requests
    from threads without a slot would be dropped — callers guarantee
    every requesting thread carries a WINNER bit (device winners are a
    subset of host winners).  ``None`` keeps the full ``n_threads``-deep
    scan; both paths are bit-identical.
    """
    data_policy = jnp.asarray(data_policy)
    pt_policy = jnp.asarray(pt_policy)
    thp = mc.page_order > 0
    is_follow = pt_policy == PT_FOLLOW_DATA
    is_interleave = data_policy == INTERLEAVE
    no_wm = jnp.asarray(False)

    def body(carry, x):
        free, rec, ptr, oom = carry
        needs, need_d, t = x
        gate = ~oom                     # thread-entry OOM gate
        nodes, slows, oks, acts = [], [], [], []
        for lvl in range(4):
            is_upper = _LEVEL_IS_UPPER[lvl]
            act = needs[lvl] & gate
            dprefs = data_prefs_for(data_policy, t, mc, ptr)
            prefs, ign = pt_prefs_for(pt_policy, is_upper, t, mc,
                                      dprefs, thp)
            node, slow, nf, nr, ok = alloc_one(free, rec, prefs, wm, ign)
            if is_upper or thp:
                # BHi falls back to the data policy when DRAM is exhausted
                # (mirrors sim._alloc_pt_level: both allocations computed,
                # the fallback selected per traced lane).
                node2, slow2, nf2, nr2, ok2 = alloc_one(free, rec, dprefs,
                                                        wm, no_wm)
                is_bhi = pt_policy == PT_BIND_HIGH
                use_fb = is_bhi & ~ok
                node = jnp.where(use_fb, node2, node)
                slow = jnp.where(use_fb, slow2, slow)
                nf = jnp.where(use_fb, nf2, nf)
                nr = jnp.where(use_fb, nr2, nr)
                ok = ok | (is_bhi & ok2)
            do = act & ok
            free = jnp.where(do, nf, free)
            rec = jnp.where(do, nr, rec)
            ptr = ptr + (do & is_follow & is_interleave).astype(I32)
            oom = oom | (act & ~ok)
            nodes.append(node), slows.append(slow)
            oks.append(ok), acts.append(act)

        act_d = need_d & gate
        dprefs = data_prefs_for(data_policy, t, mc, ptr)
        node, slow, nf, nr, ok = alloc_one(free, rec, dprefs, wm, no_wm)
        do = act_d & ok
        free = jnp.where(do, nf, free)
        rec = jnp.where(do, nr, rec)
        ptr = ptr + (do & is_interleave).astype(I32)
        oom = oom | (act_d & ~ok)
        nodes.append(node), slows.append(slow)
        oks.append(ok), acts.append(act_d)

        y = (jnp.stack(nodes), jnp.stack(slows), jnp.stack(oks),
             jnp.stack(acts), gate)
        return (free, rec, ptr, oom), y

    T = need_data.shape[0]
    carry0 = (node_free, node_reclaimable, interleave_ptr, oom_killed)
    if slot_thread is None:
        xs = (need_pt, need_data, jnp.arange(T, dtype=I32))
        (free, rec, ptr, oom), (nodes, slow, ok, act, gate) = \
            jax.lax.scan(body, carry0, xs)
        return nodes, slow, ok, act, gate, free, rec, ptr, oom

    # Conflict-group compaction: gather the allocating threads' requests
    # into the G slots, scan those, scatter results back.
    pad = slot_thread >= T
    safe_t = jnp.where(pad, 0, slot_thread).astype(I32)
    needs_g = jnp.where(pad[:, None], False, need_pt[safe_t])
    need_d_g = jnp.where(pad, False, need_data[safe_t])
    (free, rec, ptr, oom), (nodes_g, slow_g, ok_g, act_g, _gate_g) = \
        jax.lax.scan(body, carry0, (needs_g, need_d_g, safe_t))

    tgt = jnp.where(pad, T, slot_thread)           # route pads out of range
    nodes = jnp.full((T, 5), -1, I32).at[tgt].set(nodes_g, mode="drop")
    slow = jnp.zeros((T, 5), bool).at[tgt].set(slow_g, mode="drop")
    ok = jnp.zeros((T, 5), bool).at[tgt].set(ok_g, mode="drop")
    act = jnp.zeros((T, 5), bool).at[tgt].set(act_g, mode="drop")
    # Thread-order OOM gate: a thread is gated iff the latch was set on
    # entry or any allocating thread BEFORE it failed a request.
    fail_g = jnp.any(act_g & ~ok_g, axis=1)
    fail_t = jnp.zeros((T,), bool).at[tgt].set(fail_g, mode="drop")
    prefix = jnp.cumsum(fail_t.astype(I32)) - fail_t.astype(I32)
    gate = ~oom_killed & (prefix == 0)
    return nodes, slow, ok, act, gate, free, rec, ptr, oom
