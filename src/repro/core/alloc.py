"""Per-node page allocator with watermarks, slow path, reclaim and OOM.

Mirrors the Linux buddy-allocator behaviors the paper measures:

  * fast path when a node is above its low watermark,
  * slow path (direct-reclaim attempt, ``alloc_slow`` cycles) below it,
  * a small "reclaimable" reserve per node standing in for clean page cache,
  * OOM when a *bound* allocation (PT bind-all) cannot be satisfied from the
    allowed nodes even after reclaim (paper section 3.5, Fig. 7).

Allocation preferences are length-4 node orders with -1 padding, so the same
scalar routine serves first-touch (local DRAM -> remote DRAM -> local NVMM ->
remote NVMM), interleave (rotating start node), and DRAM-only binds.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import (MachineConfig, INTERLEAVE, PT_BIND_ALL, PT_BIND_HIGH)

I32 = jnp.int32


def watermark_pages(mc: MachineConfig) -> jax.Array:
    cap = jnp.asarray(mc.node_capacity(), jnp.float32)
    return (cap * mc.low_watermark).astype(I32)


def first_touch_prefs(thread: jax.Array, n_threads: int) -> jax.Array:
    """Zonelist order for a thread: its socket's DRAM, remote DRAM, local
    NVMM, remote NVMM (paper Fig. 2 topology)."""
    local = jnp.where(thread < n_threads // 2, 0, 1).astype(I32)
    return jnp.stack([local, 1 - local, local + 2, 3 - local])


def interleave_prefs(ptr: jax.Array) -> jax.Array:
    """Round-robin start node with wrap-around fallback."""
    start = (ptr % 4).astype(I32)
    return (start + jnp.arange(4, dtype=I32)) % 4


def dram_prefs(thread: jax.Array, n_threads: int) -> jax.Array:
    """DRAM-only preference (for PT binds); -1 entries are invalid."""
    local = jnp.where(thread < n_threads // 2, 0, 1).astype(I32)
    return jnp.stack([local, 1 - local,
                      jnp.asarray(-1, I32), jnp.asarray(-1, I32)])


def alloc_one(node_free: jax.Array, node_reclaimable: jax.Array,
              prefs: jax.Array, wm: jax.Array, ignore_wm: jax.Array
              ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Allocate a single page following ``prefs`` (i32[4], -1 = skip).

    Returns (node, slow, new_free, new_reclaimable, ok).  ``node`` is -1 on
    failure.  ``slow`` flags the watermark slow path (or a reclaim), charged
    ``alloc_slow`` cycles by the caller.  Deterministic: first acceptable
    node in preference order wins.
    """
    valid = prefs >= 0
    safe_prefs = jnp.where(valid, prefs, 0)
    free_p = jnp.where(valid, node_free[safe_prefs], -1)
    wm_p = jnp.where(ignore_wm, 0, wm[safe_prefs])
    rec_p = jnp.where(valid, node_reclaimable[safe_prefs], 0)

    above = valid & (free_p > wm_p)
    has_page = valid & (free_p > 0)
    has_reclaim = valid & (rec_p > 0)

    fast_ok = jnp.any(above)
    slow_ok = jnp.any(has_page)
    rec_ok = jnp.any(has_reclaim)

    pick_fast = safe_prefs[jnp.argmax(above)]
    pick_slow = safe_prefs[jnp.argmax(has_page)]
    pick_rec = safe_prefs[jnp.argmax(has_reclaim)]

    node = jnp.where(fast_ok, pick_fast,
                     jnp.where(slow_ok, pick_slow,
                               jnp.where(rec_ok, pick_rec, -1)))
    ok = fast_ok | slow_ok | rec_ok
    slow = ok & ~fast_ok
    from_reclaim = ok & ~fast_ok & ~slow_ok

    dec = jnp.zeros((4,), I32).at[jnp.clip(node, 0, 3)].add(
        jnp.where(ok & ~from_reclaim, 1, 0))
    dec_rec = jnp.zeros((4,), I32).at[jnp.clip(node, 0, 3)].add(
        jnp.where(from_reclaim, 1, 0))
    return node, slow, node_free - dec, node_reclaimable - dec_rec, ok


def data_prefs_for(data_policy: jax.Array, thread: jax.Array, n_threads: int,
                   interleave_ptr: jax.Array) -> jax.Array:
    """Zonelist for a data-page allocation.  ``data_policy`` may be a traced
    int32 policy code (a vmap policy sweep), so both orders are computed and
    selected."""
    interleave = jnp.asarray(data_policy) == INTERLEAVE
    return jnp.where(interleave, interleave_prefs(interleave_ptr),
                     first_touch_prefs(thread, n_threads))


def pt_prefs_for(pt_policy: jax.Array, level_is_upper: bool, thread: jax.Array,
                 n_threads: int, data_prefs: jax.Array,
                 thp: bool) -> Tuple[jax.Array, jax.Array]:
    """Preference order for a PT page allocation.

    Returns (prefs, ignore_wm); ``pt_policy`` may be traced, so ``ignore_wm``
    is a traced bool.  ``level_is_upper`` marks root/top/mid pages (plus the
    leaf under THP, where the PMD *is* the leaf and BHi binds it — paper
    section 6.6); it is static because each walk level is a separate call.
    """
    pt_policy = jnp.asarray(pt_policy)
    bound = (pt_policy == PT_BIND_ALL) | \
        ((pt_policy == PT_BIND_HIGH) & (level_is_upper or thp))
    # Linux default: PT pages follow the data-page policy (paper section 3.2).
    prefs = jnp.where(bound, dram_prefs(thread, n_threads), data_prefs)
    return prefs, bound
