"""Machine / cost / policy configuration for the Radiant tiered-memory simulator.

The simulated machine mirrors the paper's Table 1: a 2-socket box with two
DRAM-backed NUMA nodes (0, 1) and two NVMM (Optane)-backed no-CPU NUMA nodes
(2, 3).  Capacities are expressed in 4 KiB pages and scaled down from the
paper's 384 GB DRAM / 1.6 TB Optane so that whole-workload simulations run in
seconds on CPU while preserving the ratios that drive the paper's results
(DRAM : total ~= 19%, workload RSS > DRAM, NVMM read latency = 3x DRAM).

The machine generalizes to N tiers (``tier_pages_per_node``): a 2-socket box
always has two NUMA nodes per tier, numbered tier-major — tier 0 (DRAM) is
nodes 0/1, tier 1 the next pair, and so on down to the slowest tier.  The
2-tier DRAM/NVMM default is the degenerate case, and an N-tier machine whose
middle tiers have zero capacity reproduces the 2-tier machine bit-for-bit
(``tests/test_ntier.py``).  Middle tiers use the ``cxl_read``/``cxl_write``
latencies (CXL-attached expansion memory); tier 0 uses the DRAM latencies and
the slowest tier the NVMM ones.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax

N_NODES = 4
DRAM_NODES = (0, 1)
NVMM_NODES = (2, 3)
NODES_PER_TIER = 2            # 2-socket box: one node per socket per tier

# Policies are integer codes so a PolicyConfig can hold either plain Python
# ints (single run) or traced/stacked int32 arrays (a vmap policy sweep —
# see core.sweep).  The data-policy and PT-policy namespaces are disjoint
# so an accidental cross-comparison can never be true.

# Data-page placement policies (paper section 2.3 / 6.1).
FIRST_TOUCH = 0
INTERLEAVE = 1

# Page-table placement policies (paper sections 3.5 / 4.2).
PT_FOLLOW_DATA = 10  # Linux default: same policy as data pages
PT_BIND_ALL = 11     # LKML patch [36]: whole page table in DRAM
PT_BIND_HIGH = 12    # Radiant BHi: L1-L3 in DRAM, L4 follows data

# Migration policy families (which algorithm the periodic balancing scan
# runs; ``PolicyConfig.autonuma`` switches the scan itself on/off):
MIG_AUTONUMA = 20  # Linux AutoNUMA: hint-fault promotion, optional exchange
MIG_TPP = 21       # TPP (CXL tiered memory): active/inactive LRU split,
#                    demotion to the next-slower tier ahead of reclaim
MIG_NOMAD = 22     # Nomad: transactional page migration (abort + retry on a
#                    concurrent write) with non-exclusive shadow copies

# Legacy string spellings still accepted by PolicyConfig and kept for
# display purposes.
DATA_POLICY_NAMES = {FIRST_TOUCH: "first_touch", INTERLEAVE: "interleave"}
PT_POLICY_NAMES = {PT_FOLLOW_DATA: "follow_data", PT_BIND_ALL: "bind_all",
                   PT_BIND_HIGH: "bind_high"}
MIG_POLICY_NAMES = {MIG_AUTONUMA: "autonuma", MIG_TPP: "tpp",
                    MIG_NOMAD: "nomad"}
_POLICY_CODES = {name: code
                 for names in (DATA_POLICY_NAMES, PT_POLICY_NAMES,
                               MIG_POLICY_NAMES)
                 for code, name in names.items()}


@dataclasses.dataclass(frozen=True)
class MachineConfig:
    """Physical machine shape (scaled-down paper Table 1)."""

    n_threads: int = 32                # simulated CPUs (paper: 96)
    # Pages per node.  Defaults: DRAM 2*49152 = 96 Ki pages, NVMM 2*204800.
    dram_pages_per_node: int = 49152
    nvmm_pages_per_node: int = 204800
    # N-tier generalization: pages per node of each tier, fastest first
    # (DRAM, CXL..., NVMM).  ``None`` means the classic 2-tier machine
    # built from the two fields above.  Every tier contributes two NUMA
    # nodes (one per socket), numbered tier-major: tier t owns nodes
    # (2t, 2t+1).  A middle tier may have zero capacity — its nodes are
    # never allocatable and the machine behaves bit-identically to one
    # without that tier (guarded by tests/test_ntier.py).
    tier_pages_per_node: Optional[Tuple[int, ...]] = None
    va_pages: int = 1 << 18            # virtual address space, 4 KiB pages
    page_order: int = 0                # 0 => base pages; radix_bits => THP

    # log2 fan-out per page-table level.  Real x86-64 is 9 (512-ary).  The
    # scaled-down benchmark machine uses 6 so that upper-level pages number
    # in the dozens (as they do for terabyte footprints) instead of 1-4 —
    # otherwise the paper's startup/interleave effects, which hinge on *mid-
    # level* page placement, cannot exist at simulation scale.  Structural
    # claims (PT size ratios, 0.18%) are asserted separately at radix 9.
    radix_bits: int = 9

    # TLB hierarchy (per simulated thread).
    l1_tlb_sets: int = 16
    l1_tlb_ways: int = 4
    stlb_sets: int = 128
    stlb_ways: int = 12

    # Page-walk caches (per thread, fully associative).
    pde_pwc_entries: int = 32          # caches L3->L4 pointers (skip L1..L3)
    pdpte_pwc_entries: int = 8         # caches L2->L3 pointers (skip L1..L2)

    # Allocator watermarks, as fractions of a node's capacity.
    low_watermark: float = 0.02        # below this the buddy slow path runs
    reclaimable_frac: float = 0.01     # page-cache style reclaimable reserve

    # PMD try-lock conflict domain, in leaf-page-id right-shift.  On real
    # hardware one PMD page (= lock) covers 512 leaf pages (shift 9), and a
    # 1 TB workload has ~1024 lock domains; the scaled-down simulation has
    # only ~2-8 mid-level pages, which would serialize Algorithm-1 batches
    # far beyond reality.  shift=1 (one lock per 2 leaf pages) restores the
    # real system's conflict *ratio* at simulation scale; set 9 to model the
    # literal lock granularity.
    lock_domain_shift: int = 1

    def __post_init__(self):
        if self.tier_pages_per_node is not None:
            tiers = tuple(int(c) for c in self.tier_pages_per_node)
            if len(tiers) < 2:
                raise ValueError(
                    f"tier_pages_per_node needs >= 2 tiers, got {tiers}")
            if tiers[0] <= 0 or tiers[-1] <= 0:
                raise ValueError(
                    "the fastest and slowest tiers must have capacity; "
                    f"got {tiers}")
            object.__setattr__(self, "tier_pages_per_node", tiers)

    @property
    def tier_capacities(self) -> Tuple[int, ...]:
        """Pages per node of each tier, fastest (DRAM) first."""
        if self.tier_pages_per_node is not None:
            return self.tier_pages_per_node
        return (self.dram_pages_per_node, self.nvmm_pages_per_node)

    @property
    def n_tiers(self) -> int:
        return len(self.tier_capacities)

    @property
    def n_nodes(self) -> int:
        return NODES_PER_TIER * self.n_tiers

    @property
    def tier_of_node(self) -> Tuple[int, ...]:
        """Tier index per NUMA node (node 2t and 2t+1 belong to tier t)."""
        return tuple(t for t in range(self.n_tiers)
                     for _ in range(NODES_PER_TIER))

    @property
    def alloc_nodes(self) -> Tuple[int, ...]:
        """Nodes with nonzero capacity, ascending — the interleave
        rotation runs over these, so zero-capacity middle tiers never
        perturb the round-robin order."""
        caps = self.tier_capacities
        return tuple(n for n in range(self.n_nodes)
                     if caps[n // NODES_PER_TIER] > 0)

    def node_capacity(self) -> Tuple[int, ...]:
        return tuple(self.tier_capacities[t] for t in self.tier_of_node)

    @property
    def map_shift(self) -> int:
        """log2(#base pages per mapping granule): 0 normally, radix for THP."""
        return self.page_order

    @property
    def n_map(self) -> int:
        """Number of mapping granules (== leaf entries) in the VA space."""
        return max(self.va_pages >> self.page_order, 1)

    @property
    def n_leaf_pages(self) -> int:
        """Number of leaf page-table pages (PTE pages; PMD pages for THP)."""
        return max(self.n_map >> self.radix_bits, 1)

    @property
    def n_mid_pages(self) -> int:
        return max(self.n_map >> (2 * self.radix_bits), 1)

    @property
    def n_top_pages(self) -> int:
        return max(self.n_map >> (3 * self.radix_bits), 1)

    @property
    def walk_levels(self) -> int:
        """Memory accesses in a full hardware walk (4 for 4K, 3 for THP)."""
        return 4 if self.page_order == 0 else 3


@dataclasses.dataclass(frozen=True)
class CostConfig:
    """Latency model in CPU cycles (~3 GHz).

    The only paper-anchored constant that matters for the headline results is
    the 3x NVMM:DRAM read ratio ([38], paper section 1); write latency on
    Optane is worse and modeled at 4x.  Everything else is standard x86
    folklore and only shifts absolute numbers, not the policy deltas.

    Registered as a pytree with every field a leaf: a CostConfig enters the
    compiled simulator as traced scalars (so cost changes never recompile)
    and ``core.sweep`` may stack several CostConfigs into one batched run.
    """

    dram_read: int = 250
    nvmm_read: int = 750               # 3x DRAM (paper observation 2)
    dram_write: int = 250
    nvmm_write: int = 1000             # 4x DRAM
    # Middle (CXL-attached) tiers on an N-tier machine; unused on the
    # classic 2-tier box.  ~1.8x DRAM read matches reported CXL adder.
    cxl_read: int = 450
    cxl_write: int = 500
    llc_hit: int = 40
    stlb_hit: int = 10
    cpu_work: int = 60                 # non-memory work per access (IPC proxy)

    fault_base: int = 600              # trap + handler entry/exit
    alloc_fast: int = 150              # buddy fast path
    alloc_slow: int = 4000             # watermark slow path / reclaim attempt
    zero_lines: int = 16               # charged lines when zeroing a page
    migrate_fixed: int = 1200          # rmap walk, unmap, bookkeeping
    copy_lines: int = 16               # charged lines for the 4 KiB copy
    tlb_flush: int = 450               # local invalidation + IPI shootdown
    oom_scan: int = 200000             # direct reclaim scan before OOM kill

    # Fraction of data-access latency NOT hidden by out-of-order execution.
    # Page walks stall the pipeline fully (the PMH serializes translations).
    data_stall_frac: float = 0.6

    # The simulated access stream subsamples the real one by ~10^3 (a run
    # simulates ~10^6 accesses standing in for ~10^9+), while the AutoNUMA
    # scan cadence is kept realistic relative to DRAM capacity.  Background
    # migration-daemon cycles charged to application threads are therefore
    # scaled by this factor; the full cost is still reported separately as
    # ``migration_cycles``.  Calibrated so migration overhead lands at the
    # paper's observed ~1-5% of total cycles.
    mig_cost_scale: float = 0.05

    # Probability that the leaf PTE *cache line* is already in the LLC
    # (PT entries travel the normal cache hierarchy; 8 entries/line).
    leaf_llc_hit: float = 0.30
    # Same for mid/top-level entries on a PWC miss.  Upper-level pages are
    # fewer but PWC misses imply poor locality, so this stays moderate.
    upper_llc_hit: float = 0.35


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    """Which paper technique is active (Table 3 conventions).

    Registered as a pytree with every field a leaf, so a PolicyConfig can be
    swept: ``core.sweep`` stacks N configs into one whose leaves are
    ``int32[N]`` / ``bool[N]`` arrays and vmaps the simulator step over them.
    Two knobs stay effectively static per compile — ``autonuma_period``
    (scan-step schedule, precomputed host-side) and ``autonuma_budget``
    (bounds the ``top_k`` shape) — but both live outside the compiled step,
    so they are ordinary leaves here.
    """

    data_policy: Union[int, jax.Array] = FIRST_TOUCH   # FIRST_TOUCH | INTERLEAVE
    pt_policy: Union[int, jax.Array] = PT_FOLLOW_DATA  # PT_FOLLOW_DATA | PT_BIND_ALL | PT_BIND_HIGH
    mig: Union[bool, jax.Array] = False     # Radiant "Mig": Algorithm-1 L4 migration
    autonuma: Union[bool, jax.Array] = True  # data-page balancing (migration source)

    # AutoNUMA-ish scanner.  Threshold 1 = migrate-on-touch, matching NUMA
    # hint-fault behavior; the budget bounds per-scan migrate_pages batches.
    autonuma_period: int = 512         # steps between scans
    autonuma_budget: int = 256         # max data-page promotions per scan
    autonuma_threshold: Union[int, jax.Array] = 1   # min recent accesses to be "hot"
    autonuma_exchange: Union[bool, jax.Array] = True  # demote cold DRAM pages

    # Which migration algorithm the periodic scan runs (MIG_AUTONUMA |
    # MIG_TPP | MIG_NOMAD).  TPP splits pages into active/inactive by the
    # recent-access count and demotes inactive pages to the *next-slower*
    # tier ahead of reclaim pressure; Nomad migrates transactionally —
    # a promotion aborts (and retries next scan) if the page saw a
    # concurrent write, and committed promotions keep a non-exclusive
    # shadow copy on the source tier that a later demotion can flip to
    # for free.
    mig_policy: Union[int, jax.Array] = MIG_AUTONUMA
    # TPP only: extra fraction of tier-0 capacity the demotion path keeps
    # free beyond the low watermark (the "demotion watermark").
    tpp_demote_wm: Union[float, jax.Array] = 0.0

    def __post_init__(self):
        # Normalize legacy string spellings and validate concrete codes;
        # traced/stacked array leaves (pytree unflatten, sweeps) pass
        # through untouched.
        for f, valid in (("data_policy", DATA_POLICY_NAMES),
                         ("pt_policy", PT_POLICY_NAMES),
                         ("mig_policy", MIG_POLICY_NAMES)):
            v = getattr(self, f)
            if isinstance(v, str):
                if v not in _POLICY_CODES or _POLICY_CODES[v] not in valid:
                    raise ValueError(f"unknown {f} {v!r}")
                object.__setattr__(self, f, _POLICY_CODES[v])
            elif isinstance(v, int) and v not in valid:
                raise ValueError(
                    f"unknown {f} code {v}; valid: {dict(valid)}")

    def label(self) -> str:
        bits = []
        bits.append("interleave" if self.data_policy == INTERLEAVE else "first-touch")
        if self.pt_policy == PT_BIND_HIGH:
            bits.append("BHi")
        elif self.pt_policy == PT_BIND_ALL:
            bits.append("BindAll")
        if self.mig:
            bits.append("Mig")
        if not self.autonuma:
            bits.append("noAutoNUMA")
        if self.mig_policy == MIG_TPP:
            bits.append("TPP")
        elif self.mig_policy == MIG_NOMAD:
            bits.append("Nomad")
        return "+".join(bits)


_COST_FIELDS = tuple(f.name for f in dataclasses.fields(CostConfig))
jax.tree_util.register_dataclass(CostConfig, data_fields=_COST_FIELDS,
                                 meta_fields=())

_POLICY_FIELDS = tuple(f.name for f in dataclasses.fields(PolicyConfig))
jax.tree_util.register_dataclass(PolicyConfig, data_fields=_POLICY_FIELDS,
                                 meta_fields=())


def benchmark_machine(thp: bool = False, n_threads: int = 32) -> MachineConfig:
    """The scaled-down paper machine used by the benchmark suite.

    radix 6 (64-ary tables) so mid/top-level pages number in the dozens, as
    they do for the paper's terabyte footprints; DRAM : footprint ratio and
    NVMM latency ratios match Table 1.  ``thp`` switches to huge-page
    mapping granules (3-level walks, paper section 6.6).
    """
    return MachineConfig(n_threads=n_threads, radix_bits=6,
                         va_pages=1 << 18,
                         dram_pages_per_node=49152,
                         nvmm_pages_per_node=204800,
                         page_order=6 if thp else 0)


# Preset policy bundles matching the paper's Table 3 conventions.
def linux_default(data_policy: str = FIRST_TOUCH, autonuma: bool = True) -> PolicyConfig:
    return PolicyConfig(data_policy=data_policy, pt_policy=PT_FOLLOW_DATA,
                        mig=False, autonuma=autonuma)


def bind_all(data_policy: str = FIRST_TOUCH, autonuma: bool = True) -> PolicyConfig:
    return PolicyConfig(data_policy=data_policy, pt_policy=PT_BIND_ALL,
                        mig=False, autonuma=autonuma)


def bhi(data_policy: str = FIRST_TOUCH, autonuma: bool = True) -> PolicyConfig:
    return PolicyConfig(data_policy=data_policy, pt_policy=PT_BIND_HIGH,
                        mig=False, autonuma=autonuma)


def bhi_mig(data_policy: str = FIRST_TOUCH, autonuma: bool = True) -> PolicyConfig:
    return PolicyConfig(data_policy=data_policy, pt_policy=PT_BIND_HIGH,
                        mig=True, autonuma=autonuma)


def tpp(data_policy: str = FIRST_TOUCH, demote_wm: float = 0.02,
        **kw) -> PolicyConfig:
    """TPP-style tiering: active/inactive split + headroom demotion."""
    return PolicyConfig(data_policy=data_policy, pt_policy=PT_FOLLOW_DATA,
                        mig=False, autonuma=True, mig_policy=MIG_TPP,
                        tpp_demote_wm=demote_wm, **kw)


def nomad(data_policy: str = FIRST_TOUCH, **kw) -> PolicyConfig:
    """Nomad-style transactional migration with shadow copies."""
    return PolicyConfig(data_policy=data_policy, pt_policy=PT_FOLLOW_DATA,
                        mig=False, autonuma=True, mig_policy=MIG_NOMAD, **kw)


def cxl_machine(n_threads: int = 32, cxl_pages_per_node: int = 98304,
                thp: bool = False) -> MachineConfig:
    """3-tier DRAM + CXL + NVMM benchmark machine (tier-major nodes 0-5)."""
    return MachineConfig(n_threads=n_threads, radix_bits=6,
                         va_pages=1 << 18,
                         tier_pages_per_node=(49152, cxl_pages_per_node,
                                              204800),
                         page_order=6 if thp else 0)
