"""Pure-Python oracle for the tiered-memory simulator.

Replicates ``core.sim`` step-for-step at small scales (python loops, numpy
scalars) so tests can compare placement arrays and counters exactly and
cycle totals to float32 rounding.  Every ordering rule of the JAX version is
mirrored:

  * phase A (mapped accesses) uses the pre-step state for every thread;
  * phase B (faults) runs threads in index order — the serialization
    contract the batched fault engine reproduces: the first thread to
    touch a shared mapping granule (or missing PT entry) allocates it,
    later same-step threads take the cheap "wait" path, and once an
    allocation fails every later thread is OOM-gated.  Because mapped-ness
    and PT-entry existence are policy-independent, that conflict structure
    is exactly ``sim.fault_schedule``'s host-precomputed bits, and
    :meth:`OracleSim.run` *asserts* the equivalence on the fly (pre-OOM,
    when starting from a pristine address space — a chained second run
    is pre-populated, where the schedule over-approximates by design):
    phase A's miss set must equal the schedule's DO bits and the
    real-fault/wait split must equal its WINNER bits;
  * TLB/PWC victim choice: ``argmin`` over LRU stamps with lowest-way
    tie-break, empty slots stamped -1;
  * AutoNUMA ordering via the same composite integer sort keys;
  * Algorithm-1 trigger batches: first-per-leaf evaluates, winners apply,
    later triggers are judged against the post-migration table; try-lock
    conflicts resolve to the earliest batch position per mid-level page.
"""
from __future__ import annotations

import numpy as np

from .config import (CostConfig, MachineConfig, PolicyConfig, INTERLEAVE,
                     MIG_NOMAD, MIG_TPP, PT_BIND_ALL, PT_BIND_HIGH,
                     PT_FOLLOW_DATA)
from .sim import (SCHED_DO, SCHED_WINNER, Trace, fault_schedule)

_MIX = (0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F)
M32 = 0xFFFFFFFF


def bern(p, site, *keys) -> bool:
    h = (0x811C9DC5 + 0x1000193 * site) & M32
    for i, k in enumerate(keys):
        h = ((h ^ (int(k) & M32)) * _MIX[i % 4]) & M32
    h = (h >> 8) & 0xFFFFFF
    thr = int(np.float32(p) * np.float32(1 << 24))
    return h < thr


class _Tlb:
    def __init__(self, sets, ways):
        self.sets, self.ways = sets, ways
        self.tags = np.full((sets, ways), -1, np.int64)
        self.lru = np.full((sets, ways), -1, np.int64)

    def lookup(self, tag):
        s = tag % self.sets
        ways = self.tags[s]
        hits = np.where(ways == tag)[0]
        if len(hits):
            return True, int(hits[0])
        return False, int(np.argmin(self.lru[s]))

    def update(self, tag, way, now):
        s = tag % self.sets
        self.tags[s, way] = tag
        self.lru[s, way] = now

    def invalidate_where(self, pred):
        for s in range(self.sets):
            for w in range(self.ways):
                t = self.tags[s, w]
                if t >= 0 and pred(int(t)):
                    self.tags[s, w] = -1
                    self.lru[s, w] = -1


class OracleSim:
    def __init__(self, mc: MachineConfig, cc: CostConfig, pc: PolicyConfig):
        self.mc, self.cc, self.pc = mc, cc, pc
        T = mc.n_threads
        self.n_map = mc.n_map
        self.n_leaf = mc.n_leaf_pages
        self.rb = mc.radix_bits
        self.n_mid = mc.n_mid_pages
        self.n_top = mc.n_top_pages
        self.thp = mc.page_order > 0
        self.nt = mc.n_tiers
        self.tier_of = mc.tier_of_node
        self.rd_vals = [cc.dram_read] + [cc.cxl_read] * (self.nt - 2) \
            + [cc.nvmm_read]
        self.wr_vals = [cc.dram_write] + [cc.cxl_write] * (self.nt - 2) \
            + [cc.nvmm_write]

        self.data_node = np.full(self.n_map, -1, np.int64)
        self.leaf_node = np.full(self.n_leaf, -1, np.int64)
        self.mid_node = np.full(self.n_mid, -1, np.int64)
        self.top_node = np.full(self.n_top, -1, np.int64)
        self.root_node = np.full(1, -1, np.int64)
        self.ldc = np.zeros(self.n_leaf, np.int64)

        cap = np.array(mc.node_capacity(), np.int64)
        self.reclaimable = (cap.astype(np.float32) * mc.reclaimable_frac
                            ).astype(np.int64)
        self.free = cap - self.reclaimable
        self.wm = (cap.astype(np.float32) * mc.low_watermark).astype(np.int64)
        self.interleave_ptr = 0
        self.oom = False
        self.oom_step = -1
        self.access = np.zeros(self.n_map, np.int64)
        self.shadow = np.full(self.n_map, -1, np.int64)
        self.written = np.zeros(self.n_map, np.int64)

        self.l1 = [_Tlb(mc.l1_tlb_sets, mc.l1_tlb_ways) for _ in range(T)]
        self.stlb = [_Tlb(mc.stlb_sets, mc.stlb_ways) for _ in range(T)]
        self.pde = [_Tlb(1, mc.pde_pwc_entries) for _ in range(T)]
        self.pdpte = [_Tlb(1, mc.pdpte_pwc_entries) for _ in range(T)]

        self.cy_total = np.zeros(T, np.float32)
        self.cy_walk = np.zeros(T, np.float32)
        self.cy_stall = np.zeros(T, np.float32)
        self.cy_data = np.zeros(T, np.float32)
        self.cy_fault = np.zeros(T, np.float32)
        self.cy_mig = np.float32(0)
        self.cnt = dict(l1_hits=0, stlb_hits=0, walks=0, walk_mem_reads=0,
                        faults=0, slow_allocs=0, data_migrations=0,
                        demotions=0, l4_mig_success=0, l4_mig_already_dest=0,
                        l4_mig_in_dram=0, l4_mig_sibling_guard=0,
                        l4_mig_lock_skip=0, oom_kills=0, nomad_retries=0,
                        nomad_flip_demotions=0, nomad_shadow_drops=0)
        self.data_allocs = np.zeros(len(cap), np.int64)
        self.pt_allocs = np.zeros(len(cap), np.int64)
        self.step = 0

    # ---------------- helpers -------------------------------------------------
    def _is_dram(self, n):
        return 0 <= n < 2

    def _tier(self, n):
        """Tier of a node; node -1 (unallocated) maps to the slowest tier,
        mirroring ``migrate.tier_ext``'s node+1 indexing."""
        return self.nt - 1 if n < 0 else int(self.tier_of[n])

    def _rd(self, n):
        return np.float32(self.rd_vals[self._tier(n)])

    def _wr_(self, n):
        return np.float32(self.wr_vals[self._tier(n)])

    def _alloc_one(self, prefs, ignore_wm):
        """Mirror of alloc.alloc_one."""
        cand_fast = cand_slow = cand_rec = None
        for p in prefs:
            if p < 0:
                continue
            wm = 0 if ignore_wm else self.wm[p]
            if cand_fast is None and self.free[p] > wm:
                cand_fast = p
            if cand_slow is None and self.free[p] > 0:
                cand_slow = p
            if cand_rec is None and self.reclaimable[p] > 0:
                cand_rec = p
        if cand_fast is not None:
            self.free[cand_fast] -= 1
            return cand_fast, False
        if cand_slow is not None:
            self.free[cand_slow] -= 1
            return cand_slow, True
        if cand_rec is not None:
            self.reclaimable[cand_rec] -= 1
            return cand_rec, True
        return -1, True

    def _data_prefs(self, t):
        if self.pc.data_policy == INTERLEAVE:
            # round-robin over the *allocatable* nodes only (zero-capacity
            # middle tiers never perturb the rotation)
            alloc = self.mc.alloc_nodes
            a = len(alloc)
            s = self.interleave_ptr % a
            return [alloc[(s + i) % a] for i in range(a)]
        # local then remote node of each tier, fastest tier first
        local = 0 if t < self.mc.n_threads // 2 else 1
        prefs = []
        for tt in range(self.nt):
            prefs += [2 * tt + local, 2 * tt + (1 - local)]
        return prefs

    def _dram_prefs(self, t):
        local = 0 if t < self.mc.n_threads // 2 else 1
        return [local, 1 - local]

    def _alloc_pt(self, t, arr, idx, is_upper):
        """Mirror of sim._alloc_pt_level; returns cycles charged."""
        if arr[idx] >= 0:
            return np.float32(0)
        pc = self.pc
        cost = np.float32(0)
        data_prefs = self._data_prefs(t)
        if pc.pt_policy == PT_BIND_ALL or (
                pc.pt_policy == PT_BIND_HIGH and (is_upper or self.thp)):
            node, slow = self._alloc_one(self._dram_prefs(t), True)
            if node < 0 and pc.pt_policy == PT_BIND_HIGH:
                node, slow = self._alloc_one(data_prefs, False)
        else:
            node, slow = self._alloc_one(data_prefs, False)
        if node < 0:
            self.oom = True
            if self.oom_step < 0:
                self.oom_step = self.step
            self.cnt["oom_kills"] += 1
            return np.float32(self.cc.oom_scan)
        arr[idx] = node
        self.pt_allocs[node] += 1
        if slow:
            self.cnt["slow_allocs"] += 1
        if (pc.pt_policy == PT_FOLLOW_DATA
                and pc.data_policy == INTERLEAVE):
            self.interleave_ptr += 1
        cost += np.float32(self.cc.zero_lines) * self._wr_(node)
        cost += np.float32(self.cc.alloc_slow if slow else self.cc.alloc_fast)
        return cost

    # ---------------- AutoNUMA / TPP / Nomad + Algorithm 1 -------------------
    def _autonuma(self, va_row, w_row):
        """One balancing scan, mirroring ``migrate.autonuma_scan`` exactly.

        ``va_row``/``w_row`` are the current step's access row — Nomad's
        concurrent-write abort condition (unused by the other families).
        """
        mc, cc, pc = self.mc, self.cc, self.pc
        nt = self.nt
        bt = min(int(pc.autonuma_budget), self.n_map)
        idx_bits = max(self.n_map - 1, 1).bit_length()
        nn = 1 << idx_bits
        en_tpp = int(pc.mig_policy) == MIG_TPP
        en_nomad = int(pc.mig_policy) == MIG_NOMAD

        def rank_key(count, i):
            return (min(max(count, 0), 255) << idx_bits) | (nn - 1 - i)

        # (0) Nomad shadow invalidation: a write since the last scan made
        # the shadow stale; drop it and free its page.
        if en_nomad:
            for i in range(self.n_map):
                if self.shadow[i] >= 0 and self.written[i] > 0:
                    self.free[self.shadow[i]] += 1
                    self.shadow[i] = -1
                    self.cnt["nomad_shadow_drops"] += 1

        # (1) hot candidates: same recent-access test in every family
        hot = [(rank_key(self.access[i], i), i) for i in range(self.n_map)
               if self.data_node[i] >= 2
               and self.access[i] >= pc.autonuma_threshold
               and self.access[i] > 0]
        hot.sort(key=lambda kv: -kv[0])
        hot_pages = [i for _, i in hot]
        n_hot = min(len(hot_pages), bt)

        # (2) cold tier-0 victims; TPP narrows to the *inactive* list
        cold = [(rank_key(255 - min(self.access[i], 255), i), i)
                for i in range(self.n_map)
                if self._is_dram(self.data_node[i])
                and (not en_tpp or self.access[i] < pc.autonuma_threshold)]
        cold.sort(key=lambda kv: -kv[0])
        cold_pages = [i for _, i in cold]
        n_victims = min(len(cold_pages), bt)

        excess0 = max(self.free[0] - self.wm[0], 0)
        excess1 = max(self.free[1] - self.wm[1], 0)
        dram_excess = excess0 + excess1
        n_promote_want = min(n_hot, bt)
        need_demote = max(n_promote_want - dram_excess, 0)

        # TPP demotes ahead of reclaim pressure: watermark + headroom
        # fraction of tier-0 capacity, independent of promotion demand.
        cap0 = 2 * mc.tier_capacities[0]
        tpp_extra = int(np.float32(np.float32(pc.tpp_demote_wm) * cap0))
        need_tpp = max(int(self.wm[0]) + int(self.wm[1]) + tpp_extra
                       - (int(self.free[0]) + int(self.free[1])), 0)
        need_eff = max(need_tpp, need_demote) if en_tpp else need_demote

        # demotion destination pair: TPP -> next-slower non-empty tier,
        # AutoNUMA/Nomad -> slowest tier
        caps = mc.tier_capacities
        tpp_t = next(t for t in range(1, nt) if caps[t] > 0)
        dest_a = 2 * tpp_t if en_tpp else 2 * (nt - 1)
        dest_b = dest_a + 1
        cap_a = int(self.free[dest_a])
        cap_b = int(self.free[dest_b])
        room = max(cap_a, 0) + max(cap_b, 0)
        dem_en = True if en_tpp else bool(pc.autonuma_exchange)
        n_demote = min(min(need_eff, n_victims), room) if dem_en else 0
        n_promote = min(n_promote_want, dram_excess + n_demote)

        def split_two(n, ca, cb):
            if ca >= cb:
                return max(min(ca, n), 0)
            return max(n - min(cb, n), 0)

        cost = np.float32(0)
        triggers = []     # (page, dest) in batch order
        migrated = []

        share_a = split_two(n_demote, cap_a, cap_b)
        for k in range(n_demote):
            page = cold_pages[k]
            dest = dest_a if k < share_a else dest_b
            src = self.data_node[page]
            # Nomad flip: a surviving (clean) shadow *becomes* the page —
            # no copy, no new occupancy on the destination.
            flip = en_nomad and self.shadow[page] >= 0
            dest_eff = int(self.shadow[page]) if flip else dest
            self.data_node[page] = dest_eff
            self.free[src] += 1
            if flip:
                self.shadow[page] = -1
                self.cnt["nomad_flip_demotions"] += 1
            else:
                self.free[dest_eff] -= 1
            self.ldc[page >> self.rb] -= 1
            add = np.float32(cc.migrate_fixed + cc.tlb_flush)
            if not flip:
                add = add + np.float32(cc.copy_lines) * \
                    (self._rd(src) + self._wr_(dest_eff))
            cost += add
            self.cnt["demotions"] += 1
            self.cnt["data_migrations"] += 1
            triggers.append((page, dest_eff))
            migrated.append(page)

        # granules written *this step* (Nomad's transactional-abort set)
        conc_w = set()
        for t in range(mc.n_threads):
            va = int(va_row[t])
            if va >= 0 and bool(w_row[t]):
                conc_w.add(min(va >> mc.map_shift, self.n_map - 1))

        excess0b = max(self.free[0] - self.wm[0], 0)
        excess1b = max(self.free[1] - self.wm[1], 0)
        share0 = split_two(n_promote, excess0b, excess1b)
        for k in range(n_promote):
            page = hot_pages[k]
            src = self.data_node[page]
            if en_nomad and page in conc_w:
                # transactional abort: the copy's read half + bookkeeping
                # were already paid; the page retries at a later scan
                cost += np.float32(cc.migrate_fixed) + \
                    np.float32(cc.copy_lines) * self._rd(src)
                self.cnt["nomad_retries"] += 1
                continue
            dest = 0 if k < share0 else 1
            self.data_node[page] = dest
            if en_nomad:
                self.shadow[page] = src   # non-exclusive: keep clean shadow
            else:
                self.free[src] += 1
            self.free[dest] -= 1
            self.ldc[page >> self.rb] += 1
            cost += np.float32(cc.migrate_fixed + cc.tlb_flush) + \
                np.float32(cc.copy_lines) * (self._rd(src) + self._wr_(dest))
            self.cnt["data_migrations"] += 1
            triggers.append((page, dest))
            migrated.append(page)

        mig_set = set(migrated)
        for tlb_list in (self.l1, self.stlb):
            for tlb in tlb_list:
                tlb.invalidate_where(lambda tag: tag in mig_set)
        if en_nomad:
            self.written[:] = 0
        self.access //= 2

        if pc.mig and triggers:
            cost += self._migrate_leaf_batch(triggers)
        return cost

    def _migrate_leaf_batch(self, triggers):
        cc = self.cc
        cost = np.float32(0)
        pre_free = self.free.copy()
        seen_leaf = {}
        first_flags = []
        for pos, (page, dest) in enumerate(triggers):
            leaf = page >> self.rb
            first = leaf not in seen_leaf
            seen_leaf.setdefault(leaf, pos)
            first_flags.append(first)

        # pass 1: firsts evaluate against the pre-batch table
        wants = []
        for pos, (page, dest) in enumerate(triggers):
            if not first_flags[pos]:
                continue
            leaf = page >> self.rb
            l4n = self.leaf_node[leaf]
            if l4n < 0:
                continue
            if l4n == dest:
                self.cnt["l4_mig_already_dest"] += 1
                continue
            if self._tier(l4n) == self._tier(dest):
                self.cnt["l4_mig_in_dram"] += 1
                continue
            if self._tier(dest) > 0 and self.ldc[leaf] > 0:
                self.cnt["l4_mig_sibling_guard"] += 1
                continue
            wants.append(pos)

        locked_mids = set()
        winners = []
        for pos in wants:
            page, dest = triggers[pos]
            mid = (page >> self.rb) >> self.mc.lock_domain_shift
            if mid in locked_mids:
                self.cnt["l4_mig_lock_skip"] += 1
                continue
            locked_mids.add(mid)
            if pre_free[dest] <= 0:
                self.cnt["l4_mig_lock_skip"] += 1
                continue
            winners.append(pos)

        flushed_leaves = set()
        for pos in winners:
            page, dest = triggers[pos]
            leaf = page >> self.rb
            src = self.leaf_node[leaf]
            self.leaf_node[leaf] = dest
            self.free[src] += 1
            self.free[dest] -= 1
            cost += np.float32(cc.migrate_fixed + cc.tlb_flush + cc.alloc_fast) \
                + np.float32(cc.copy_lines) * (self._rd(src) + self._wr_(dest))
            self.cnt["l4_mig_success"] += 1
            flushed_leaves.add(leaf)

        # pass 2: non-first triggers judged against the post-migration table
        for pos, (page, dest) in enumerate(triggers):
            if first_flags[pos]:
                continue
            leaf = page >> self.rb
            new_l4 = self.leaf_node[leaf]
            if new_l4 == dest:
                self.cnt["l4_mig_already_dest"] += 1
            elif self._tier(new_l4) == self._tier(dest):
                self.cnt["l4_mig_in_dram"] += 1
            elif self._tier(dest) > 0 and self.ldc[leaf] > 0:
                self.cnt["l4_mig_sibling_guard"] += 1

        for tlb_list in (self.l1, self.stlb):
            for tlb in tlb_list:
                tlb.invalidate_where(lambda tag: (tag >> self.rb) in flushed_leaves)
        for tlb in self.pde:
            tlb.invalidate_where(lambda tag: tag in flushed_leaves)
        return cost

    # ---------------- step ----------------------------------------------------
    def run(self, trace: Trace):
        mc, cc, pc = self.mc, self.cc, self.pc
        T = mc.n_threads
        shift = mc.map_shift
        seg_of_map = np.asarray(trace.seg_of_map)
        n_leaf = self.n_leaf
        seg_of_leaf = seg_of_map[(np.arange(n_leaf) << self.rb) % max(self.n_map, 1)]
        # The host-precomputed fault schedule must predict this oracle's
        # phase-B behavior exactly until the OOM latch fires (see module
        # docstring); both assertions below enforce that equivalence.
        # They only hold from a pristine address space — a chained
        # second run() (resume-style) starts pre-populated, where the
        # schedule deliberately over-approximates, so skip them then.
        assert_schedule = self.step == 0
        sched = fault_schedule(trace, self.mc)

        for s in range(trace.n_steps):
            oom_at_step_start = self.oom
            fid = int(trace.free_seg[s])
            if fid >= 0:
                self._free_segment(fid, seg_of_map, seg_of_leaf)

            va_row = trace.va[s]
            w_row = trace.is_write[s]
            llc_rate = float(trace.llc[s])

            if pc.autonuma and self.step > 0 \
                    and self.step % pc.autonuma_period == 0 and not self.oom:
                c = self._autonuma(va_row, w_row)
                self.cy_total += c * np.float32(cc.mig_cost_scale) / np.float32(T)
                self.cy_mig += c

            # ---- phase A ------------------------------------------------
            fault_mask = np.zeros(T, bool)
            for t in range(T):
                va = int(va_row[t])
                if va < 0 or self.oom:
                    continue
                m = min(max(va >> shift, 0), self.n_map - 1)
                if self.data_node[m] < 0:
                    fault_mask[t] = True
                    continue
                self._mapped_access(t, m, bool(w_row[t]), llc_rate)
            if assert_schedule and not oom_at_step_start:
                exp_do = (sched[s] & SCHED_DO) > 0
                assert (fault_mask == exp_do).all(), \
                    f"step {s}: fault_schedule DO bits diverge from oracle"
            # ---- phase B ------------------------------------------------
            for t in range(T):
                if not fault_mask[t] or self.oom:
                    continue
                va = int(va_row[t])
                m = min(max(va >> shift, 0), self.n_map - 1)
                assert not assert_schedule or \
                    (self.data_node[m] < 0) == bool(sched[s, t]
                                                    & SCHED_WINNER), \
                    f"step {s} thread {t}: WINNER bit diverges from oracle"
                self._fault(t, m, bool(w_row[t]))
            self.step += 1

    def _mapped_access(self, t, m, is_write, llc_rate):
        cc = self.cc
        now = self.step
        hit1, way1 = self.l1[t].lookup(m)
        hit2, way2 = self.stlb[t].lookup(m)
        walkn = not hit1 and not hit2
        leaf_id, mid_id, top_id = m >> self.rb, m >> (2 * self.rb), m >> (3 * self.rb)
        pde_hit, pde_way = self.pde[t].lookup(leaf_id)
        pdpte_hit, pdpte_way = self.pdpte[t].lookup(mid_id)

        walk_cost = np.float32(0)
        walk_reads = 0
        if walkn:
            leaf_llc = bern(cc.leaf_llc_hit, 1, m, now, t)
            up1 = bern(cc.upper_llc_hit, 2, mid_id, now, t)
            up2 = bern(cc.upper_llc_hit, 3, top_id, now, t)
            leaf_read = np.float32(cc.llc_hit) if leaf_llc \
                else self._rd(self.leaf_node[leaf_id])
            mid_read = np.float32(0)
            if not pde_hit:
                mid_read = np.float32(cc.llc_hit) if up1 \
                    else self._rd(self.mid_node[min(mid_id, self.n_mid - 1)])
            full = not pde_hit and not pdpte_hit
            top_read = np.float32(0)
            if full and not self.thp:
                top_read = np.float32(cc.llc_hit) if up2 \
                    else self._rd(self.top_node[min(top_id, self.n_top - 1)])
            root_read = np.float32(cc.llc_hit) if full else np.float32(0)
            walk_cost = leaf_read + mid_read + top_read + root_read
            walk_reads = int(not leaf_llc) + int(not pde_hit and not up1) \
                + (int(full and not up2) if not self.thp else 0)
            self.cnt["walks"] += 1
            self.cnt["walk_mem_reads"] += walk_reads
        elif hit1:
            self.cnt["l1_hits"] += 1
        else:
            self.cnt["stlb_hits"] += 1

        data_llc = bern(llc_rate, 4, m, now, t)
        node = self.data_node[m]
        mem = self._wr_(node) if is_write else self._rd(node)
        data_cost = np.float32(cc.llc_hit) if data_llc else mem

        tlb_pen = np.float32(cc.stlb_hit) if not hit1 else np.float32(0)
        stall = walk_cost + np.float32(cc.data_stall_frac) * data_cost
        total = np.float32(cc.cpu_work) + tlb_pen + stall

        self.l1[t].update(m, way1, now)
        if not hit1:
            self.stlb[t].update(m, way2, now)
        if walkn:
            self.pde[t].update(leaf_id, pde_way, now)
            self.pdpte[t].update(mid_id, pdpte_way, now)
        self.access[m] += 1
        if is_write:
            self.written[m] += 1
        self.cy_total[t] += total
        self.cy_walk[t] += walk_cost
        self.cy_stall[t] += stall
        self.cy_data[t] += data_cost

    def _fault(self, t, m, is_write=False):
        cc = self.cc
        now = self.step
        if self.data_node[m] >= 0:      # raced with an earlier thread
            cost = np.float32(cc.fault_base) + np.float32(cc.llc_hit)
            self.cy_data[t] += np.float32(cc.llc_hit)
        else:
            cost = np.float32(0)
            cost += self._alloc_pt(t, self.root_node, 0, True)
            cost += self._alloc_pt(t, self.top_node,
                                   min(m >> (3 * self.rb), self.n_top - 1), True)
            cost += self._alloc_pt(t, self.mid_node,
                                   min(m >> (2 * self.rb), self.n_mid - 1), True)
            cost += self._alloc_pt(t, self.leaf_node, m >> self.rb, False)
            node, slow = self._alloc_one(self._data_prefs(t), False)
            if node < 0:
                self.oom = True
                if self.oom_step < 0:
                    self.oom_step = self.step
                self.cnt["oom_kills"] += 1
                cost += np.float32(cc.oom_scan)
            else:
                self.data_node[m] = node
                self.data_allocs[node] += 1
                if self._is_dram(node):
                    self.ldc[m >> self.rb] += 1
                if slow:
                    self.cnt["slow_allocs"] += 1
                if self.pc.data_policy == INTERLEAVE:
                    self.interleave_ptr += 1
                cost += np.float32(cc.zero_lines) * self._wr_(node) + \
                    np.float32(cc.alloc_slow if slow else cc.alloc_fast)
            mid_n = self.mid_node[min(m >> (2 * self.rb), self.n_mid - 1)]
            leaf_n = self.leaf_node[m >> self.rb]
            cost += np.float32(cc.fault_base) + self._rd(mid_n) + self._wr_(leaf_n)
            self.cnt["faults"] += 1

        _, w1 = self.l1[t].lookup(m)
        self.l1[t].update(m, w1, now)
        _, w2 = self.stlb[t].lookup(m)
        self.stlb[t].update(m, w2, now)
        _, w3 = self.pde[t].lookup(m >> self.rb)
        self.pde[t].update(m >> self.rb, w3, now)
        _, w4 = self.pdpte[t].lookup(m >> (2 * self.rb))
        self.pdpte[t].update(m >> (2 * self.rb), w4, now)
        self.access[m] += 1
        if is_write:
            self.written[m] += 1
        self.cy_total[t] += cost
        self.cy_fault[t] += cost

    def _free_segment(self, fid, seg_of_map, seg_of_leaf):
        for i in range(self.n_map):
            if seg_of_map[i] == fid and self.data_node[i] >= 0:
                n = self.data_node[i]
                self.free[n] += 1
                if self._is_dram(n):
                    self.ldc[i >> self.rb] = max(self.ldc[i >> self.rb] - 1, 0)
                self.data_node[i] = -1
                self.access[i] = 0
                self.written[i] = 0
            if seg_of_map[i] == fid and self.shadow[i] >= 0:
                # Nomad shadows of freed granules go with the segment
                self.free[self.shadow[i]] += 1
                self.shadow[i] = -1
        freed_leaves = set()
        for l in range(self.n_leaf):
            if seg_of_leaf[l] == fid and self.leaf_node[l] >= 0:
                self.free[self.leaf_node[l]] += 1
                self.leaf_node[l] = -1
                freed_leaves.add(l)
        freed_maps = set(int(i) for i in np.where(seg_of_map == fid)[0])
        for tlb_list in (self.l1, self.stlb):
            for tlb in tlb_list:
                tlb.invalidate_where(lambda tag: tag in freed_maps)
        for tlb in self.pde:
            tlb.invalidate_where(lambda tag: tag in freed_leaves)

    # ---------------- results ------------------------------------------------
    def summary(self):
        out = dict(self.cnt)
        out.update(
            total_cycles=float(np.sum(self.cy_total)),
            walk_cycles=float(np.sum(self.cy_walk)),
            stall_cycles=float(np.sum(self.cy_stall)),
            data_mem_cycles=float(np.sum(self.cy_data)),
            fault_cycles=float(np.sum(self.cy_fault)),
            migration_cycles=float(self.cy_mig),
            oom_killed=self.oom, oom_step=self.oom_step,
            data_pages_dram=int(np.sum((self.data_node >= 0)
                                       & (self.data_node < 2))),
            data_pages_nvmm=int(np.sum(self.data_node >= 2)),
            leaf_pages_dram=int(np.sum((self.leaf_node >= 0)
                                       & (self.leaf_node < 2))),
            leaf_pages_nvmm=int(np.sum(self.leaf_node >= 2)),
            data_pages_per_tier=[
                int(np.sum((self.data_node >= 2 * t)
                           & (self.data_node < 2 * t + 2)))
                for t in range(self.nt)],
            leaf_pages_per_tier=[
                int(np.sum((self.leaf_node >= 2 * t)
                           & (self.leaf_node < 2 * t + 2)))
                for t in range(self.nt)],
            shadow_pages=int(np.sum(self.shadow >= 0)),
        )
        return out
