"""Architecture and shape configuration.

Every assigned architecture is an :class:`ArchConfig`; the four assigned
input shapes are :data:`SHAPES`.  ``reduced()`` produces the CPU-smoke-test
variant of an architecture (same family/topology, tiny widths).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden width
    shared_expert: bool = False    # llama4-style always-on shared expert
    capacity_factor: float = 1.25
    every: int = 1                 # MoE layer every N layers (jamba: 2)


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int                   # attention heads (0 for attn-free)
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None   # defaults to d_model // n_heads
    mlp: str = "swiglu"            # swiglu | squared_relu | gelu
    qkv_bias: bool = False
    rope: str = "rope"             # rope | mrope | none
    encoder_only: bool = False
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    attn_every: int = 1            # jamba: attention layer every N (=8)
    rwkv: bool = False
    frontend: Optional[str] = None  # vision | audio (stubbed embeddings)
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False

    @property
    def head_dim(self) -> int:
        if self.d_head is not None:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def attn_free(self) -> bool:
        return self.rwkv

    @property
    def sub_quadratic(self) -> bool:
        """Supports 500k-token decode (SSM / hybrid with O(1) state)."""
        return self.rwkv or self.mamba is not None

    @property
    def has_decode(self) -> bool:
        return not self.encoder_only

    def param_dtype(self):
        return jnp.dtype(self.dtype)

    def n_params(self) -> int:
        """Approximate parameter count (for MODEL_FLOPS and sanity checks)."""
        d, L = self.d_model, self.n_layers
        dh = self.head_dim
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        for i in range(L):
            is_attn = (i % self.attn_every) == (self.attn_every - 1) \
                if self.attn_every > 1 else True
            if self.rwkv:
                per_layer += 4 * d * d + 2 * d * self.d_ff   # time-mix + channel-mix
                continue
            if self.mamba is not None and not is_attn:
                di = self.mamba.expand * d
                per_layer += 2 * d * di + di * d + di * (2 * self.mamba.d_state)
            else:
                per_layer += d * (self.n_heads * dh) * 2 \
                    + d * (self.n_kv_heads * dh) * 2
            if self.moe is not None and (i % self.moe.every
                                         == self.moe.every - 1):
                mult = 3 if self.mlp == "swiglu" else 2
                per_layer += self.moe.n_experts * mult * d * self.moe.d_ff
                per_layer += d * self.moe.n_experts
                if self.moe.shared_expert:
                    per_layer += mult * d * self.moe.d_ff
            elif not (self.rwkv or (self.mamba is not None and not is_attn)):
                mult = 3 if self.mlp == "swiglu" else 2
                per_layer += mult * d * self.d_ff
        return emb + per_layer

    def n_expert_params(self) -> int:
        """Routed-expert parameters only (excludes shared experts)."""
        if self.moe is None:
            return 0
        mult = 3 if self.mlp == "swiglu" else 2
        n_moe_layers = sum(1 for i in range(self.n_layers)
                           if (i % self.moe.every) == self.moe.every - 1)
        return n_moe_layers * self.moe.n_experts * mult \
            * self.d_model * self.moe.d_ff

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: routed top-k + shared only)."""
        if self.moe is None:
            return self.n_params()
        full = self.n_params()
        mult = 3 if self.mlp == "swiglu" else 2
        n_moe_layers = sum(1 for i in range(self.n_layers)
                           if (i % self.moe.every) == self.moe.every - 1)
        all_experts = n_moe_layers * self.moe.n_experts * mult \
            * self.d_model * self.moe.d_ff
        active = n_moe_layers * self.moe.top_k * mult \
            * self.d_model * self.moe.d_ff
        return full - all_experts + active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_is_valid(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is a runnable dry-run cell; reason if not.

    Skips follow the assignment text: encoder-only archs have no decode
    step; ``long_500k`` needs sub-quadratic attention.
    """
    if shape.is_decode and not arch.has_decode:
        return False, "encoder-only: no decode step"
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "full-attention arch: 500k decode skipped per assignment"
    return True, ""


def reduced(arch: ArchConfig) -> ArchConfig:
    """Tiny same-family variant for CPU smoke tests."""
    kw = dict(
        name=arch.name + "-smoke",
        n_layers=min(arch.n_layers, 4 if arch.attn_every <= 1
                     else arch.attn_every),
        d_model=128,
        n_heads=min(arch.n_heads, 4) if arch.n_heads else 0,
        n_kv_heads=min(arch.n_kv_heads, 2) if arch.n_kv_heads else 0,
        d_head=32 if arch.n_heads else None,
        d_ff=256,
        vocab=512,
    )
    if arch.moe is not None:
        kw["moe"] = dataclasses.replace(arch.moe, n_experts=4,
                                        top_k=min(arch.moe.top_k, 2),
                                        d_ff=128)
    if arch.rwkv:
        kw["n_heads"] = 2
        kw["n_kv_heads"] = 2
        kw["d_head"] = 64           # RWKV6 head size is fixed at 64
        kw["d_model"] = 128
    return dataclasses.replace(arch, **kw)
