"""Jamba-v0.1-52B [arXiv:2403.19887]: Mamba+attention 1:7, MoE 16e top-2.

Layer schedule per 8-layer period: attention at offset 3, Mamba elsewhere;
MoE MLP every second layer (16 MoE layers over 32).
"""
from .base import ArchConfig, MambaConfig, MoEConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65536, mlp="swiglu", rope="none",
    attn_every=8, mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff=14336, every=2))
