"""Architecture registry: ``get_config(arch_id)`` + the shape table."""
from .base import (ArchConfig, MambaConfig, MoEConfig, ShapeConfig, SHAPES,
                   cell_is_valid, reduced)

from . import (nemotron_4_340b, deepseek_coder_33b, qwen2_5_14b,
               qwen1_5_0_5b, llama4_maverick_400b, llama4_scout_17b,
               qwen2_vl_2b, hubert_xlarge, jamba_v0_1_52b, rwkv6_3b)

_MODULES = (nemotron_4_340b, deepseek_coder_33b, qwen2_5_14b, qwen1_5_0_5b,
            llama4_maverick_400b, llama4_scout_17b, qwen2_vl_2b,
            hubert_xlarge, jamba_v0_1_52b, rwkv6_3b)

REGISTRY = {m.CONFIG.name: m.CONFIG for m in _MODULES}
ARCH_IDS = tuple(REGISTRY)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[arch_id]


__all__ = ["ArchConfig", "MambaConfig", "MoEConfig", "ShapeConfig", "SHAPES",
           "REGISTRY", "ARCH_IDS", "get_config", "cell_is_valid", "reduced"]
