"""RWKV6-3B "Finch" [arXiv:2404.05892]: attention-free, data-dependent decay.

d_model=2560 -> 40 heads of fixed size 64.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, d_head=64,
    d_ff=8960, vocab=65536, mlp="rwkv_channel_mix", rope="none", rwkv=True)
