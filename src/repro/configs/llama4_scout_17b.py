"""Llama-4-Scout-17B-16E [hf:meta-llama]: MoE 16e top-1, shared expert."""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048, mlp="swiglu", rope="rope",
    moe=MoEConfig(n_experts=16, top_k=1, d_ff=8192, shared_expert=True))
