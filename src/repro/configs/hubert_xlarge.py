"""HuBERT-XLarge [arXiv:2106.07447]: encoder-only audio transformer.

The convolutional waveform frontend is a stub: ``input_specs`` supplies
precomputed frame embeddings; the head predicts 504 cluster targets.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab=504, mlp="gelu", rope="none", encoder_only=True,
    frontend="audio")
