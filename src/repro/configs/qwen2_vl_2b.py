"""Qwen2-VL-2B [arXiv:2409.12191]: VLM backbone with M-RoPE.

The vision frontend is a stub: ``input_specs`` supplies precomputed patch
embeddings and 3-component (t, h, w) M-RoPE position ids.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151936, mlp="swiglu", qkv_bias=True, rope="mrope",
    frontend="vision")
