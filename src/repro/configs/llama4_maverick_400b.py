"""Llama-4-Maverick-400B-A17B [hf:meta-llama]: MoE 128e top-1, shared expert.

MoE layers interleave with dense layers (every=2), as in the released
architecture; this lands the total at ~400B with ~17B active."""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048, mlp="swiglu", rope="rope",
    moe=MoEConfig(n_experts=128, top_k=1, d_ff=8192, shared_expert=True,
                  every=2))
