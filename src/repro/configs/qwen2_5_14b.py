"""Qwen2.5-14B [hf:Qwen]: dense GQA with QKV bias."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=13824, vocab=152064, mlp="swiglu", qkv_bias=True, rope="rope")
