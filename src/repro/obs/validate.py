"""CLI artifact validator: ``python -m repro.obs.validate <file ...>``.

Schema-aware: each argument is classified by content and validated —

  * ``*.jsonl``           — a BenchRecord history log; every line must
                            be a schema-valid ``bench-record/v1``;
  * ``schema: postmortem/v1`` — a flight-recorder dump
                            (:func:`repro.obs.telemetry.validate_postmortem`);
  * ``schema: bench-record/v1`` — a single BenchRecord object;
  * ``traceEvents``       — Chrome/Perfetto ``trace_event`` JSON
                            (well-formed, balanced, nested spans,
                            monotonic B/E tracks).

Exits 0 when every file is clean (printing a one-line summary per
file); prints every problem and exits 1 otherwise; 2 on usage errors.
"""
from __future__ import annotations

import json
import sys
from typing import List, Tuple

from .bench import RECORD_SCHEMA, validate_record
from .telemetry import POSTMORTEM_SCHEMA, validate_postmortem
from .tracing import validate_trace_events


def validate_file(path: str) -> Tuple[List[str], str]:
    """Validate one artifact file; returns (problems, ok-summary)."""
    if path.endswith(".jsonl"):
        problems: List[str] = []
        n = 0
        seen: dict = {}
        try:
            lines = open(path).read().splitlines()
        except OSError as e:
            return [f"unreadable: {e}"], ""
        for i, line in enumerate(lines, start=1):
            if not line.strip():
                continue
            n += 1
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                problems.append(f"line {i}: unparseable: {e}")
                continue
            problems += [f"line {i}: {p}" for p in validate_record(rec)]
            # duplicate-append guard: one run id is shared by every
            # driver of one ``benchmarks.run`` invocation, but a given
            # (run_id, driver) pair must appear exactly once per history
            # manifest — a repeat means a double-append (crashed rerun,
            # botched merge) that would skew the regression gate's
            # best-of-last-N windows.
            key = (rec.get("run_id"), rec.get("driver"))
            if key in seen:
                problems.append(
                    f"line {i}: duplicate record for run_id={key[0]} "
                    f"driver={key[1]!r} (first at line {seen[key]}; "
                    f"double-append?)")
            else:
                seen[key] = i
        if not n:
            problems.append("empty history (no records)")
        return problems, f"ok — {n} bench records"
    try:
        with open(path) as fh:
            obj = json.load(fh)
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable: {e}"], ""
    schema = obj.get("schema") if isinstance(obj, dict) else None
    if schema == POSTMORTEM_SCHEMA:
        problems = validate_postmortem(obj)
        return problems, (f"ok — postmortem at {obj.get('site')}, "
                          f"{len(obj.get('spans', []))} spans, "
                          f"{len(obj.get('metrics_delta', {}))} metric "
                          f"deltas")
    if schema == RECORD_SCHEMA:
        return validate_record(obj), \
            f"ok — bench record for {obj.get('driver')}"
    if isinstance(obj, dict) and "traceEvents" in obj:
        problems = validate_trace_events(obj)
        n = len(obj["traceEvents"])
        spans = sum(1 for e in obj["traceEvents"] if e.get("ph") == "X")
        return problems, f"ok — {n} events, {spans} spans"
    return ["unrecognized artifact (no known schema or traceEvents)"], ""


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: python -m repro.obs.validate <artifact.json ...>",
              file=sys.stderr)
        return 2
    rc = 0
    for path in argv:
        problems, summary = validate_file(path)
        if problems:
            rc = 1
            for p in problems:
                print(f"{path}: {p}", file=sys.stderr)
        else:
            print(f"{path}: {summary}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
