"""CLI trace validator: ``python -m repro.obs.validate trace.json``.

Exits 0 when the file is well-formed, balanced Chrome/Perfetto
``trace_event`` JSON (the CI telemetry smoke's gate); prints every
problem and exits 1 otherwise.
"""
from __future__ import annotations

import json
import sys

from .tracing import validate_trace_events


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 1:
        print("usage: python -m repro.obs.validate <trace.json>",
              file=sys.stderr)
        return 2
    path = argv[0]
    try:
        with open(path) as fh:
            obj = json.load(fh)
    except (OSError, json.JSONDecodeError) as e:
        print(f"{path}: unreadable trace: {e}", file=sys.stderr)
        return 1
    problems = validate_trace_events(obj)
    if problems:
        for p in problems:
            print(f"{path}: {p}", file=sys.stderr)
        return 1
    n = len(obj["traceEvents"])
    spans = sum(1 for e in obj["traceEvents"] if e.get("ph") == "X")
    print(f"{path}: ok — {n} events, {spans} spans")
    return 0


if __name__ == "__main__":
    sys.exit(main())
