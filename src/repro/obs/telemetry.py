"""The telemetry facade: one object the whole service stack reports into.

``Telemetry`` bundles a :class:`~repro.obs.metrics.MetricsRegistry`
(always on — counters are a few attribute ops) with an optional
:class:`~repro.obs.tracing.SpanRecorder` (``tracing=True``), and
``snapshot()`` renders everything as one flat JSON-friendly dict — the
blessed replacement for ad-hoc ``BrokerStats.as_dict`` readouts in
benchmark artifacts.

``NULL`` is the near-zero-cost default: a :class:`NullTelemetry` whose
``counter/gauge/histogram`` return shared no-op twins and whose ``span``
is a reusable no-op context manager.  Instrumented code holds exactly
one pattern::

    tel = telemetry if telemetry is not None else NULL
    tel.counter("broker.queries").inc()
    with tel.span("bucket.sweep", args={...}):
        ...

so the off path costs one attribute load and one no-op call per site —
and, because every hook is host-side Python, the compiled engines are
bitwise-identical with telemetry on or off (``tests/test_obs.py``
asserts the blocked engine's outputs exactly).
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, List, Optional

from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .tracing import SpanRecorder


class Telemetry:
    """Live metrics registry + optional span recorder."""

    def __init__(self, tracing: bool = False, clock=time.monotonic,
                 max_events: int = 200_000):
        self.metrics = MetricsRegistry()
        self.tracer: Optional[SpanRecorder] = (
            SpanRecorder(clock=clock, max_events=max_events)
            if tracing else None)

    # -------------------------------------------------------- metrics --
    @property
    def enabled(self) -> bool:
        return True

    @property
    def tracing(self) -> bool:
        return self.tracer is not None

    def counter(self, name: str, **labels) -> Counter:
        return self.metrics.counter(name, **labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self.metrics.gauge(name, **labels)

    def histogram(self, name: str, **kw) -> Histogram:
        return self.metrics.histogram(name, **kw)

    # -------------------------------------------------------- tracing --
    def span(self, name: str, cat: str = "service", tid: int = 0,
             args: Optional[Dict] = None):
        if self.tracer is None:
            return _NULL_CTX
        return self.tracer.span(name, cat=cat, tid=tid, args=args)

    def add_span(self, name: str, begin: float, end: float,
                 cat: str = "service", tid: int = 0,
                 args: Optional[Dict] = None) -> None:
        if self.tracer is not None:
            self.tracer.add_span(name, begin, end, cat=cat, tid=tid,
                                 args=args)

    def instant(self, name: str, cat: str = "service", tid: int = 0,
                args: Optional[Dict] = None) -> None:
        if self.tracer is not None:
            self.tracer.instant(name, cat=cat, tid=tid, args=args)

    def now(self) -> Optional[float]:
        """Tracer-clock seconds for explicit add_span bounds (None when
        tracing is off — pair with ``add_span``, which no-ops then)."""
        return None if self.tracer is None else self.tracer.now()

    # -------------------------------------------------------- results --
    def snapshot(self) -> Dict[str, object]:
        """Everything the stack reported, one JSON-friendly dict."""
        out = {"metrics": self.metrics.snapshot()}
        if self.tracer is not None:
            out["trace"] = {"events": len(self.tracer.events),
                            "dropped": self.tracer.dropped}
        return out

    def export_trace(self, path) -> bool:
        """Write the Perfetto trace JSON; False when tracing is off."""
        if self.tracer is None:
            return False
        self.tracer.export(path)
        return True

    def reset(self) -> None:
        self.metrics.reset()
        if self.tracer is not None:
            self.tracer.reset()


# ---------------------------------------------------------------------------
# The no-op default.  Shared singletons: no allocation on the off path.
# ---------------------------------------------------------------------------
class _NullCtx:
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_CTX = _NullCtx()


class _NullMetric:
    """Counter/gauge/histogram twin that absorbs every write."""

    __slots__ = ()
    value = 0
    count = 0
    total = 0.0

    def inc(self, n=1):
        pass

    def set(self, v):
        pass

    def observe(self, v):
        pass

    def snapshot(self):
        return 0


_NULL_METRIC = _NullMetric()


class NullTelemetry(Telemetry):
    """The near-zero-cost off switch; API-compatible with Telemetry."""

    def __init__(self):  # no registry, no tracer
        pass

    @property
    def enabled(self) -> bool:
        return False

    @property
    def tracing(self) -> bool:
        return False

    tracer = None
    metrics = None

    def counter(self, name: str, **labels):
        return _NULL_METRIC

    def gauge(self, name: str, **labels):
        return _NULL_METRIC

    def histogram(self, name: str, **kw):
        return _NULL_METRIC

    def span(self, name: str, cat: str = "service", tid: int = 0,
             args: Optional[Dict] = None):
        return _NULL_CTX

    def add_span(self, *a, **kw):
        pass

    def instant(self, *a, **kw):
        pass

    def now(self):
        return None

    def snapshot(self) -> Dict[str, object]:
        return {"metrics": {}}

    def export_trace(self, path) -> bool:
        return False

    def reset(self) -> None:
        pass


NULL = NullTelemetry()


def or_null(telemetry: Optional[Telemetry]) -> Telemetry:
    """The one canonicalization every instrumented call site uses."""
    return telemetry if telemetry is not None else NULL


# ---------------------------------------------------------------------------
# The failure flight recorder.
# ---------------------------------------------------------------------------
POSTMORTEM_SCHEMA = "postmortem/v1"


class FlightRecorder:
    """A black box for persistent service failures.

    Rides alongside a :class:`Telemetry`: when the broker confirms a
    poisoned lane, trips a circuit breaker or abandons a livelocked
    bucket, it calls :meth:`dump`, which writes a self-contained
    postmortem JSON to ``<out_dir>/<ts>_<site>.json`` containing

      * the bounded ring of recently *completed* spans (the tracer's
        ``recent`` deque — newest events survive even after the main
        event list saturates),
      * a metrics **delta** since the last mark (construction or the
        previous dump): every counter/gauge that moved, histograms by
        their observation count,
      * the caller-supplied ``state`` dict (the broker passes its stats,
        quarantine digests, degraded buckets and injector totals) and
        the typed error (with its lane digest when it carries one).

    So a chaos failure in CI arrives with its own story instead of a
    bare counter.  Dumps are best-effort by contract: callers wrap them
    so a postmortem write can never take down the service path itself.
    """

    def __init__(self, telemetry, out_dir, max_spans: int = 64,
                 clock=time.time):
        self.telemetry = or_null(telemetry)
        self.out_dir = Path(out_dir)
        self.max_spans = int(max_spans)
        self.clock = clock
        self.dumps: List[Path] = []
        self._baseline = self._numeric_metrics()

    def _numeric_metrics(self) -> Dict[str, float]:
        if not self.telemetry.enabled:
            return {}
        out: Dict[str, float] = {}
        for k, v in self.telemetry.metrics.snapshot().items():
            if isinstance(v, dict):             # histogram -> obs count
                v = v.get("count", 0)
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            out[k] = float(v)
        return out

    def mark(self) -> None:
        """Reset the metrics-delta baseline (done after every dump)."""
        self._baseline = self._numeric_metrics()

    def metrics_delta(self) -> Dict[str, float]:
        now = self._numeric_metrics()
        delta = {k: v - self._baseline.get(k, 0.0)
                 for k, v in now.items() if v != self._baseline.get(k, 0.0)}
        return delta

    def recent_spans(self) -> List[dict]:
        tr = self.telemetry.tracer
        if tr is None:
            return []
        ring = getattr(tr, "recent", None)
        events = list(ring) if ring is not None else list(tr.events)
        return [e for e in events if e.get("ph") == "X"][-self.max_spans:]

    def dump(self, site: str, error: Optional[BaseException] = None,
             state: Optional[Dict] = None) -> Path:
        ts = float(self.clock())
        obj: Dict[str, object] = {
            "schema": POSTMORTEM_SCHEMA,
            "ts": ts,
            "time": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime(ts)),
            "site": str(site),
            "spans": self.recent_spans(),
            "metrics_delta": self.metrics_delta(),
            "state": state or {},
        }
        if error is not None:
            err: Dict[str, object] = {"type": type(error).__name__,
                                      "message": str(error)}
            digest = getattr(error, "digest", None)
            if digest is not None:
                err["digest"] = digest
            if error.__cause__ is not None:
                err["cause"] = (f"{type(error.__cause__).__name__}: "
                                f"{error.__cause__}")
            obj["error"] = err
        self.out_dir.mkdir(parents=True, exist_ok=True)
        stamp = time.strftime("%Y%m%dT%H%M%S", time.gmtime(ts))
        slug = "".join(c if c.isalnum() or c in "._-" else "-"
                       for c in str(site))
        path = self.out_dir / f"{stamp}_{slug}.json"
        n = 1
        while path.exists():                    # same-second collisions
            path = self.out_dir / f"{stamp}_{slug}.{n}.json"
            n += 1
        path.write_text(json.dumps(obj, indent=1, default=float))
        self.dumps.append(path)
        self.mark()
        return path


def validate_postmortem(obj) -> List[str]:
    """Schema check for one postmortem JSON; returns problems."""
    problems: List[str] = []
    if not isinstance(obj, dict):
        return ["postmortem is not an object"]
    if obj.get("schema") != POSTMORTEM_SCHEMA:
        problems.append(f"schema is {obj.get('schema')!r}, "
                        f"expected {POSTMORTEM_SCHEMA!r}")
    for field, kind in (("ts", (int, float)), ("time", str),
                        ("site", str), ("spans", list),
                        ("metrics_delta", dict), ("state", dict)):
        if not isinstance(obj.get(field), kind):
            problems.append(f"field {field!r} missing or not "
                            f"{getattr(kind, '__name__', kind)}")
    if isinstance(obj.get("spans"), list):
        for i, e in enumerate(obj["spans"]):
            if not isinstance(e, dict) or e.get("ph") != "X" \
                    or not isinstance(e.get("name"), str):
                problems.append(f"spans[{i}] is not a complete (X) span")
                break
    err = obj.get("error")
    if err is not None and (not isinstance(err, dict)
                            or not isinstance(err.get("type"), str)):
        problems.append("error present but malformed (needs type/message)")
    return problems
