"""The telemetry facade: one object the whole service stack reports into.

``Telemetry`` bundles a :class:`~repro.obs.metrics.MetricsRegistry`
(always on — counters are a few attribute ops) with an optional
:class:`~repro.obs.tracing.SpanRecorder` (``tracing=True``), and
``snapshot()`` renders everything as one flat JSON-friendly dict — the
blessed replacement for ad-hoc ``BrokerStats.as_dict`` readouts in
benchmark artifacts.

``NULL`` is the near-zero-cost default: a :class:`NullTelemetry` whose
``counter/gauge/histogram`` return shared no-op twins and whose ``span``
is a reusable no-op context manager.  Instrumented code holds exactly
one pattern::

    tel = telemetry if telemetry is not None else NULL
    tel.counter("broker.queries").inc()
    with tel.span("bucket.sweep", args={...}):
        ...

so the off path costs one attribute load and one no-op call per site —
and, because every hook is host-side Python, the compiled engines are
bitwise-identical with telemetry on or off (``tests/test_obs.py``
asserts the blocked engine's outputs exactly).
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Dict, Optional

from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .tracing import SpanRecorder


class Telemetry:
    """Live metrics registry + optional span recorder."""

    def __init__(self, tracing: bool = False, clock=time.monotonic,
                 max_events: int = 200_000):
        self.metrics = MetricsRegistry()
        self.tracer: Optional[SpanRecorder] = (
            SpanRecorder(clock=clock, max_events=max_events)
            if tracing else None)

    # -------------------------------------------------------- metrics --
    @property
    def enabled(self) -> bool:
        return True

    @property
    def tracing(self) -> bool:
        return self.tracer is not None

    def counter(self, name: str, **labels) -> Counter:
        return self.metrics.counter(name, **labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self.metrics.gauge(name, **labels)

    def histogram(self, name: str, **kw) -> Histogram:
        return self.metrics.histogram(name, **kw)

    # -------------------------------------------------------- tracing --
    def span(self, name: str, cat: str = "service", tid: int = 0,
             args: Optional[Dict] = None):
        if self.tracer is None:
            return _NULL_CTX
        return self.tracer.span(name, cat=cat, tid=tid, args=args)

    def add_span(self, name: str, begin: float, end: float,
                 cat: str = "service", tid: int = 0,
                 args: Optional[Dict] = None) -> None:
        if self.tracer is not None:
            self.tracer.add_span(name, begin, end, cat=cat, tid=tid,
                                 args=args)

    def instant(self, name: str, cat: str = "service", tid: int = 0,
                args: Optional[Dict] = None) -> None:
        if self.tracer is not None:
            self.tracer.instant(name, cat=cat, tid=tid, args=args)

    def now(self) -> Optional[float]:
        """Tracer-clock seconds for explicit add_span bounds (None when
        tracing is off — pair with ``add_span``, which no-ops then)."""
        return None if self.tracer is None else self.tracer.now()

    # -------------------------------------------------------- results --
    def snapshot(self) -> Dict[str, object]:
        """Everything the stack reported, one JSON-friendly dict."""
        out = {"metrics": self.metrics.snapshot()}
        if self.tracer is not None:
            out["trace"] = {"events": len(self.tracer.events),
                            "dropped": self.tracer.dropped}
        return out

    def export_trace(self, path) -> bool:
        """Write the Perfetto trace JSON; False when tracing is off."""
        if self.tracer is None:
            return False
        self.tracer.export(path)
        return True

    def reset(self) -> None:
        self.metrics.reset()
        if self.tracer is not None:
            self.tracer.reset()


# ---------------------------------------------------------------------------
# The no-op default.  Shared singletons: no allocation on the off path.
# ---------------------------------------------------------------------------
class _NullCtx:
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_CTX = _NullCtx()


class _NullMetric:
    """Counter/gauge/histogram twin that absorbs every write."""

    __slots__ = ()
    value = 0
    count = 0
    total = 0.0

    def inc(self, n=1):
        pass

    def set(self, v):
        pass

    def observe(self, v):
        pass

    def snapshot(self):
        return 0


_NULL_METRIC = _NullMetric()


class NullTelemetry(Telemetry):
    """The near-zero-cost off switch; API-compatible with Telemetry."""

    def __init__(self):  # no registry, no tracer
        pass

    @property
    def enabled(self) -> bool:
        return False

    @property
    def tracing(self) -> bool:
        return False

    tracer = None
    metrics = None

    def counter(self, name: str, **labels):
        return _NULL_METRIC

    def gauge(self, name: str, **labels):
        return _NULL_METRIC

    def histogram(self, name: str, **kw):
        return _NULL_METRIC

    def span(self, name: str, cat: str = "service", tid: int = 0,
             args: Optional[Dict] = None):
        return _NULL_CTX

    def add_span(self, *a, **kw):
        pass

    def instant(self, *a, **kw):
        pass

    def now(self):
        return None

    def snapshot(self) -> Dict[str, object]:
        return {"metrics": {}}

    def export_trace(self, path) -> bool:
        return False

    def reset(self) -> None:
        pass


NULL = NullTelemetry()


def or_null(telemetry: Optional[Telemetry]) -> Telemetry:
    """The one canonicalization every instrumented call site uses."""
    return telemetry if telemetry is not None else NULL
