"""End-to-end telemetry for the simulation service stack.

Three layers:

  * :mod:`repro.obs.metrics` — a metrics registry (counters, gauges,
    fixed log-bucket histograms, labeled) that the broker, result cache,
    sweep engine, search drivers and benchmark drivers report into;
  * :mod:`repro.obs.tracing` — a structured span recorder exporting
    Chrome/Perfetto ``trace_event`` JSON (open a 64-query burst in a
    trace viewer), plus the validator CI runs on exported traces;
  * :mod:`repro.obs.telemetry` — the :class:`Telemetry` facade with a
    near-zero-cost :data:`NULL` default, so the instrumented stack pays
    one attribute load per hook when observability is off, and the
    compiled engines stay bitwise-identical either way.

``python -m repro.obs.validate trace.json`` checks an exported trace is
well-formed, balanced ``trace_event`` JSON (the CI telemetry smoke).

A fourth layer rides alongside: :mod:`repro.obs.inject`, a deterministic
fault-injection harness (named sites, seeded schedule-reproducible
failure plans) that the service layer's resilience machinery is chaos-
tested against.  Like telemetry, its default is a no-op singleton.
"""
from .inject import (FaultInjector, FaultRule, InjectedFault, NULL_INJECTOR,
                     NullInjector, fail_lane, fail_n, fail_once, fail_rate,
                     or_null_injector)
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .telemetry import NULL, NullTelemetry, Telemetry, or_null
from .tracing import SpanRecorder, validate_trace_events

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "NULL", "NullTelemetry", "Telemetry", "or_null",
    "SpanRecorder", "validate_trace_events",
    "FaultInjector", "FaultRule", "InjectedFault", "NULL_INJECTOR",
    "NullInjector", "fail_lane", "fail_n", "fail_once", "fail_rate",
    "or_null_injector",
]
