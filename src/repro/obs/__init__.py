"""End-to-end telemetry for the simulation service stack.

Three layers:

  * :mod:`repro.obs.metrics` — a metrics registry (counters, gauges,
    fixed log-bucket histograms, labeled) that the broker, result cache,
    sweep engine, search drivers and benchmark drivers report into;
  * :mod:`repro.obs.tracing` — a structured span recorder exporting
    Chrome/Perfetto ``trace_event`` JSON (open a 64-query burst in a
    trace viewer), plus the validator CI runs on exported traces;
  * :mod:`repro.obs.telemetry` — the :class:`Telemetry` facade with a
    near-zero-cost :data:`NULL` default, so the instrumented stack pays
    one attribute load per hook when observability is off, and the
    compiled engines stay bitwise-identical either way.

``python -m repro.obs.validate <artifact.json ...>`` schema-checks
exported artifacts — Perfetto traces, ``history.jsonl`` BenchRecord
logs, postmortem dumps — and is what CI gates on.

Two layers ride alongside:

  * :mod:`repro.obs.inject` — a deterministic fault-injection harness
    (named sites, seeded schedule-reproducible failure plans) that the
    service layer's resilience machinery is chaos-tested against.  Like
    telemetry, its default is a no-op singleton.
  * :mod:`repro.obs.bench` + :mod:`repro.obs.report` — the perf
    observatory: every benchmark driver emits a fingerprinted
    :data:`BenchRecord <repro.obs.bench.RECORD_SCHEMA>` into
    ``artifacts/bench/history.jsonl``, and ``python -m repro.obs.report
    --check`` gates the trajectory against committed per-namespace
    baselines.  :class:`FlightRecorder` dumps a postmortem (recent
    spans + metrics delta + broker state) on persistent service
    failures.
"""
from .bench import (RECORD_SCHEMA, append_record, fingerprint,
                    flatten_metrics, load_history, make_record,
                    namespace_of, next_run_id, validate_record)
from .inject import (FaultInjector, FaultRule, InjectedFault, NULL_INJECTOR,
                     NullInjector, fail_lane, fail_n, fail_once, fail_rate,
                     or_null_injector)
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry, merge,
                      quantile_from_snapshot)
from .telemetry import (FlightRecorder, NULL, NullTelemetry,
                        POSTMORTEM_SCHEMA, Telemetry, or_null,
                        validate_postmortem)
from .tracing import SpanRecorder, validate_trace_events

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "merge", "quantile_from_snapshot",
    "NULL", "NullTelemetry", "Telemetry", "or_null",
    "FlightRecorder", "POSTMORTEM_SCHEMA", "validate_postmortem",
    "SpanRecorder", "validate_trace_events",
    "RECORD_SCHEMA", "append_record", "fingerprint", "flatten_metrics",
    "load_history", "make_record", "namespace_of", "next_run_id",
    "validate_record",
    "FaultInjector", "FaultRule", "InjectedFault", "NULL_INJECTOR",
    "NullInjector", "fail_lane", "fail_n", "fail_once", "fail_rate",
    "or_null_injector",
]
