"""End-to-end telemetry for the simulation service stack.

Three layers:

  * :mod:`repro.obs.metrics` — a metrics registry (counters, gauges,
    fixed log-bucket histograms, labeled) that the broker, result cache,
    sweep engine, search drivers and benchmark drivers report into;
  * :mod:`repro.obs.tracing` — a structured span recorder exporting
    Chrome/Perfetto ``trace_event`` JSON (open a 64-query burst in a
    trace viewer), plus the validator CI runs on exported traces;
  * :mod:`repro.obs.telemetry` — the :class:`Telemetry` facade with a
    near-zero-cost :data:`NULL` default, so the instrumented stack pays
    one attribute load per hook when observability is off, and the
    compiled engines stay bitwise-identical either way.

``python -m repro.obs.validate trace.json`` checks an exported trace is
well-formed, balanced ``trace_event`` JSON (the CI telemetry smoke).
"""
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .telemetry import NULL, NullTelemetry, Telemetry, or_null
from .tracing import SpanRecorder, validate_trace_events

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "NULL", "NullTelemetry", "Telemetry", "or_null",
    "SpanRecorder", "validate_trace_events",
]
