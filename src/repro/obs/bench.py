"""BenchRecord: the unified benchmark-observability record.

Every driver in ``benchmarks/`` used to end with an ad-hoc
``save_artifact(name, payload)`` — 13 disconnected JSON files, no run
history, no idea *which machine or JAX* produced a number.  This module
defines the one record schema the shared harness
(``benchmarks.common.emit_record``) emits for every driver run:

  * identity — driver name, monotonic ``run_id`` (one id per
    ``benchmarks.run`` invocation; all drivers of one invocation share
    it), wall-clock timestamps, the repo's git revision;
  * provenance — a machine/JAX/device **fingerprint** plus the coarse
    ``namespace`` derived from it.  Baselines (``repro.obs.report``) are
    namespaced by it, so accelerator validation lands as "new
    fingerprint ⇒ new baseline namespace", not new CI plumbing;
  * payload — the driver's CSV ``figures`` rows, a flattened
    ``metrics`` dict (every finite scalar in the artifact payload,
    dotted-path keyed: ``populate.8lane.speedup``, ``gates.stranded``,
    ...), and the telemetry registry ``snapshot`` for the run.

Records append to ``artifacts/bench/history.jsonl`` — one JSON object
per line, append-only, committed — so the perf trajectory is a
first-class queryable artifact and ``repro.obs.report`` can gate on it.
"""
from __future__ import annotations

import json
import math
import platform as _platform
import subprocess
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

RECORD_SCHEMA = "bench-record/v1"

# repo root (src/repro/obs/bench.py -> repo); artifacts live beside src/
_REPO = Path(__file__).resolve().parents[3]
DEFAULT_HISTORY = _REPO / "artifacts" / "bench" / "history.jsonl"

# payload subtrees that are not trajectory metrics: the registry snapshot
# is carried whole in its own field, traces/postmortems are file pointers
_SKIP_SUBTREES = ("snapshot", "telemetry", "trace_file", "postmortems")

_FINGERPRINT: Optional[Dict[str, object]] = None
_GIT_REV: Optional[str] = None


def fingerprint() -> Dict[str, object]:
    """Machine/JAX/device identity of this process (cached).

    Deliberately coarse: it must be stable across runs on one box (it
    keys baseline namespaces) yet distinguish a CPU runner from a
    GPU/TPU one.  jax import is lazy so schema validation and report
    rendering never pay for device init.
    """
    global _FINGERPRINT
    if _FINGERPRINT is None:
        fp: Dict[str, object] = {
            "platform": _platform.platform(),
            "machine": _platform.machine(),
            "python": _platform.python_version(),
        }
        try:
            import jax
            devs = jax.devices()
            fp["jax"] = jax.__version__
            fp["device_platform"] = devs[0].platform
            fp["device_kind"] = devs[0].device_kind
            fp["device_count"] = len(devs)
        except Exception:  # noqa: BLE001 — fingerprint must never fail
            fp["jax"] = "unavailable"
            fp["device_platform"] = "unknown"
            fp["device_kind"] = "unknown"
            fp["device_count"] = 0
        try:
            import numpy
            fp["numpy"] = numpy.__version__
        except Exception:  # noqa: BLE001
            fp["numpy"] = "unavailable"
        _FINGERPRINT = fp
    return dict(_FINGERPRINT)


def namespace_of(fp: Dict[str, object]) -> str:
    """Coarse baseline namespace from a fingerprint.

    All CPU backends share one namespace ("cpu" — CI runners and dev
    boxes gate against the same committed baselines); an accelerator
    gets its own (``gpu:nvidia-a100`` style), which the report treats as
    un-baselined until seeded with ``--update-baselines``.
    """
    plat = str(fp.get("device_platform", "unknown")).lower()
    if plat in ("cpu", "unknown"):
        return "cpu"
    kind = str(fp.get("device_kind", "")).strip().lower()
    kind = "-".join(kind.split()) or "generic"
    return f"{plat}:{kind}"


def git_rev() -> str:
    """Short git revision of the repo (cached; "unknown" outside git)."""
    global _GIT_REV
    if _GIT_REV is None:
        try:
            out = subprocess.run(
                ["git", "rev-parse", "--short=12", "HEAD"], cwd=_REPO,
                capture_output=True, text=True, timeout=10)
            _GIT_REV = out.stdout.strip() if out.returncode == 0 else ""
        except Exception:  # noqa: BLE001 — provenance is best-effort
            _GIT_REV = ""
        _GIT_REV = _GIT_REV or "unknown"
    return _GIT_REV


def flatten_metrics(payload, max_entries: int = 400) -> Dict[str, float]:
    """Every finite scalar in a driver's artifact payload, keyed by its
    dotted path — the queryable surface baselines address.

    Booleans become 0/1 (``_meta.compile_check.ok``), short numeric
    lists index per element, strings and long arrays are skipped.
    """
    out: Dict[str, float] = {}

    def walk(prefix: str, node) -> None:
        if len(out) >= max_entries:
            return
        if isinstance(node, bool):
            out[prefix] = float(int(node))
        elif isinstance(node, (int, float)):
            v = float(node)
            if math.isfinite(v):
                out[prefix] = v
        elif isinstance(node, dict):
            for k, v in node.items():
                if prefix == "" and k in _SKIP_SUBTREES:
                    continue
                walk(f"{prefix}.{k}" if prefix else str(k), v)
        elif isinstance(node, (list, tuple)) and 0 < len(node) <= 8 and \
                all(isinstance(x, (int, float)) for x in node):
            for i, x in enumerate(node):
                walk(f"{prefix}.{i}", x)

    if isinstance(payload, dict):
        walk("", payload)
    return out


def make_record(driver: str, payload=None, figures: Sequence[Tuple] = (),
                wall_seconds: float = 0.0, quick: bool = False,
                run_id: int = 0, snapshot=None,
                clock=time.time) -> Dict[str, object]:
    """Assemble one schema-valid BenchRecord for a finished driver."""
    ts = float(clock())
    rec: Dict[str, object] = {
        "schema": RECORD_SCHEMA,
        "run_id": int(run_id),
        "driver": str(driver),
        "quick": bool(quick),
        "ts": ts,
        "time": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime(ts)),
        "wall_seconds": float(wall_seconds),
        "git_rev": git_rev(),
        "fingerprint": fingerprint(),
        "figures": [[str(n), float(s), str(d)] for n, s, d in figures],
        "metrics": flatten_metrics(payload),
    }
    rec["namespace"] = namespace_of(rec["fingerprint"])
    if snapshot:
        rec["snapshot"] = snapshot
    return rec


def validate_record(rec) -> List[str]:
    """Schema check for one BenchRecord; returns problems (empty = ok)."""
    problems: List[str] = []
    if not isinstance(rec, dict):
        return ["record is not an object"]
    if rec.get("schema") != RECORD_SCHEMA:
        problems.append(f"schema is {rec.get('schema')!r}, "
                        f"expected {RECORD_SCHEMA!r}")
    for field, kind in (("run_id", int), ("driver", str), ("quick", bool),
                        ("ts", (int, float)), ("time", str),
                        ("wall_seconds", (int, float)), ("git_rev", str),
                        ("namespace", str), ("fingerprint", dict),
                        ("figures", list), ("metrics", dict)):
        v = rec.get(field)
        if not isinstance(v, kind) or (kind is int and isinstance(v, bool)):
            problems.append(f"field {field!r} missing or not "
                            f"{getattr(kind, '__name__', kind)}")
    if isinstance(rec.get("run_id"), int) and rec["run_id"] < 0:
        problems.append("run_id is negative")
    if isinstance(rec.get("driver"), str) and not rec["driver"]:
        problems.append("driver is empty")
    fp = rec.get("fingerprint")
    if isinstance(fp, dict):
        for field in ("device_platform", "jax", "python"):
            if not isinstance(fp.get(field), str):
                problems.append(f"fingerprint.{field} missing")
    if isinstance(rec.get("metrics"), dict):
        for k, v in rec["metrics"].items():
            if not isinstance(k, str) or isinstance(v, bool) or \
                    not isinstance(v, (int, float)):
                problems.append(f"metrics[{k!r}] is not numeric")
                break
    if isinstance(rec.get("figures"), list):
        for row in rec["figures"]:
            if (not isinstance(row, list) or len(row) != 3
                    or not isinstance(row[0], str)
                    or not isinstance(row[1], (int, float))
                    or not isinstance(row[2], str)):
                problems.append(f"figures row malformed: {row!r}")
                break
    return problems


# ---------------------------------------------------------------------------
# the history store: append-only JSONL
# ---------------------------------------------------------------------------
def append_record(rec: Dict[str, object],
                  path: Path = DEFAULT_HISTORY) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "a") as fh:
        fh.write(json.dumps(rec, sort_keys=True, default=float) + "\n")


def load_history(path: Path = DEFAULT_HISTORY) \
        -> Tuple[List[Dict[str, object]], List[str]]:
    """Parse a history.jsonl; returns (records, problems).  Records that
    parse but fail schema validation are still returned (the report can
    render them) with their problems listed."""
    path = Path(path)
    records: List[Dict[str, object]] = []
    problems: List[str] = []
    if not path.exists():
        return records, [f"{path}: no such file"]
    for i, line in enumerate(path.read_text().splitlines(), start=1):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            problems.append(f"line {i}: unparseable: {e}")
            continue
        for p in validate_record(rec):
            problems.append(f"line {i}: {p}")
        records.append(rec)
    return records, problems


def next_run_id(path: Path = DEFAULT_HISTORY) -> int:
    """The next monotonic run id: max committed id + 1 (0 for a fresh
    history).  One id spans all drivers of one ``benchmarks.run``."""
    records, _ = load_history(path)
    ids = [r["run_id"] for r in records
           if isinstance(r.get("run_id"), int)]
    return (max(ids) + 1) if ids else 0
