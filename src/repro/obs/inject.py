"""Deterministic fault injection for the service stack.

Chaos testing a deterministic simulator demands deterministic chaos:
every failure the resilience layer must survive — a device error mid
``sweep_lanes``, a torn disk-cache write, a whole flush falling over —
is representable here as a *named injection site* plus a seeded,
schedule-reproducible :class:`FaultRule`.  Re-running the same plan
against the same submission order reproduces the same failures, so the
chaos suite can pin exact retry/shed/quarantine counter values instead
of asserting "something probably failed".

Sites instrumented in the stack (context keys each site provides):

  ====================  =====================================================
  ``broker.flush``      once per microbatch flush attempt (``bucket=`` label)
  ``sweep.device``      once per ``sweep_lanes`` device execution, including
                        bisection sub-batches (``lanes=`` list of query
                        digests, ``bucket=``)
  ``cache.disk.read``   once per disk-tier lookup (``key=`` digest string)
  ``cache.disk.write``  once per disk-tier spill (``key=`` digest string)
  ====================  =====================================================

Rule modes:

  * ``fail_once(site)`` / ``fail_n(site, n)`` — the next 1/N firings of
    the site raise; transient by default (the broker's bounded retry
    clears them).
  * ``fail_lane(site, digest)`` — raise whenever the matched digest is
    present in the site context (``lanes`` list or ``key``); persistent
    by default — this is how a chaos plan poisons one lane so the
    broker's batch bisection must isolate it.
  * ``fail_rate(site, rate, seed)`` — seeded Bernoulli per firing; the
    draw sequence depends only on the rule's own counter, so identical
    call schedules reproduce identical failures.

``kind="corrupt"`` asks the *site* to corrupt data instead of raising
(the disk tier writes a truncated blob so the self-healing read path
must detect, quarantine and recompute); sites that cannot corrupt treat
it as ``raise``.

The no-op :data:`NULL_INJECTOR` keeps the production path at one
attribute load per site, mirroring ``obs.telemetry.NULL``.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence, Tuple


class InjectedFault(RuntimeError):
    """Raised by an injection site the active plan told to fail.

    ``transient`` distinguishes a retryable device hiccup from a
    persistent (poison-lane) failure; the broker's retry loop consults
    it before burning backoff budget.
    """

    def __init__(self, site: str, rule: "FaultRule",
                 matched: Optional[str] = None):
        self.site = site
        self.kind = rule.kind
        self.transient = rule.transient
        self.matched = matched
        detail = f" lane={matched}" if matched else ""
        super().__init__(
            f"injected {rule.kind} fault at {site}{detail} "
            f"({'transient' if rule.transient else 'persistent'})")


@dataclasses.dataclass
class FaultRule:
    """One failure clause of a plan.  ``mode``:

    ``once`` / ``times``  fail the next ``times`` firings of the site;
    ``match``             fail every firing whose context contains
                          ``match`` (in ``lanes`` or ``key``);
    ``rate``              seeded Bernoulli(``rate``) per firing.
    """

    site: str
    mode: str = "once"                 # once | times | match | rate
    times: int = 1
    match: Optional[str] = None
    rate: float = 0.0
    kind: str = "raise"                # raise | corrupt
    transient: bool = True
    seed: int = 0

    def __post_init__(self):
        if self.mode not in ("once", "times", "match", "rate"):
            raise ValueError(f"unknown fault mode {self.mode!r}")
        if self.mode == "match" and not self.match:
            raise ValueError("match mode needs a match target")
        if self.kind not in ("raise", "corrupt"):
            raise ValueError(f"unknown fault kind {self.kind!r}")


def fail_once(site: str, **kw) -> FaultRule:
    return FaultRule(site=site, mode="once", times=1, **kw)


def fail_n(site: str, n: int, **kw) -> FaultRule:
    return FaultRule(site=site, mode="times", times=n, **kw)


def fail_lane(site: str, digest: str, transient: bool = False,
              **kw) -> FaultRule:
    return FaultRule(site=site, mode="match", match=digest,
                     transient=transient, **kw)


def fail_rate(site: str, rate: float, seed: int = 0, **kw) -> FaultRule:
    return FaultRule(site=site, mode="rate", rate=rate, seed=seed, **kw)


class FaultInjector:
    """A fault plan armed over the named sites.

    ``fire(site, **context)`` walks the plan's rules for ``site`` in
    order and raises :class:`InjectedFault` on the first one that
    triggers.  Every firing — triggered or not — is counted, and every
    triggered fault is appended to ``log`` so tests can assert the exact
    schedule that was injected.
    """

    def __init__(self, rules: Sequence[FaultRule] = ()):
        self.rules: List[FaultRule] = list(rules)
        self._remaining: Dict[int, int] = {
            i: r.times for i, r in enumerate(self.rules)
            if r.mode in ("once", "times")}
        self._rngs: Dict[int, random.Random] = {
            i: random.Random(r.seed) for i, r in enumerate(self.rules)
            if r.mode == "rate"}
        self.fired: Dict[str, int] = {}      # site -> firings (all)
        self.injected: Dict[str, int] = {}   # site -> faults raised
        self.log: List[Tuple[str, str, Optional[str]]] = []

    def add(self, rule: FaultRule) -> None:
        i = len(self.rules)
        self.rules.append(rule)
        if rule.mode in ("once", "times"):
            self._remaining[i] = rule.times
        if rule.mode == "rate":
            self._rngs[i] = random.Random(rule.seed)

    @staticmethod
    def _matched(rule: FaultRule, context) -> Optional[str]:
        lanes = context.get("lanes") or ()
        for lane in lanes:
            if rule.match in str(lane):
                return str(lane)
        key = context.get("key")
        if key is not None and rule.match in str(key):
            return str(key)
        return None

    def fire(self, site: str, **context) -> None:
        self.fired[site] = self.fired.get(site, 0) + 1
        for i, rule in enumerate(self.rules):
            if rule.site != site:
                continue
            matched = None
            if rule.mode in ("once", "times"):
                if self._remaining.get(i, 0) <= 0:
                    continue
                self._remaining[i] -= 1
            elif rule.mode == "match":
                matched = self._matched(rule, context)
                if matched is None:
                    continue
            else:  # rate
                if self._rngs[i].random() >= rule.rate:
                    continue
            self.injected[site] = self.injected.get(site, 0) + 1
            self.log.append((site, rule.kind, matched))
            raise InjectedFault(site, rule, matched)

    def stats(self) -> Dict[str, object]:
        return {"fired": dict(self.fired), "injected": dict(self.injected),
                "total_injected": sum(self.injected.values())}


class NullInjector(FaultInjector):
    """The production default: every site is a no-op."""

    def __init__(self):
        super().__init__(())

    def add(self, rule: FaultRule) -> None:
        raise RuntimeError("NULL_INJECTOR is shared; build a FaultInjector")

    def fire(self, site: str, **context) -> None:
        pass


NULL_INJECTOR = NullInjector()


def or_null_injector(injector: Optional[FaultInjector]) -> FaultInjector:
    return injector if injector is not None else NULL_INJECTOR
