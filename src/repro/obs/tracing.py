"""Structured trace recorder exporting Chrome/Perfetto ``trace_event``
JSON.

Spans are recorded as *complete* events (``ph: "X"`` — one event
carrying both timestamp and duration), which are balanced by
construction and load directly in Perfetto / ``chrome://tracing``.
Timestamps are microseconds relative to the recorder's construction, on
the recorder's own monotonic clock — the broker's (possibly fake)
scheduling clock never leaks into exported traces, and a span emitted
late with an earlier start (e.g. a queue-wait span recorded at flush
time) still gets a non-negative timestamp.

The recorder is bounded (``max_events``): a long benchmark run cannot
grow an unbounded event list; overflow drops new events and counts the
drops, which ``to_trace_json()`` reports in metadata so a truncated
trace is never mistaken for a complete one.
"""
from __future__ import annotations

import json
import time
from collections import deque
from contextlib import contextmanager
from typing import Dict, List, Optional

# Microseconds per second: trace_event timestamps are in us.
_US = 1e6


class SpanRecorder:
    """Append-only span/instant event log with trace_event export."""

    def __init__(self, clock=time.monotonic, max_events: int = 200_000,
                 process_name: str = "repro-sim-service",
                 recent_events: int = 256):
        self.clock = clock
        self.max_events = int(max_events)
        self.process_name = process_name
        self.events: List[dict] = []
        # black-box ring: always holds the *newest* events, even after
        # the main list saturates and starts dropping — the flight
        # recorder's postmortems read this, and a crash late in a long
        # run must still see its own final spans
        self.recent: "deque[dict]" = deque(maxlen=int(recent_events))
        self.dropped = 0
        self._t0 = self.clock()

    # ------------------------------------------------------------------
    # recording
    # ------------------------------------------------------------------
    def now(self) -> float:
        """Recorder-clock seconds; use for explicit begin/end spans."""
        return self.clock()

    def _ts(self, t: float) -> float:
        return max(t - self._t0, 0.0) * _US

    def _emit(self, ev: dict) -> None:
        self.recent.append(ev)
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    def add_span(self, name: str, begin: float, end: float,
                 cat: str = "service", tid: int = 0,
                 args: Optional[Dict] = None) -> None:
        """One complete span from recorder-clock ``begin`` to ``end``."""
        ev = {"name": name, "cat": cat, "ph": "X",
              "ts": self._ts(begin), "dur": max(end - begin, 0.0) * _US,
              "pid": 0, "tid": int(tid)}
        if args:
            ev["args"] = args
        self._emit(ev)

    @contextmanager
    def span(self, name: str, cat: str = "service", tid: int = 0,
             args: Optional[Dict] = None):
        t0 = self.clock()
        try:
            yield
        finally:
            self.add_span(name, t0, self.clock(), cat=cat, tid=tid,
                          args=args)

    def instant(self, name: str, cat: str = "service", tid: int = 0,
                args: Optional[Dict] = None) -> None:
        ev = {"name": name, "cat": cat, "ph": "i", "s": "t",
              "ts": self._ts(self.clock()), "pid": 0, "tid": int(tid)}
        if args:
            ev["args"] = args
        self._emit(ev)

    # ------------------------------------------------------------------
    # export
    # ------------------------------------------------------------------
    def span_names(self) -> List[str]:
        return [e["name"] for e in self.events if e["ph"] == "X"]

    def to_trace_json(self) -> dict:
        """The Chrome/Perfetto ``trace_event`` JSON object."""
        meta = [{"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
                 "args": {"name": self.process_name}}]
        obj = {"traceEvents": meta + self.events,
               "displayTimeUnit": "ms"}
        if self.dropped:
            obj["otherData"] = {"dropped_events": self.dropped}
        return obj

    def export(self, path) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_trace_json(), fh, indent=1, default=float)

    def reset(self) -> None:
        self.events.clear()
        self.recent.clear()
        self.dropped = 0
        self._t0 = self.clock()


def validate_trace_events(obj: dict) -> List[str]:
    """Validate a ``trace_event`` JSON object; return a list of problems
    (empty = well-formed, balanced, Perfetto-loadable).

    Checks: the ``traceEvents`` container, per-event required fields,
    non-negative timestamps/durations on complete (``X``) spans, and —
    for any begin/end (``B``/``E``) pairs a foreign producer might emit —
    LIFO balance and non-decreasing timestamps per (pid, tid).

    Complete spans on one (pid, tid) track must *nest*: exact
    containment is fine (Perfetto stacks it), but partial overlap —
    span B starting inside span A and ending after it — renders as
    garbage and always indicates a producer attributing one wall-clock
    interval to two concurrent activities on the same track.
    """
    problems: List[str] = []
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    if not any(e.get("ph") == "X" for e in events):
        problems.append("no complete (ph='X') spans in trace")
    open_stacks: Dict[tuple, list] = {}
    # per-track lists for the cross-event checks below
    x_spans: Dict[tuple, list] = {}
    last_be_ts: Dict[tuple, float] = {}
    for i, e in enumerate(events):
        ph = e.get("ph")
        if ph not in ("X", "B", "E", "i", "I", "M", "C"):
            problems.append(f"event {i}: unknown ph {ph!r}")
            continue
        if ph != "E" and not isinstance(e.get("name"), str):
            problems.append(f"event {i}: missing name")
        ts_ok = False
        if ph in ("X", "B", "E", "i", "I", "C"):
            ts = e.get("ts")
            ts_ok = isinstance(ts, (int, float)) and ts >= 0
            if not ts_ok:
                problems.append(f"event {i}: bad ts {ts!r}")
        key = (e.get("pid"), e.get("tid"))
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i}: bad dur {dur!r}")
            elif ts_ok:
                x_spans.setdefault(key, []).append(
                    (float(ts), float(ts) + float(dur), i, e.get("name")))
        if ph == "B":
            open_stacks.setdefault(key, []).append(e.get("name"))
        elif ph == "E":
            stack = open_stacks.get(key)
            if not stack:
                problems.append(f"event {i}: E without matching B on {key}")
            else:
                stack.pop()
        if ph in ("B", "E") and ts_ok:
            # B/E events carry implicit ordering: a track that goes
            # backwards in time is unparseable by duration-event viewers
            prev = last_be_ts.get(key)
            if prev is not None and ts < prev:
                problems.append(
                    f"event {i}: non-monotonic ts on track {key}: "
                    f"{ts} after {prev}")
            last_be_ts[key] = float(ts)
    for key, stack in open_stacks.items():
        if stack:
            problems.append(f"unclosed B spans on {key}: {stack}")
    # X-span nesting per track: sweep spans in (start, -end) order with a
    # stack of enclosing ends; a span poking out past its encloser is a
    # partial overlap.  EPS absorbs float-us rounding at shared edges.
    eps = 1e-6
    for key, spans in x_spans.items():
        stack: List[float] = []
        for ts, end, i, name in sorted(spans,
                                       key=lambda s: (s[0], -s[1])):
            while stack and stack[-1] <= ts + eps:
                stack.pop()
            if stack and end > stack[-1] + eps:
                problems.append(
                    f"event {i}: span {name!r} [{ts:g}, {end:g}] "
                    f"partially overlaps an earlier span on track {key}")
                continue
            stack.append(end)
    return problems
