"""The metrics registry: counters, gauges, log-bucket histograms.

The service stack attributes *simulated* cycles obsessively (per-level
walk latency, migration overhead — the paper's whole argument) but until
this module it could not attribute its *own* time: ``BrokerStats`` was
six bare counters and a slow flush or a compile storm was invisible
until a CI perf bar tripped.  This registry is the substrate every
service layer reports into — the broker (queue-wait and flush-latency
histograms, per-bucket compile counts), the result cache
(hit/miss/evict/spill), the sweep engine (fast vs event windows, device
seconds) and the benchmark drivers (which embed ``snapshot()`` in their
committed artifacts so CI perf numbers carry their own explanation).

Design constraints, in order:

  * **host-side only** — nothing here ever touches a traced value; the
    compiled engines are bitwise-identical with telemetry on or off
    (asserted in ``tests/test_obs.py``);
  * **near-zero cost when off** — the no-op twins in ``telemetry.py``
    reduce every call site to one attribute load and one no-op call;
  * **stable snapshots** — ``snapshot()`` emits a flat, JSON-friendly
    dict (``name`` or ``name{label=value,...}`` keys, sorted labels) so
    artifacts diff cleanly across runs.

Histograms use fixed log-scale buckets (powers of ``base`` from
``lo`` up to ``hi``): latencies span orders of magnitude, and fixed
boundaries mean two snapshots are mergeable bucket-by-bucket — the
property the ROADMAP's fleet-wide metrics item needs.
"""
from __future__ import annotations

import json
import math
from typing import Dict, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _fmt(name: str, labels: LabelKey) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonically increasing value (events, lanes, compiles)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n

    def snapshot(self):
        return self.value


class Gauge:
    """Last-written value (queue depth, pages-per-tier)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def set(self, v) -> None:
        self.value = v

    def inc(self, n=1) -> None:
        self.value += n

    def snapshot(self):
        return self.value


class Histogram:
    """Fixed log-scale buckets: bucket i counts observations in
    ``(lo * base**(i-1), lo * base**i]``, with underflow in bucket 0 and
    overflow in the last bucket.  Fixed boundaries (never rescaled on
    observe) keep histograms mergeable across snapshots and processes.
    """

    __slots__ = ("lo", "base", "n_buckets", "buckets", "count", "total",
                 "min", "max")

    def __init__(self, lo: float = 1e-6, base: float = 2.0,
                 n_buckets: int = 40):
        if lo <= 0 or base <= 1 or n_buckets < 2:
            raise ValueError("need lo > 0, base > 1, n_buckets >= 2")
        self.lo = float(lo)
        self.base = float(base)
        self.n_buckets = int(n_buckets)
        self.buckets = [0] * self.n_buckets
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def bucket_of(self, v: float) -> int:
        if v <= self.lo:
            return 0
        i = int(math.ceil(math.log(v / self.lo) / math.log(self.base)))
        return min(max(i, 0), self.n_buckets - 1)

    def bucket_le(self, i: int) -> float:
        """Inclusive upper bound of bucket ``i`` (inf for the overflow)."""
        if i >= self.n_buckets - 1:
            return math.inf
        return self.lo * self.base ** i

    def observe(self, v) -> None:
        v = float(v)
        self.buckets[self.bucket_of(v)] += 1
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def snapshot(self):
        out = {"count": self.count, "sum": self.total}
        if self.count:
            out["min"] = self.min
            out["max"] = self.max
            out["mean"] = self.total / self.count
            # bucket geometry rides along so quantile_from_snapshot /
            # merge can reconstruct edges from the snapshot alone (only
            # when non-empty: the empty shape is pinned by tests and
            # carries no information)
            out["lo"] = self.lo
            out["base"] = self.base
        # sparse: only non-empty buckets, keyed by their upper bound
        out["buckets"] = {
            ("inf" if math.isinf(self.bucket_le(i)) else
             f"{self.bucket_le(i):.9g}"): n
            for i, n in enumerate(self.buckets) if n}
        return out

    def quantile(self, q: float) -> Optional[float]:
        """Estimated q-quantile (0 <= q <= 1) by log-bucket
        interpolation; exact to within one bucket width.  None when
        empty."""
        return quantile_from_snapshot(self.snapshot(), q)


def merge(snapshot_a: Dict[str, object],
          snapshot_b: Dict[str, object]) -> Dict[str, object]:
    """Merge two Histogram snapshots bucket-by-bucket.

    Fixed boundaries make this exact: the merged snapshot is identical
    (up to float summation) to observing both streams into one
    histogram — the property the ROADMAP's fleet-wide metrics item
    needs, and what the report uses to aggregate per-driver latency
    histograms across history records.
    """
    if not snapshot_a.get("count"):
        return json.loads(json.dumps(snapshot_b))
    if not snapshot_b.get("count"):
        return json.loads(json.dumps(snapshot_a))
    for field in ("lo", "base"):
        av, bv = snapshot_a.get(field), snapshot_b.get(field)
        if av is not None and bv is not None and av != bv:
            raise ValueError(
                f"cannot merge histograms with different {field}: "
                f"{av} vs {bv}")
    out = {
        "count": snapshot_a["count"] + snapshot_b["count"],
        "sum": snapshot_a["sum"] + snapshot_b["sum"],
        "min": min(snapshot_a["min"], snapshot_b["min"]),
        "max": max(snapshot_a["max"], snapshot_b["max"]),
    }
    out["mean"] = out["sum"] / out["count"]
    for field in ("lo", "base"):
        v = snapshot_a.get(field, snapshot_b.get(field))
        if v is not None:
            out[field] = v
    buckets: Dict[str, int] = dict(snapshot_a.get("buckets", {}))
    for ub, n in snapshot_b.get("buckets", {}).items():
        buckets[ub] = buckets.get(ub, 0) + n
    out["buckets"] = buckets
    return out


def quantile_from_snapshot(snapshot: Dict[str, object],
                           q: float) -> Optional[float]:
    """q-quantile (0 <= q <= 1) of a Histogram snapshot.

    Walks the sparse buckets in boundary order to the target rank, then
    interpolates geometrically within the bucket (log-scale buckets ⇒
    log-space interpolation), clamping to the observed [min, max].  The
    estimate is exact to within one bucket width of the true quantile.
    """
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile must be in [0, 1], got {q}")
    count = snapshot.get("count", 0)
    if not count:
        return None
    lo = float(snapshot.get("lo", 1e-6))
    base = float(snapshot.get("base", 2.0))
    obs_min = float(snapshot.get("min", lo))
    obs_max = float(snapshot.get("max", obs_min))
    buckets = sorted(
        ((math.inf if ub == "inf" else float(ub), int(n))
         for ub, n in snapshot.get("buckets", {}).items()),
        key=lambda t: t[0])
    rank = min(max(int(math.ceil(q * count)), 1), count)
    cum = 0
    for ub, n in buckets:
        if cum + n < rank:
            cum += n
            continue
        if math.isinf(ub):          # overflow bucket: no finite edges
            return obs_max
        hi_edge = ub
        lo_edge = ub / base
        frac = (rank - cum) / n
        val = lo_edge * (hi_edge / lo_edge) ** frac
        return min(max(val, obs_min), obs_max)
    return obs_max


class MetricsRegistry:
    """Named, labeled metric store.

    ``counter/gauge/histogram`` get-or-create: the same (name, labels)
    pair always returns the same metric object, so call sites hold no
    references and the registry stays the single source of truth.  A
    name is one kind only — re-registering it as another kind raises.
    """

    def __init__(self):
        # name -> (kind, {label_key -> metric})
        self._metrics: Dict[str, Tuple[type, Dict[LabelKey, object]]] = {}

    def _get(self, kind, name: str, labels: Dict[str, object], **kw):
        ent = self._metrics.get(name)
        if ent is None:
            ent = (kind, {})
            self._metrics[name] = ent
        elif ent[0] is not kind:
            raise ValueError(
                f"metric {name!r} already registered as "
                f"{ent[0].__name__}, not {kind.__name__}")
        key = _label_key(labels)
        m = ent[1].get(key)
        if m is None:
            m = kind(**kw)
            ent[1][key] = m
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, lo: float = 1e-6, base: float = 2.0,
                  n_buckets: int = 40, **labels) -> Histogram:
        return self._get(Histogram, name, labels, lo=lo, base=base,
                         n_buckets=n_buckets)

    def snapshot(self) -> Dict[str, object]:
        """Flat JSON-friendly dict, deterministically ordered."""
        out = {}
        for name in sorted(self._metrics):
            _, by_label = self._metrics[name]
            for key in sorted(by_label):
                out[_fmt(name, key)] = by_label[key].snapshot()
        return out

    def value(self, name: str, **labels):
        """Current value of one metric (None when never written)."""
        ent = self._metrics.get(name)
        if ent is None:
            return None
        m = ent[1].get(_label_key(labels))
        return None if m is None else m.snapshot()

    def reset(self) -> None:
        self._metrics.clear()
