"""The metrics registry: counters, gauges, log-bucket histograms.

The service stack attributes *simulated* cycles obsessively (per-level
walk latency, migration overhead — the paper's whole argument) but until
this module it could not attribute its *own* time: ``BrokerStats`` was
six bare counters and a slow flush or a compile storm was invisible
until a CI perf bar tripped.  This registry is the substrate every
service layer reports into — the broker (queue-wait and flush-latency
histograms, per-bucket compile counts), the result cache
(hit/miss/evict/spill), the sweep engine (fast vs event windows, device
seconds) and the benchmark drivers (which embed ``snapshot()`` in their
committed artifacts so CI perf numbers carry their own explanation).

Design constraints, in order:

  * **host-side only** — nothing here ever touches a traced value; the
    compiled engines are bitwise-identical with telemetry on or off
    (asserted in ``tests/test_obs.py``);
  * **near-zero cost when off** — the no-op twins in ``telemetry.py``
    reduce every call site to one attribute load and one no-op call;
  * **stable snapshots** — ``snapshot()`` emits a flat, JSON-friendly
    dict (``name`` or ``name{label=value,...}`` keys, sorted labels) so
    artifacts diff cleanly across runs.

Histograms use fixed log-scale buckets (powers of ``base`` from
``lo`` up to ``hi``): latencies span orders of magnitude, and fixed
boundaries mean two snapshots are mergeable bucket-by-bucket — the
property the ROADMAP's fleet-wide metrics item needs.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _fmt(name: str, labels: LabelKey) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonically increasing value (events, lanes, compiles)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n

    def snapshot(self):
        return self.value


class Gauge:
    """Last-written value (queue depth, pages-per-tier)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def set(self, v) -> None:
        self.value = v

    def inc(self, n=1) -> None:
        self.value += n

    def snapshot(self):
        return self.value


class Histogram:
    """Fixed log-scale buckets: bucket i counts observations in
    ``(lo * base**(i-1), lo * base**i]``, with underflow in bucket 0 and
    overflow in the last bucket.  Fixed boundaries (never rescaled on
    observe) keep histograms mergeable across snapshots and processes.
    """

    __slots__ = ("lo", "base", "n_buckets", "buckets", "count", "total",
                 "min", "max")

    def __init__(self, lo: float = 1e-6, base: float = 2.0,
                 n_buckets: int = 40):
        if lo <= 0 or base <= 1 or n_buckets < 2:
            raise ValueError("need lo > 0, base > 1, n_buckets >= 2")
        self.lo = float(lo)
        self.base = float(base)
        self.n_buckets = int(n_buckets)
        self.buckets = [0] * self.n_buckets
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def bucket_of(self, v: float) -> int:
        if v <= self.lo:
            return 0
        i = int(math.ceil(math.log(v / self.lo) / math.log(self.base)))
        return min(max(i, 0), self.n_buckets - 1)

    def bucket_le(self, i: int) -> float:
        """Inclusive upper bound of bucket ``i`` (inf for the overflow)."""
        if i >= self.n_buckets - 1:
            return math.inf
        return self.lo * self.base ** i

    def observe(self, v) -> None:
        v = float(v)
        self.buckets[self.bucket_of(v)] += 1
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def snapshot(self):
        out = {"count": self.count, "sum": self.total}
        if self.count:
            out["min"] = self.min
            out["max"] = self.max
            out["mean"] = self.total / self.count
        # sparse: only non-empty buckets, keyed by their upper bound
        out["buckets"] = {
            ("inf" if math.isinf(self.bucket_le(i)) else
             f"{self.bucket_le(i):.9g}"): n
            for i, n in enumerate(self.buckets) if n}
        return out


class MetricsRegistry:
    """Named, labeled metric store.

    ``counter/gauge/histogram`` get-or-create: the same (name, labels)
    pair always returns the same metric object, so call sites hold no
    references and the registry stays the single source of truth.  A
    name is one kind only — re-registering it as another kind raises.
    """

    def __init__(self):
        # name -> (kind, {label_key -> metric})
        self._metrics: Dict[str, Tuple[type, Dict[LabelKey, object]]] = {}

    def _get(self, kind, name: str, labels: Dict[str, object], **kw):
        ent = self._metrics.get(name)
        if ent is None:
            ent = (kind, {})
            self._metrics[name] = ent
        elif ent[0] is not kind:
            raise ValueError(
                f"metric {name!r} already registered as "
                f"{ent[0].__name__}, not {kind.__name__}")
        key = _label_key(labels)
        m = ent[1].get(key)
        if m is None:
            m = kind(**kw)
            ent[1][key] = m
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, lo: float = 1e-6, base: float = 2.0,
                  n_buckets: int = 40, **labels) -> Histogram:
        return self._get(Histogram, name, labels, lo=lo, base=base,
                         n_buckets=n_buckets)

    def snapshot(self) -> Dict[str, object]:
        """Flat JSON-friendly dict, deterministically ordered."""
        out = {}
        for name in sorted(self._metrics):
            _, by_label = self._metrics[name]
            for key in sorted(by_label):
                out[_fmt(name, key)] = by_label[key].snapshot()
        return out

    def value(self, name: str, **labels):
        """Current value of one metric (None when never written)."""
        ent = self._metrics.get(name)
        if ent is None:
            return None
        m = ent[1].get(_label_key(labels))
        return None if m is None else m.snapshot()

    def reset(self) -> None:
        self._metrics.clear()
