"""The perf observatory: statistical regression gate + trajectory report.

``python -m repro.obs.report --check`` replaces the four hand-rolled CI
bar checks (fault_batch 1.3x, steady_state 2x, chaos zero-stranded,
scenario compile-count) with declarative **baseline entries** evaluated
over the committed run history (``artifacts/bench/history.jsonl``,
written by every driver via ``benchmarks.common.emit_record``).

Baselines (``artifacts/bench/baselines.json``) are grouped by
**namespace** — the coarse machine fingerprint slug from
``repro.obs.bench`` — so a GPU/TPU runner gates against its own numbers
("new fingerprint ⇒ new baseline namespace").  Three entry kinds:

  ``min`` / ``max``   hard structural bars on the newest sample
                      (``gates.stranded <= 0``, ``speedup >= 1.3``) —
                      exactly the old CI semantics, declaratively;
  ``best``            committed best-known value with a relative
                      tolerance band, judged on the *best of the last
                      N* samples (``min_of_n``) — noise-damped
                      trajectory tracking that catches slow erosion
                      (a 6x win decaying to 3x fails here long before
                      it would trip a 2x floor).

Without ``--check`` the module renders the human-readable trajectory
report: per-driver deltas vs. the previous run and vs. baseline,
sparkline history tables, p50/p99 flush latency (via
``Histogram.quantile`` over snapshot histograms) and the top
compile-count / pad-ratio movers between the last two runs.

``--update-baselines`` rewrites each ``best`` entry's value to the
current candidate — the intentional-ratchet workflow documented in the
README (commit the diff alongside the change that earned it).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from .bench import DEFAULT_HISTORY, load_history
from .metrics import merge as merge_hist
from .metrics import quantile_from_snapshot

BASELINES_SCHEMA = "bench-baselines/v1"
DEFAULT_BASELINES = DEFAULT_HISTORY.parent / "baselines.json"

_SPARK = "▁▂▃▄▅▆▇█"


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------
def load_baselines(path: Path = DEFAULT_BASELINES) -> Dict[str, object]:
    obj = json.loads(Path(path).read_text())
    if obj.get("schema") != BASELINES_SCHEMA:
        raise ValueError(f"{path}: schema is {obj.get('schema')!r}, "
                         f"expected {BASELINES_SCHEMA!r}")
    if not isinstance(obj.get("namespaces"), dict):
        raise ValueError(f"{path}: missing namespaces mapping")
    return obj


def _series(records: Sequence[dict], namespace: str, driver: str,
            metric: str) -> List[float]:
    """Metric values oldest -> newest for one (namespace, driver)."""
    rows = [r for r in records
            if r.get("namespace") == namespace
            and r.get("driver") == driver
            and isinstance(r.get("metrics"), dict)
            and metric in r["metrics"]]
    rows.sort(key=lambda r: (r.get("run_id", 0), r.get("ts", 0.0)))
    return [float(r["metrics"][metric]) for r in rows]


def check(records: Sequence[dict],
          baselines: Dict[str, object]) -> List[Dict[str, object]]:
    """Evaluate every baseline entry; returns one check dict per entry
    (``ok`` False on a regression *or* on missing history — a gate that
    silently skips a vanished metric is no gate)."""
    checks: List[Dict[str, object]] = []
    for ns, group in sorted(baselines.get("namespaces", {}).items()):
        for ent in group.get("entries", []):
            driver = ent["driver"]
            metric = ent["metric"]
            kind = ent.get("kind", "best")
            value = float(ent["value"])
            direction = ent.get(
                "direction", "lower" if kind == "max" else "higher")
            n = int(ent.get("min_of_n", 3 if kind == "best" else 1))
            series = _series(records, ns, driver, metric)
            window = series[-n:]
            chk: Dict[str, object] = {
                "namespace": ns, "driver": driver, "metric": metric,
                "kind": kind, "baseline": value, "direction": direction,
                "samples": len(window), "history_len": len(series),
            }
            if not window:
                chk.update(ok=False, candidate=None, threshold=value,
                           detail="no history sample for this metric")
                checks.append(chk)
                continue
            candidate = max(window) if direction == "higher" \
                else min(window)
            if kind == "min":
                threshold, ok = value, candidate >= value
            elif kind == "max":
                threshold, ok = value, candidate <= value
            else:                       # best-known with tolerance band
                tol = float(ent.get("rel_tol", 0.25))
                if direction == "higher":
                    threshold = value * (1.0 - tol)
                    ok = candidate >= threshold
                else:
                    threshold = value * (1.0 + tol)
                    ok = candidate <= threshold
            cmp = ">=" if (kind == "min" or (kind == "best"
                                             and direction == "higher")) \
                else "<="
            chk.update(
                ok=bool(ok), candidate=candidate, threshold=threshold,
                detail=(f"{'best' if n > 1 else 'latest'}-of-{len(window)} "
                        f"{candidate:g} {cmp} {threshold:g}"
                        + ("" if ok else " VIOLATED")))
            checks.append(chk)
    return checks


def update_baselines(records: Sequence[dict], baselines: Dict[str, object]) \
        -> List[str]:
    """Rewrite each ``best`` entry's value to the current candidate
    (in place); returns human-readable change lines."""
    changed: List[str] = []
    for ns, group in baselines.get("namespaces", {}).items():
        for ent in group.get("entries", []):
            if ent.get("kind", "best") != "best":
                continue
            direction = ent.get("direction", "higher")
            n = int(ent.get("min_of_n", 3))
            window = _series(records, ns, ent["driver"],
                             ent["metric"])[-n:]
            if not window:
                continue
            candidate = max(window) if direction == "higher" \
                else min(window)
            if candidate != ent["value"]:
                changed.append(
                    f"{ns}/{ent['driver']}:{ent['metric']} "
                    f"{ent['value']:g} -> {candidate:g}")
                ent["value"] = candidate
    return changed


# ---------------------------------------------------------------------------
# trajectory report
# ---------------------------------------------------------------------------
def sparkline(vals: Sequence[float]) -> str:
    vals = [v for v in vals if v is not None]
    if not vals:
        return ""
    lo, hi = min(vals), max(vals)
    if hi - lo <= 1e-12 * max(abs(hi), 1.0):
        return _SPARK[3] * len(vals)
    return "".join(_SPARK[int((v - lo) / (hi - lo) * (len(_SPARK) - 1))]
                   for v in vals)


def _pct(new: Optional[float], old: Optional[float]) -> str:
    if new is None or old is None or abs(old) < 1e-12:
        return "—"
    return f"{100.0 * (new - old) / abs(old):+.1f}%"


def _runs(records: Sequence[dict]) -> List[int]:
    return sorted({r.get("run_id", 0) for r in records})


def _latest_per_driver(records: Sequence[dict], run_id: int) -> Dict[str, dict]:
    out: Dict[str, dict] = {}
    for r in records:
        if r.get("run_id") == run_id:
            out[r.get("driver", "?")] = r
    return out


def render_report(records: Sequence[dict], baselines: Dict[str, object],
                  checks: Sequence[dict], last_n: int = 12) -> str:
    lines: List[str] = ["# Perf observatory report", ""]
    if not records:
        lines.append("history is empty — run `python -m benchmarks.run` "
                     "to emit BenchRecords.")
        return "\n".join(lines) + "\n"

    runs = _runs(records)
    latest_run = runs[-1]
    latest = [r for r in records if r.get("run_id") == latest_run]
    fp = latest[-1].get("fingerprint", {})
    lines += [
        f"{len(records)} records, {len(runs)} runs, "
        f"{len({r.get('driver') for r in records})} drivers "
        f"in history.",
        f"Latest run {latest_run} at {latest[-1].get('time', '?')} — "
        f"git {latest[-1].get('git_rev', '?')}, "
        f"namespace `{latest[-1].get('namespace', '?')}` "
        f"(jax {fp.get('jax', '?')}, "
        f"{fp.get('device_count', '?')}x {fp.get('device_kind', '?')}).",
        "",
    ]
    known_ns = set(baselines.get("namespaces", {}))
    for ns in sorted({r.get("namespace", "?") for r in records}):
        if ns not in known_ns:
            lines += [f"> namespace `{ns}` has history but no baselines "
                      f"— seed it with `--update-baselines` after adding "
                      f"entries.", ""]

    # ------------------------------------------------------------- gate --
    lines += ["## Regression gate", "",
              "| status | namespace | driver : metric | kind | candidate "
              "| threshold | baseline | history |",
              "|---|---|---|---|---|---|---|---|"]
    for c in checks:
        series = _series(records, c["namespace"], c["driver"],
                         c["metric"])[-last_n:]
        cand = "—" if c["candidate"] is None else f"{c['candidate']:g}"
        lines.append(
            f"| {'ok' if c['ok'] else '**FAIL**'} | {c['namespace']} "
            f"| {c['driver']} : {c['metric']} | {c['kind']} "
            f"| {cand} | {c['threshold']:g} | {c['baseline']:g} "
            f"| {sparkline(series)} |")
    lines.append("")

    # ------------------------------------------------- per-driver deltas --
    prev_run = runs[-2] if len(runs) > 1 else None
    by_latest = _latest_per_driver(records, latest_run)
    by_prev = _latest_per_driver(records, prev_run) if prev_run is not None \
        else {}
    tracked: Dict[str, List[str]] = {}
    for c in checks:
        tracked.setdefault(c["driver"], [])
        if c["metric"] not in tracked[c["driver"]]:
            tracked[c["driver"]].append(c["metric"])
    lines += [f"## Driver trajectory (run {latest_run}"
              + (f" vs run {prev_run}" if prev_run is not None else "")
              + ")", "",
              "| driver | metric | latest | Δ prev | Δ baseline "
              "| history |", "|---|---|---|---|---|---|"]
    base_val = {(c["driver"], c["metric"]): c["baseline"] for c in checks
                if c["kind"] == "best"}
    for driver in sorted(by_latest):
        rec = by_latest[driver]
        prev = by_prev.get(driver)
        metrics = tracked.get(driver) or []
        rows = [(m, rec.get("metrics", {}).get(m)) for m in metrics]
        rows.append(("wall_seconds", rec.get("wall_seconds")))
        for metric, val in rows:
            if val is None:
                continue
            prev_val = None
            if prev is not None:
                prev_val = (prev.get("metrics", {}).get(metric)
                            if metric != "wall_seconds"
                            else prev.get("wall_seconds"))
            series = _series(records, rec.get("namespace", "?"), driver,
                             metric)[-last_n:] \
                if metric != "wall_seconds" else \
                [r.get("wall_seconds") for r in records
                 if r.get("driver") == driver][-last_n:]
            lines.append(
                f"| {driver} | {metric} | {val:g} "
                f"| {_pct(val, prev_val)} "
                f"| {_pct(val, base_val.get((driver, metric)))} "
                f"| {sparkline(series)} |")
    lines.append("")

    # -------------------------------------------------- flush latency ----
    lat_lines: List[str] = []
    for driver in sorted(by_latest):
        snap = by_latest[driver].get("snapshot") or {}
        h = snap.get("broker.flush_seconds")
        if not isinstance(h, dict) or not h.get("count"):
            continue
        merged = {"count": 0, "sum": 0.0, "buckets": {}}
        for r in records:
            if r.get("driver") != driver:
                continue
            rh = (r.get("snapshot") or {}).get("broker.flush_seconds")
            if isinstance(rh, dict) and rh.get("count"):
                merged = merge_hist(merged, rh)
        p50 = quantile_from_snapshot(h, 0.5)
        p99 = quantile_from_snapshot(h, 0.99)
        ap50 = quantile_from_snapshot(merged, 0.5)
        lat_lines.append(
            f"| {driver} | {h['count']} | {p50 * 1e3:.1f} ms "
            f"| {p99 * 1e3:.1f} ms | {ap50 * 1e3:.1f} ms |")
    if lat_lines:
        lines += ["## Broker flush latency (latest run)", "",
                  "| driver | flushes | p50 | p99 | p50 all-history |",
                  "|---|---|---|---|---|", *lat_lines, ""]

    # ------------------------------------------------------- top movers --
    movers: List[tuple] = []
    for driver, rec in sorted(by_latest.items()):
        prev = by_prev.get(driver)
        if prev is None:
            continue
        snap, psnap = rec.get("snapshot") or {}, prev.get("snapshot") or {}
        for key, val in snap.items():
            if not isinstance(val, (int, float)) or isinstance(val, bool):
                continue
            if "compile" not in key and "pad" not in key:
                continue
            pval = psnap.get(key)
            if isinstance(pval, (int, float)) and pval != val:
                movers.append((abs(val - pval), driver, key, pval, val))
    if movers:
        movers.sort(reverse=True)
        lines += ["## Top compile/pad movers (vs previous run)", "",
                  "| driver | metric | prev | latest |", "|---|---|---|---|"]
        lines += [f"| {d} | {k} | {pv:g} | {v:g} |"
                  for _, d, k, pv, v in movers[:8]]
        lines.append("")
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="benchmark trajectory report + regression gate")
    ap.add_argument("--check", action="store_true",
                    help="evaluate baselines; exit 1 on any regression")
    ap.add_argument("--history", default=str(DEFAULT_HISTORY))
    ap.add_argument("--baselines", default=str(DEFAULT_BASELINES))
    ap.add_argument("--out", default=None,
                    help="also write the rendered report to this path")
    ap.add_argument("--last", type=int, default=12,
                    help="history window for sparklines")
    ap.add_argument("--update-baselines", action="store_true",
                    help="ratchet every 'best' entry to its current "
                         "candidate and rewrite the baselines file")
    args = ap.parse_args(argv)

    records, problems = load_history(Path(args.history))
    try:
        baselines = load_baselines(Path(args.baselines))
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"cannot load baselines: {e}", file=sys.stderr)
        return 2
    for p in problems:
        print(f"history: {p}", file=sys.stderr)

    if args.update_baselines:
        changed = update_baselines(records, baselines)
        Path(args.baselines).write_text(
            json.dumps(baselines, indent=1, sort_keys=True) + "\n")
        for line in changed:
            print(f"baseline updated: {line}")
        if not changed:
            print("baselines already at their candidates; file rewritten")

    checks = check(records, baselines)
    report = render_report(records, baselines, checks, last_n=args.last)
    print(report)
    if args.out:
        out = Path(args.out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(report)

    if args.check:
        failures = [c for c in checks if not c["ok"]]
        if problems:
            print(f"REGRESSION GATE: history.jsonl has "
                  f"{len(problems)} schema problem(s)", file=sys.stderr)
        for c in failures:
            print(f"REGRESSION: {c['namespace']}/{c['driver']}:"
                  f"{c['metric']} — {c['detail']}", file=sys.stderr)
        if failures or problems:
            return 1
        print(f"regression gate ok: {len(checks)} baseline checks passed "
              f"over {len(records)} records")
    return 0


if __name__ == "__main__":
    sys.exit(main())
