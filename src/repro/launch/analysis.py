"""HLO collective parsing + analytic step-FLOPs (dry-run helpers).

Importable without touching jax device state (unlike dryrun.py, which must
set XLA_FLAGS at import).
"""
from __future__ import annotations

import re

COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s32|u32|s8|u8|pred|s64|u64)"
                      r"\[([0-9,]*)\]")
GROUP_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")

DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
               "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo: str):
    """Per-device collective traffic estimate from the SPMD HLO.

    Ring-model bytes-on-wire per device: all-gather / reduce-scatter /
    all-to-all move (g-1)/g of the full buffer; all-reduce moves 2x that;
    collective-permute moves the buffer once.
    """
    out = {}
    for m in COLLECTIVE_RE.finditer(hlo):
        shape_txt, op = m.group(1), m.group(2)
        nbytes = _shape_bytes(shape_txt)
        line_end = hlo.find("\n", m.end())
        line = hlo[m.start():line_end if line_end > 0 else len(hlo)]
        gm = GROUP_RE.search(line)
        g = len(gm.group(1).split(",")) if gm else 2
        frac = (g - 1) / g
        if op == "all-reduce":
            traffic = 2 * nbytes * frac
        elif op == "collective-permute":
            traffic = nbytes
        else:
            traffic = nbytes * frac
        d = out.setdefault(op, {"count": 0, "bytes": 0.0, "traffic": 0.0})
        d["count"] += 1
        d["bytes"] += nbytes
        d["traffic"] += traffic
    return out


def model_flops(cfg, shape) -> float:
    """Classic 2ND (fwd) / 6ND (train) matmul-FLOPs-per-step estimate."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch          # decode: one token per seq


_BROKER = None


def _default_broker():
    """Shared simulation-service broker for analysis helpers (lazy: keeps
    this module importable without touching jax device state)."""
    global _BROKER
    if _BROKER is None:
        from repro.service import SimBroker
        _BROKER = SimBroker(max_lanes=64, lane_sharding="auto")
    return _BROKER


def policy_sweep_summary(mc, policies, trace, cc=None, baseline: int = 0,
                         broker=None):
    """Ad-hoc policy comparison on one trace via the simulation service.

    Every PolicyConfig in ``policies`` becomes a SimQuery against the
    shared broker (``broker=None``), so grid regeneration microbatches
    into per-bucket ``sweep_lanes`` programs, repeats are answered from
    the content-addressed result cache, and — unlike a raw ``sweep()``
    call — mixed AutoNUMA periods are legal (they just land in separate
    buckets).  Returns ``{label: summary}`` where each summary carries
    the simulator metrics plus ``improvement_pct`` of ``total_cycles``
    against the ``baseline``-indexed policy.  Imports lazily so this
    module stays importable without touching jax device state.
    """
    from repro.core import CostConfig
    from repro.service import SimQuery

    broker = broker if broker is not None else _default_broker()
    cc = cc if cc is not None else CostConfig()
    results = broker.run([SimQuery(trace=trace, policy=pc, cost=cc,
                                   machine=mc) for pc in policies])
    base_total = results[baseline].summary()["total_cycles"]
    out = {}
    for i, (pc, res) in enumerate(zip(policies, results)):
        m = res.summary()
        m["improvement_pct"] = (100.0 * (base_total - m["total_cycles"])
                                / max(base_total, 1e-12))
        key = pc.label()
        if key in out:            # same label, different non-label knobs
            key = f"{key}#{i}"
        out[key] = m
    return out


