import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory / cost / collective statistics.

The two lines above MUST run before any jax import (device count locks at
first init).  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun                 # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b \
        --shape train_4k --mesh single                           # one cell

Artifacts land in artifacts/dryrun/<mesh>/<arch>__<shape>.json and are
skipped if present (delete to re-run); benchmarks/roofline.py consumes
them.
"""
import argparse
import dataclasses
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.base import SHAPES, cell_is_valid
from repro.distributed import sharding as shard_mod
from repro.models import model as model_mod
from repro.models.modules import count_params
from repro.training import optimizer as opt_mod
from repro.training.train import TrainConfig, batch_constraint, make_train_step
from repro.launch.mesh import make_production_mesh

ART_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# Per-cell resource strategy: (microbatches, seq_shard, factored_opt).
# Chosen by napkin math over v5e HBM (16 GB/chip) — see EXPERIMENTS.md
# §Dry-run for the per-cell memory_analysis that validates these.
TRAIN_OVERRIDES = {
    "nemotron-4-340b": dict(microbatches=16, seq_shard=True, factored=True,
                            accum_dtype="bfloat16"),
    "deepseek-coder-33b": dict(microbatches=8, seq_shard=True,
                               factored=True),
    "qwen2.5-14b": dict(microbatches=8, seq_shard=True),
    "qwen1.5-0.5b": dict(microbatches=1),
    "llama4-maverick-400b-a17b": dict(microbatches=16, seq_shard=True,
                                      factored=True,
                                      accum_dtype="bfloat16"),
    "llama4-scout-17b-16e": dict(microbatches=16, seq_shard=True,
                                 factored=True),
    "qwen2-vl-2b": dict(microbatches=4),
    "hubert-xlarge": dict(microbatches=4),
    "jamba-v0.1-52b": dict(microbatches=16, seq_shard=True, factored=True),
    "rwkv6-3b": dict(microbatches=4),
}

from repro.launch.analysis import (model_flops,
                                   parse_collectives)


def _dp_axes(mesh):
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return dp[0] if len(dp) == 1 else dp


def auto_out_shardings(mesh, out_shapes, batch_div):
    """Output shardings by leaf rank: rank-5 [G,B,S,KH,Dh] KV collections
    shard batch over DP and head_dim over model; rank-2 [B,V] logits shard
    batch; everything else replicates."""
    dp = _dp_axes(mesh)
    dp_size = shard_mod.mesh_axis_size(mesh, dp)
    tp = shard_mod.mesh_axis_size(mesh, "model") if "model" in mesh.shape \
        else 1

    def one(s):
        if not hasattr(s, "shape"):
            return NamedSharding(mesh, P())
        if len(s.shape) == 5 and s.shape[1] % dp_size == 0:
            last = "model" if s.shape[-1] % tp == 0 else None
            return NamedSharding(mesh, P(None, dp, None, None, last))
        if len(s.shape) >= 1 and s.shape and s.shape[0] % dp_size == 0 \
                and len(s.shape) <= 2 and s.shape[0] == batch_div:
            return NamedSharding(mesh, P(dp))
        return NamedSharding(mesh, P())
    return jax.tree.map(one, out_shapes)


def build_cell(cfg, shape, mesh, variant=None):
    """Returns (fn, example_args) ready for jit lower."""
    specs = model_mod.param_specs(cfg)
    pbytes = sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
                 for s in jax.tree.leaves(
                     specs, is_leaf=lambda x: isinstance(x, shard_mod.ParamSpec)))
    rules = shard_mod.choose_rules(
        pbytes, mesh, mode="train" if shape.kind == "train" else "serve")
    overrides = PERF_VARIANTS.get(variant, {}).get((cfg.name, shape.name), {})
    if "rules" in overrides:
        rules = shard_mod.RULE_SETS[overrides["rules"]]
    p_sh = shard_mod.param_shardings(specs, mesh, rules)
    abs_params = model_mod.make_abstract_params(cfg)
    abs_params = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        abs_params, p_sh)

    batch_specs = model_mod.input_specs(cfg, shape.seq_len,
                                        shape.global_batch, shape.kind)
    b_sh = shard_mod.batch_specs(batch_specs, mesh)
    abs_batch = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        batch_specs, b_sh)

    if shape.kind == "train":
        ov = dict(TRAIN_OVERRIDES.get(cfg.name, {}))
        ov.update({k: v for k, v in overrides.items() if k != "rules"})
        factored = ov.pop("factored", False)
        tc = TrainConfig(opt=opt_mod.OptConfig(factored=factored), **ov)
        step = make_train_step(cfg, tc, mesh)
        o_sh = shard_mod.opt_state_shardings(specs, mesh, rules, factored)
        abs_opt = opt_mod.abstract_opt_state(abs_params, factored)
        abs_opt = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            abs_opt, o_sh)
        metric_sh = {"loss": NamedSharding(mesh, P()),
                     "grad_norm": NamedSharding(mesh, P()),
                     "lr": NamedSharding(mesh, P())}
        fn = jax.jit(step, donate_argnums=(0, 1),
                     out_shardings=(p_sh, o_sh, metric_sh))
        return fn, (abs_params, abs_opt, abs_batch), dict(strategy=ov,
                                                          factored=factored,
                                                          rules_fsdp=rules is shard_mod.FSDP_RULES)

    if shape.kind == "prefill":
        act = batch_constraint(mesh)

        def fn(params, batch):
            return model_mod.prefill(cfg, params, batch, act_constraint=act)
        out_shapes = jax.eval_shape(fn, abs_params, abs_batch)
        out_sh = auto_out_shardings(mesh, out_shapes, shape.global_batch)
        return jax.jit(fn, out_shardings=out_sh), (abs_params, abs_batch), \
            dict(rules_fsdp=rules is shard_mod.FSDP_RULES)

    # decode
    state = model_mod.init_decode_state(
        cfg, shape.global_batch, shape.seq_len, abstract=True,
        kv_dtype=overrides.get("kv_dtype"))
    s_sh = shard_mod.kv_cache_sharding(mesh, state)
    abs_state = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        state, s_sh)

    def fn(params, state, tokens):
        pos = jnp.asarray(shape.seq_len - 1, jnp.int32)
        return model_mod.decode_step(cfg, params, state, tokens, pos)

    logits_sh = NamedSharding(
        mesh, P(_dp_axes(mesh))
        if shape.global_batch % shard_mod.mesh_axis_size(
            mesh, _dp_axes(mesh)) == 0 else P())
    return jax.jit(fn, donate_argnums=(1,),
                   out_shardings=(s_sh, logits_sh)), \
        (abs_params, abs_state, abs_batch["tokens"]), dict(
            rules_fsdp=rules is shard_mod.FSDP_RULES)


# Hillclimb variants (EXPERIMENTS.md §Perf): per-cell strategy changes,
# lowered side-by-side with the baseline into artifacts/dryrun/<mesh>-<v>/.
PERF_VARIANTS = {
    "moe_ep": {
        ("llama4-maverick-400b-a17b", "train_4k"): dict(rules="moe_ep"),
        ("llama4-scout-17b-16e", "train_4k"): dict(rules="moe_ep"),
        ("jamba-v0.1-52b", "train_4k"): dict(rules="moe_ep"),
    },
    "moe_ep_mb4": {
        ("llama4-maverick-400b-a17b", "train_4k"): dict(rules="moe_ep",
                                                        microbatches=4),
        ("jamba-v0.1-52b", "train_4k"): dict(rules="moe_ep",
                                             microbatches=4),
    },
    "moe_ep_tp": {
        ("llama4-maverick-400b-a17b", "train_4k"): dict(rules="moe_ep_tp"),
        ("jamba-v0.1-52b", "train_4k"): dict(rules="moe_ep_tp"),
    },
    "kv_f8": {
        ("deepseek-coder-33b", "decode_32k"): dict(kv_dtype="float8_e4m3fn"),
        ("qwen2.5-14b", "decode_32k"): dict(kv_dtype="float8_e4m3fn"),
    },
}


def run_cell(arch_id: str, shape_id: str, mesh_name: str,
             force: bool = False, variant=None) -> dict:
    dir_name = mesh_name if not variant else f"{mesh_name}-{variant}"
    out_dir = ART_DIR / dir_name
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"{arch_id}__{shape_id}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = configs.get_config(arch_id)
    shape = SHAPES[shape_id]
    rec = {"arch": arch_id, "shape": shape_id, "mesh": mesh_name,
           "n_params": cfg.n_params(), "n_active": cfg.n_active_params(),
           "model_flops": model_flops(cfg, shape)}
    ok, reason = cell_is_valid(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=reason)
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    rec["n_chips"] = n_chips
    rec["variant"] = variant
    try:
        with mesh:
            fn, args, meta = build_cell(cfg, shape, mesh, variant)
            rec.update(meta)
            t0 = time.time()
            lowered = fn.lower(*args)
            rec["lower_s"] = round(time.time() - t0, 1)
            t0 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t0, 1)
            ma = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_est_bytes": ma.argument_size_in_bytes
                + ma.output_size_in_bytes + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes,
            }
            ca = compiled.cost_analysis() or {}
            rec["cost"] = {"flops": float(ca.get("flops", -1)),
                           "bytes_accessed": float(ca.get("bytes accessed",
                                                          -1))}
            hlo = compiled.as_text()
            rec["collectives"] = parse_collectives(hlo)
            rec["hlo_bytes"] = len(hlo)
            rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record the failure verbatim
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        rec["traceback"] = traceback.format_exc()[-3000:]
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multipod", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default=None,
                    help="PERF_VARIANTS key: lower only its cells")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(configs.ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    if args.variant:
        cells = list(PERF_VARIANTS[args.variant])
        archs = sorted({a for a, _ in cells if args.arch in (None, a)})
        shapes = sorted({s for _, s in cells})
    meshes = {"single": ["single"], "multipod": ["multipod"],
              "both": ["single", "multipod"]}[args.mesh]

    for mesh_name in meshes:
        for arch_id in archs:
            for shape_id in shapes:
                t0 = time.time()
                rec = run_cell(arch_id, shape_id, mesh_name,
                               force=args.force, variant=args.variant)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    peak = rec["memory"]["peak_est_bytes"] / (1 << 30)
                    extra = (f"peak={peak:.1f}GiB "
                             f"flops/dev={rec['cost']['flops']:.3g} "
                             f"compile={rec.get('compile_s', 0)}s")
                elif status == "skipped":
                    extra = rec["reason"]
                else:
                    extra = rec["error"][:160]
                print(f"[{mesh_name}] {arch_id} x {shape_id}: {status} "
                      f"{extra} ({time.time() - t0:.0f}s)", flush=True)


if __name__ == "__main__":
    main()
