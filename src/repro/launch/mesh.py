"""Production mesh construction.

A pod is 256 chips as (data=16, model=16); the multi-pod mesh prepends a
"pod" axis (2 pods = 512 chips).  Defined as functions so importing this
module never touches jax device state (device count is locked at first
init — dryrun.py must set XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax

from ..distributed import sharding


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return sharding.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4) -> jax.sharding.Mesh:
    """Small mesh over host CPU devices for tests/examples."""
    n = len(jax.devices())
    data = min(data, max(n // model, 1))
    if data * model > n:
        model = n // data
    return sharding.make_mesh((data, model), ("data", "model"))
