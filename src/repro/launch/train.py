"""Training launcher: config -> mesh -> train loop with checkpoint/restart.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --reduced --steps 200 --ckpt-dir /tmp/ckpt --resume auto

Fault tolerance: checkpoints every ``--ckpt-every`` steps (atomic manifest,
async write), ``--resume auto`` restarts from the newest complete one, and
a per-step watchdog aborts cleanly if a step exceeds ``--step-timeout``
(on a real pod the cluster manager restarts the job, which then resumes).
Elastic rescale: restoring onto a different mesh/DP degree re-shards via
the checkpoint loader; the data pipeline is stateless in (step, row), so
no data is skipped or repeated.
"""
from __future__ import annotations

import argparse
import signal
import time

import jax
import numpy as np

from repro import configs
from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, batch_at
from repro.distributed import sharding as shard_mod
from repro.launch.mesh import make_host_mesh
from repro.models import make_params, param_specs
from repro.training import optimizer as opt_mod
from repro.training.train import TrainConfig, make_train_step


class StepWatchdog:
    """Aborts the process if a train step wedges (straggler/deadlock)."""

    def __init__(self, timeout_s: float):
        self.timeout = timeout_s

    def __enter__(self):
        if self.timeout > 0:
            signal.signal(signal.SIGALRM, self._fire)
            signal.alarm(int(self.timeout))
        return self

    def _fire(self, *_):
        raise TimeoutError(f"train step exceeded {self.timeout}s watchdog")

    def __exit__(self, *exc):
        if self.timeout > 0:
            signal.alarm(0)
        return False


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale variant (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="none", choices=["none", "auto"])
    ap.add_argument("--step-timeout", type=float, default=0.0)
    ap.add_argument("--data", type=int, default=1, help="mesh data axis")
    ap.add_argument("--model", type=int, default=1, help="mesh model axis")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg)
    mesh = make_host_mesh(args.data, args.model)
    specs = param_specs(cfg)
    p_sh = shard_mod.param_shardings(specs, mesh)

    tc = TrainConfig(microbatches=args.microbatches,
                     opt=opt_mod.OptConfig(lr=args.lr, warmup_steps=20,
                                           total_steps=args.steps))
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                    global_batch=args.global_batch)

    with mesh:
        params = jax.tree.map(jax.device_put,
                              make_params(cfg, jax.random.PRNGKey(0)), p_sh)
        opt_state = opt_mod.init_opt_state(params)
        start = 0
        if args.resume == "auto" and args.ckpt_dir:
            latest = ckpt.latest_step(args.ckpt_dir)
            if latest is not None:
                example = jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                    {"params": params, "opt": opt_state})
                sh = {"params": p_sh,
                      "opt": jax.tree.map(lambda x: x.sharding, opt_state)}
                tree = ckpt.restore(args.ckpt_dir, latest, example, sh)
                params, opt_state = tree["params"], tree["opt"]
                start = latest
                print(f"resumed from step {latest}", flush=True)

        step_fn = jax.jit(make_train_step(cfg, tc, mesh),
                          donate_argnums=(0, 1))
        t0 = time.time()
        pending = None
        for step in range(start, args.steps):
            batch = batch_at(dc, step)
            with StepWatchdog(args.step_timeout):
                params, opt_state, metrics = step_fn(params, opt_state,
                                                     batch)
            if (step + 1) % args.log_every == 0 or step == start:
                loss = float(metrics["loss"])
                tok_s = (dc.global_batch * dc.seq_len * args.log_every
                         / max(time.time() - t0, 1e-9))
                print(f"step {step + 1}: loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"tok/s={tok_s:.0f}", flush=True)
                t0 = time.time()
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                if pending is not None:
                    pending.join()
                pending = ckpt.save(args.ckpt_dir, step + 1,
                                    {"params": params, "opt": opt_state},
                                    blocking=False)
        if pending is not None:
            pending.join()
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
