"""Deterministic, elastic, shardable synthetic token pipeline.

Tokens are a pure function of (step, global_row, column) via a counter-mode
hash — so any host can materialize exactly its shard of the global batch
with no coordination, restarts are bit-reproducible from the step counter
alone, and *elastic rescaling* (changing DP degree mid-run) cannot shift
data: host h of H serves global rows [h*B/H, (h+1)*B/H).

A light Zipf shaping makes the loss curve non-degenerate (uniform random
tokens give a flat loss surface).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    zipf_theta: float = 1.1


def _hash(x: jnp.ndarray) -> jnp.ndarray:
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def batch_at(cfg: DataConfig, step: int, host_rows=None):
    """Materialize (tokens, targets) for ``step``.

    host_rows: optional (start, count) to produce only this host's shard.
    """
    start, count = host_rows or (0, cfg.global_batch)
    rows = jnp.arange(start, start + count, dtype=jnp.uint32)
    cols = jnp.arange(cfg.seq_len + 1, dtype=jnp.uint32)
    seed = jnp.uint32(step) * jnp.uint32(0x9E3779B9)
    h = _hash(seed + _hash(rows[:, None] * jnp.uint32(65537) + cols))
    u = (h >> 8).astype(jnp.float32) / jnp.float32(1 << 24)
    # inverse-CDF Zipf over the vocab (approximate, closed form)
    theta = cfg.zipf_theta
    ranks = jnp.power(1.0 - u, -1.0 / (theta - 1.0)) - 1.0
    toks = jnp.clip(ranks.astype(jnp.int32), 0, cfg.vocab - 1)
    # deterministic n-gram structure so a model can actually learn:
    # every third token repeats the hash of its two predecessors
    mix = _hash(toks[:, :-2].astype(jnp.uint32) * jnp.uint32(31)
                + toks[:, 1:-1].astype(jnp.uint32))
    learned = (mix % jnp.uint32(cfg.vocab)).astype(jnp.int32)
    pos = jnp.arange(cfg.seq_len + 1)[None, 2:]
    toks = toks.at[:, 2:].set(
        jnp.where(pos % 3 == 0, learned, toks[:, 2:]))
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def host_iter(cfg: DataConfig, host_id: int, n_hosts: int, start_step: int = 0):
    per = cfg.global_batch // n_hosts
    step = start_step
    while True:
        yield batch_at(cfg, step, host_rows=(host_id * per, per))
        step += 1
