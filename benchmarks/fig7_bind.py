"""Paper Fig. 7 + section 3.5: the bind-all pathology.

Binding the whole page table to DRAM sends PT allocations down the buddy
slow path once DRAM fills, and finally OOM-kills the workload while NVMM
still has free memory.  Radiant (BHi) binds only the tiny upper levels.
"""
from __future__ import annotations

import dataclasses

from . import common
from repro.core import benchmark_machine, bhi, bind_all, linux_default, workloads


def main(quick: bool = False):
    # Tighter watermark/page-cache reserve (still realistic for Linux
    # min_free_kbytes scale): the paper's Fig. 7 machine has RSS ~2.7x
    # DRAM and reclaim headroom far below the PT-page demand, which is
    # what lets bind-all run the box out of memory.
    mc = dataclasses.replace(benchmark_machine(), low_watermark=0.005,
                             reclaimable_frac=0.003)
    tr = workloads.kv_store(mc, common.FOOTPRINT, run_steps=64,
                            name="memcached")
    pairs = [("first-touch", linux_default(autonuma=False)),
             ("bind-all-PT", bind_all(autonuma=False)),
             ("BHi", bhi(autonuma=False))]
    sweep_res, secs = common.run_sweep(mc, [pc for _, pc in pairs], tr)
    results, rows = {}, []
    for (pname, _), res in zip(pairs, sweep_res):
        m = res.summary()
        results[pname] = m
        nvmm_free = None
        rows.append((f"fig7/memcached/{pname}", secs,
                     f"slow_allocs={m['slow_allocs']};"
                     f"oom_killed={m['oom_killed']};oom_step={m['oom_step']};"
                     f"faults={m['faults']}"))
    common.emit(rows)
    common.emit_record("fig7_bind", results, rows=rows, quick=quick)
    return results


if __name__ == "__main__":
    main()
