"""Paper Table 4: the headline geomean summary, side by side with the
paper's numbers."""
from __future__ import annotations

import json
from pathlib import Path

from . import common

PAPER = {
    "fullsystem/BHi": dict(total=3.32, walk=4.56, stall=5.68),
    "fullsystem/BHi+Mig": dict(total=20.71, walk=12.38, stall=20.9),
    "multitenant/BHi+Mig": dict(total=19.85, walk=32.62, stall=23.25),
    "interleave/BHi": dict(total=10.02, walk=10.53, stall=9.01),
    "thp/BHi": dict(total=51.82, walk=36.37, stall=38.63),
}


def main(quick: bool = False):
    art = common.ART
    rows = []
    summary = {}

    def geo(fig, policy, key):
        data = json.loads((art / f"{fig}.json").read_text())
        return common.geomean_improvement(
            [data[w][policy]["improv"][key] for w in data])

    def regenerate(fig):
        """Produce a missing artifact by running its (sweep-batched) figure."""
        import importlib
        importlib.import_module(f"benchmarks.{fig}").main(quick=quick)

    specs = [
        ("fullsystem/BHi", "fig9_fullsystem", "BHi"),
        ("fullsystem/BHi+Mig", "fig9_fullsystem", "BHi+Mig"),
        ("multitenant/BHi+Mig", "fig10_multitenant", "BHi+Mig"),
        ("interleave/BHi", "fig11_interleave", "interleave+BHi"),
        ("thp/BHi", "fig13_thp", "thp-BHi"),
    ]
    for label, fig, policy in specs:
        if not (art / f"{fig}.json").exists():
            regenerate(fig)
        try:
            ours = {k: geo(fig, policy, k) for k in ("total", "walk", "stall")}
        except (FileNotFoundError, KeyError):
            continue
        summary[label] = {"ours": ours, "paper": PAPER[label]}
        p = PAPER[label]
        rows.append((f"table4/{label}", 0.0,
                     f"ours(total={ours['total']:.1f}%,walk={ours['walk']:.1f}%,"
                     f"stall={ours['stall']:.1f}%) "
                     f"paper(total={p['total']}%,walk={p['walk']}%,"
                     f"stall={p['stall']}%)"))
    common.emit(rows)
    common.emit_record("table4_summary", summary, rows=rows, quick=quick)
    return summary


if __name__ == "__main__":
    main()
