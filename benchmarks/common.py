"""Shared benchmark harness for the paper-reproduction suite.

One entry per paper table/figure lives in ``benchmarks/fig*.py`` /
``table*.py``; each emits CSV rows ``name,seconds,derived`` (the derived
column carries the figure's headline metric) and a JSON artifact under
``artifacts/bench/``.

The machine is the scaled paper box (``core.config.benchmark_machine``):
radix-6 tables, DRAM:footprint and NVMM-latency ratios of Table 1.  Traces
within a figure are padded to one shape so every policy shares a single
compiled simulator.
"""
from __future__ import annotations

import json
import time
from collections import OrderedDict
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np

from repro.core import (CostConfig, MachineConfig, PolicyConfig,
                        TieredMemSimulator, Trace, benchmark_machine,
                        bhi, bhi_mig, bind_all, linux_default, pad_trace,
                        sweep, workloads)
from repro.obs import bench as obsbench

ART = Path(__file__).resolve().parent.parent / "artifacts" / "bench"
HISTORY = ART / "history.jsonl"

# Process-wide benchmark telemetry (lazy).  Drivers report into it, embed
# its snapshot in their artifacts, and ``run.py --verbose`` prints it
# after each driver (run.py resets it between drivers so snapshots stay
# per-driver).  Tracing is on: benchmark runs are exactly where a
# Perfetto-loadable trace of the query lifecycle is worth its memory.
_TELEMETRY = None


def telemetry():
    global _TELEMETRY
    if _TELEMETRY is None:
        from repro.obs import Telemetry
        _TELEMETRY = Telemetry(tracing=True)
    return _TELEMETRY

# scaled run dimensions (see DESIGN.md section 2: ratios, not magnitudes)
FOOTPRINT = 1 << 18
RUN_STEPS = 8192
QUICK_RUN_STEPS = 2048

WORKLOADS = ("memcached", "redis", "btree", "hashjoin", "xsbench", "bfs")
# secondary figures use a 4-workload subset to bound suite runtime; fig9
# (the headline) runs all six
WORKLOADS_SMALL = ("memcached", "redis", "btree", "xsbench")


# Figures regenerate the same workload traces (and their padded variants)
# many times over a suite run; both are cached here.  Raw traces key on
# (workload, machine, footprint, steps); padded variants additionally on
# the padded shape, so every figure sharing a shape reuses one array set —
# and, downstream, one `sim.fault_schedule` host pass and one compile.
# LRU-bounded like sim._SCHED_CACHE: a suite run stays well under the cap,
# while a long-lived process sweeping many machine/step combinations
# doesn't pin FOOTPRINT-scale arrays forever.
_TRACE_CACHE: "OrderedDict[tuple, Trace]" = OrderedDict()
_TRACE_CACHE_MAX = 48


def _trace_cached(key, build) -> Trace:
    if key not in _TRACE_CACHE:
        _TRACE_CACHE[key] = build()
        while len(_TRACE_CACHE) > _TRACE_CACHE_MAX:
            _TRACE_CACHE.popitem(last=False)
    else:
        _TRACE_CACHE.move_to_end(key)
    return _TRACE_CACHE[key]


def make_traces(mc: MachineConfig, run_steps: int = RUN_STEPS,
                names=WORKLOADS) -> Dict[str, Trace]:
    traces = {}
    for name in names:
        gen = workloads.ALL_WORKLOADS[name]
        traces[name] = _trace_cached((name, mc, FOOTPRINT, run_steps),
                                     lambda: gen(mc, FOOTPRINT, run_steps))
    steps = max(t.n_steps for t in traces.values())
    # pad_trace returns the input unchanged when already long enough, so
    # the longest trace's "padded" entry aliases its raw one (no copy)
    return {name: _trace_cached((name, mc, FOOTPRINT, run_steps, steps),
                                lambda: pad_trace(tr, steps))
            for name, tr in traces.items()}


def run(mc: MachineConfig, pc: PolicyConfig, trace: Trace):
    t0 = time.time()
    res = TieredMemSimulator(mc=mc, pc=pc, telemetry=telemetry()).run(trace)
    return res, time.time() - t0


def run_sweep(mc: MachineConfig, policies, traces, cc: Optional[CostConfig] = None):
    """Run a figure's whole policy (× workload) grid as ONE batched scan.

    Wraps ``repro.core.sweep``: a single compile per trace shape and a
    single device program replace the former per-policy Python loop.
    Returns (results, per_lane_seconds) with results shaped like sweep()'s
    output — ``[policy]`` for a single trace, ``[trace][policy]`` for a
    list — and the wall-clock evenly attributed to lanes for the CSV rows.
    """
    t0 = time.time()
    results = sweep(mc, cc if cc is not None else CostConfig(), policies,
                    traces, telemetry=telemetry())
    n_traces = 1 if isinstance(traces, Trace) else len(traces)
    lanes = max(len(policies) * n_traces, 1)
    return results, (time.time() - t0) / lanes


def phase_metrics(res, trace: Trace) -> Dict[str, float]:
    """Split cumulative timelines at the populate/run boundary."""
    tl = res.timeline
    p = min(trace.populate_steps, len(tl["total_cycles"]) - 1)

    def seg(key, a, b):
        return float(tl[key][b] - (tl[key][a] if a > 0 else 0.0))

    last = len(tl["total_cycles"]) - 1
    out = {}
    for key in ("total_cycles", "walk_cycles", "stall_cycles",
                "data_mem_cycles", "fault_cycles"):
        out[f"run_{key}"] = seg(key, p, last)
        out[f"startup_{key}"] = seg(key, 0, p)
    out["run_walks"] = seg("walks", p, last)
    out["startup_walks"] = seg("walks", 0, p)
    out.update(res.summary())
    return out


def improvement(base: float, val: float) -> float:
    """Paper convention: % improvement of val over base (higher = better)."""
    return 100.0 * (base - val) / max(base, 1e-12)


def geomean_improvement(pcts: List[float]) -> float:
    """Geometric mean of speedup ratios, reported back as % improvement."""
    ratios = [max(1e-6, 1.0 - p / 100.0) for p in pcts]
    g = float(np.exp(np.mean(np.log(ratios))))
    return 100.0 * (1.0 - g)


def emit(rows: List[tuple]):
    for name, secs, derived in rows:
        print(f"{name},{secs:.2f},{derived}", flush=True)


def save_artifact(name: str, payload):
    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"{name}.json").write_text(json.dumps(payload, indent=1,
                                                 default=float))


# ---------------------------------------------------------------------------
# BenchRecord emission (the perf observatory's ingest path).
#
# One monotonic run id per process (all drivers of one `benchmarks.run`
# invocation share it); each driver's wall clock runs from the harness's
# begin_driver() call — run.py calls it before every driver, standalone
# `python -m benchmarks.<driver>` falls back to process start.
# ---------------------------------------------------------------------------
_PROC_T0 = time.time()
_RUN_STATE = {"run_id": None, "driver_t0": None}


def run_id() -> int:
    if _RUN_STATE["run_id"] is None:
        _RUN_STATE["run_id"] = obsbench.next_run_id(HISTORY)
    return _RUN_STATE["run_id"]


def begin_driver(name: str = "") -> None:
    """Mark the start of one driver's wall clock."""
    _RUN_STATE["driver_t0"] = time.time()


def emit_record(name: str, payload, rows: List[tuple] = (),
                quick: bool = False, history: bool = True):
    """The one way a driver lands its results: writes the per-driver
    ``artifacts/bench/<name>.json`` (unchanged format — downstream
    readers like table4 still consume it) AND appends a schema-valid
    BenchRecord — figures rows, flattened metrics, telemetry snapshot,
    git/machine fingerprint — to ``artifacts/bench/history.jsonl``.
    """
    save_artifact(name, payload)
    t0 = _RUN_STATE["driver_t0"] or _PROC_T0
    snap = None
    if _TELEMETRY is not None:
        snap = _TELEMETRY.snapshot().get("metrics")
    rec = obsbench.make_record(
        driver=name, payload=payload, figures=rows,
        wall_seconds=time.time() - t0, quick=quick, run_id=run_id(),
        snapshot=snap)
    if history:
        obsbench.append_record(rec, HISTORY)
    return rec
