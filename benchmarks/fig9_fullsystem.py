"""Paper Fig. 9 + Table 4 rows 1-2: full-system run, first-touch policy.

Baseline: Linux first-touch + AutoNUMA (data pages only).  Radiant: BHi
(bind upper PT levels to DRAM) and BHi+Mig (leaf PT migration triggered by
data migrations).  Reports run-phase improvements per workload.
"""
from __future__ import annotations

from . import common
from repro.core import benchmark_machine, bhi, bhi_mig, linux_default


def main(quick: bool = False):
    mc = benchmark_machine()
    steps = common.QUICK_RUN_STEPS if quick else common.RUN_STEPS
    names = common.WORKLOADS[:2] if quick else common.WORKLOADS
    traces = common.make_traces(mc, steps, names)

    policies = [("first-touch", linux_default()), ("BHi", bhi()),
                ("BHi+Mig", bhi_mig())]
    # the whole workload x policy grid runs as one batched compiled scan
    grid, secs = common.run_sweep(mc, [pc for _, pc in policies],
                                  list(traces.values()))
    results = {}
    rows = []
    for (wname, trace), lane_row in zip(traces.items(), grid):
        base = None
        for (pname, _), res in zip(policies, lane_row):
            m = common.phase_metrics(res, trace)
            if base is None:
                base = m
            imp = {k: common.improvement(base[f"run_{k}_cycles"],
                                         m[f"run_{k}_cycles"])
                   for k in ("total", "walk", "stall")}
            # populate-phase (startup) deltas ride along: each trace's
            # populate prefix is exactly the fault-storm regime the
            # batched phase-B engine vectorizes
            imp["startup_total"] = common.improvement(
                base["startup_total_cycles"], m["startup_total_cycles"])
            results.setdefault(wname, {})[pname] = {**m, "improv": imp}
            rows.append((f"fig9/{wname}/{pname}", secs,
                         f"total%={imp['total']:.1f};walk%={imp['walk']:.1f};"
                         f"stall%={imp['stall']:.1f};"
                         f"walk_share={m['run_walk_cycles']/max(m['run_total_cycles'],1):.3f}"))
    common.emit(rows)

    for pname in ("BHi", "BHi+Mig"):
        for k in ("total", "walk", "stall"):
            g = common.geomean_improvement(
                [results[w][pname]["improv"][k] for w in results])
            rows.append((f"fig9/geomean/{pname}/{k}", 0.0, f"{g:.2f}%"))
            print(f"fig9/geomean/{pname}/{k},0.00,{g:.2f}%", flush=True)
    common.emit_record("fig9_fullsystem", results, rows=rows, quick=quick)
    return results


if __name__ == "__main__":
    main()
