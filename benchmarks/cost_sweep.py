"""CXL what-if cost sweep: NVMM:DRAM latency ratios through ONE program.

The paper's slow tier is Optane (reads 3x DRAM, writes 4x).  CXL-attached
memory spans a wide latency band — roughly 1.5x (direct CXL DRAM) to 4x+
(far/pooled memory) — and TPP-style placement studies hinge on exactly
this ratio.  ``sweep()`` accepts one CostConfig per lane, so the whole
ratio band x {interleave, interleave+BHi} grid (fig11's setting — the
one where half the page table lands on the slow tier) is a single
compiled device program; the grid is routed through the simulation service
(``repro.service``) to dogfood the broker on a real consumer: every lane
is an ordinary SimQuery, the shape bucket microbatches them, and
re-running the sweep is answered from the result cache.

Emits ``artifacts/bench/cost_sweep.json``: per ratio, both policies'
cycle metrics plus BHi's improvement — showing how the PT-placement win
grows with the slow tier's latency disadvantage.
"""
from __future__ import annotations

import dataclasses
import time

from . import common
from repro.core import (CostConfig, INTERLEAVE, PT_BIND_HIGH,
                        PT_FOLLOW_DATA, PolicyConfig, TraceSpec,
                        benchmark_machine)
from repro.service import SimBroker, SimQuery

RATIOS = (1.5, 2.0, 3.0, 4.0, 6.0, 8.0)


def cost_for(ratio: float) -> CostConfig:
    """Scale both NVMM latencies off DRAM by ``ratio`` (the paper's 3x/4x
    Optane point corresponds to ratio=3.0 on reads with the write penalty
    kept at 4/3 of the read one)."""
    base = CostConfig()
    return CostConfig(nvmm_read=int(base.dram_read * ratio),
                      nvmm_write=int(base.dram_write * ratio * 4 / 3))


def main(quick: bool = False):
    # RSS must exceed DRAM (paper Table 1: ~2.7x) or the slow tier — and
    # hence the swept ratio — never engages.  Quick mode shrinks the
    # machine with the pressure ratio preserved.  The natural trace
    # length lands exactly on a power of two so the broker's canonical
    # padding adds no idle steps (populate = 1.5 * fp / T).
    if quick:
        mc = dataclasses.replace(benchmark_machine(), va_pages=1 << 13,
                                 dram_pages_per_node=1200,
                                 nvmm_pages_per_node=4800)
        fp, run_steps = (1 << 13), 128
    else:
        mc = benchmark_machine()
        fp, run_steps = common.FOOTPRINT, 4096
    spec = TraceSpec(workload="memcached", footprint=fp,
                     run_steps=run_steps)          # fp 2x+ over DRAM total
    # fig11's setting: interleave spreads data AND (follow_data) PT pages
    # round-robin over all four nodes, so half the table lands on the
    # slow tier; BHi pulls the upper levels back to DRAM.  That is the
    # placement delta whose value scales with the latency ratio.
    policies = [
        ("interleave", PolicyConfig(data_policy=INTERLEAVE,
                                    pt_policy=PT_FOLLOW_DATA,
                                    autonuma=False)),
        ("interleave+BHi", PolicyConfig(data_policy=INTERLEAVE,
                                        pt_policy=PT_BIND_HIGH,
                                        autonuma=False)),
    ]

    broker = SimBroker(max_lanes=len(RATIOS) * len(policies),
                       lane_sharding="auto")
    queries = [SimQuery(trace=spec, policy=pc, cost=cost_for(r), machine=mc)
               for r in RATIOS for _, pc in policies]

    t0 = time.time()
    res = broker.run(queries)
    secs = time.time() - t0

    results, rows = {}, []
    for i, r in enumerate(RATIOS):
        by_pol = {}
        for j, (pname, _) in enumerate(policies):
            m = res[i * len(policies) + j].summary()
            by_pol[pname] = m
        imp = common.improvement(by_pol["interleave"]["total_cycles"],
                                 by_pol["interleave+BHi"]["total_cycles"])
        walk_imp = common.improvement(
            by_pol["interleave"]["walk_cycles"],
            by_pol["interleave+BHi"]["walk_cycles"])
        results[f"{r:g}x"] = {"policies": by_pol, "bhi_total_improv": imp,
                              "bhi_walk_improv": walk_imp}
        rows.append((
            f"cost_sweep/{r:g}x", secs / len(RATIOS),
            f"bhi_total_improv={imp:.2f}%;bhi_walk_improv={walk_imp:.2f}%;"
            f"base_walk_share={by_pol['interleave']['walk_share']:.3f}"))
    results["_meta"] = {
        "footprint": fp, "run_steps": run_steps, "seconds": secs,
        "broker_stats": broker.stats.as_dict(),
    }
    common.emit(rows)
    common.save_artifact("cost_sweep", results)
    return results


if __name__ == "__main__":
    main()
