"""CXL what-if cost sweep + the N-tier scenario-matrix driver.

The paper's slow tier is Optane (reads 3x DRAM, writes 4x).  CXL-attached
memory spans a wide latency band — roughly 1.5x (direct CXL DRAM) to 4x+
(far/pooled memory) — and TPP-style placement studies hinge on exactly
this ratio.  ``sweep()`` accepts one CostConfig per lane, so the whole
ratio band x {interleave, interleave+BHi} grid (fig11's setting — the
one where half the page table lands on the slow tier) is a single
compiled device program; the grid is routed through the simulation service
(``repro.service``) to dogfood the broker on a real consumer: every lane
is an ordinary SimQuery, the shape bucket microbatches them, and
re-running the sweep is answered from the result cache.

Emits ``artifacts/bench/cost_sweep.json``: per ratio, both policies'
cycle metrics plus BHi's improvement — showing how the PT-placement win
grows with the slow tier's latency disadvantage.

``scenario_main`` (registered as ``scenario_matrix`` in
``benchmarks.run``) is the N-tier generalization: the full

    policy family x tier topology x latency ratio x workload

matrix through the broker.  Families are the migration algorithms
(AutoNUMA, AutoNUMA+BHi+Mig, TPP, Nomad), topologies the classic 2-tier
DRAM/NVMM box and the 3-tier DRAM/CXL/NVMM one, and every cell is an
ordinary SimQuery so the whole matrix compiles once per (tier topology,
trace shape) bucket — asserted in the emitted
``artifacts/bench/scenario_matrix.json`` (``compile_check``), which CI
regenerates with ``--quick`` and uploads.
"""
from __future__ import annotations

import dataclasses
import time

from . import common
from repro.core import (CostConfig, INTERLEAVE, MachineConfig, PT_BIND_HIGH,
                        PT_FOLLOW_DATA, PolicyConfig, TraceSpec,
                        benchmark_machine, bhi_mig, cxl_machine,
                        linux_default, nomad, tpp)
from repro.service import SimBroker, SimQuery

RATIOS = (1.5, 2.0, 3.0, 4.0, 6.0, 8.0)


def cost_for(ratio: float) -> CostConfig:
    """Scale both NVMM latencies off DRAM by ``ratio`` (the paper's 3x/4x
    Optane point corresponds to ratio=3.0 on reads with the write penalty
    kept at 4/3 of the read one)."""
    base = CostConfig()
    return CostConfig(nvmm_read=int(base.dram_read * ratio),
                      nvmm_write=int(base.dram_write * ratio * 4 / 3))


def main(quick: bool = False):
    # RSS must exceed DRAM (paper Table 1: ~2.7x) or the slow tier — and
    # hence the swept ratio — never engages.  Quick mode shrinks the
    # machine with the pressure ratio preserved.  The natural trace
    # length lands exactly on a power of two so the broker's canonical
    # padding adds no idle steps (populate = 1.5 * fp / T).
    if quick:
        mc = dataclasses.replace(benchmark_machine(), va_pages=1 << 13,
                                 dram_pages_per_node=1200,
                                 nvmm_pages_per_node=4800)
        fp, run_steps = (1 << 13), 128
    else:
        mc = benchmark_machine()
        fp, run_steps = common.FOOTPRINT, 4096
    spec = TraceSpec(workload="memcached", footprint=fp,
                     run_steps=run_steps)          # fp 2x+ over DRAM total
    # fig11's setting: interleave spreads data AND (follow_data) PT pages
    # round-robin over all four nodes, so half the table lands on the
    # slow tier; BHi pulls the upper levels back to DRAM.  That is the
    # placement delta whose value scales with the latency ratio.
    policies = [
        ("interleave", PolicyConfig(data_policy=INTERLEAVE,
                                    pt_policy=PT_FOLLOW_DATA,
                                    autonuma=False)),
        ("interleave+BHi", PolicyConfig(data_policy=INTERLEAVE,
                                        pt_policy=PT_BIND_HIGH,
                                        autonuma=False)),
    ]

    broker = SimBroker(max_lanes=len(RATIOS) * len(policies),
                       lane_sharding="auto", telemetry=common.telemetry())
    queries = [SimQuery(trace=spec, policy=pc, cost=cost_for(r), machine=mc)
               for r in RATIOS for _, pc in policies]

    t0 = time.time()
    res = broker.run(queries)
    secs = time.time() - t0

    results, rows = {}, []
    for i, r in enumerate(RATIOS):
        by_pol = {}
        for j, (pname, _) in enumerate(policies):
            m = res[i * len(policies) + j].summary()
            by_pol[pname] = m
        imp = common.improvement(by_pol["interleave"]["total_cycles"],
                                 by_pol["interleave+BHi"]["total_cycles"])
        walk_imp = common.improvement(
            by_pol["interleave"]["walk_cycles"],
            by_pol["interleave+BHi"]["walk_cycles"])
        results[f"{r:g}x"] = {"policies": by_pol, "bhi_total_improv": imp,
                              "bhi_walk_improv": walk_imp}
        rows.append((
            f"cost_sweep/{r:g}x", secs / len(RATIOS),
            f"bhi_total_improv={imp:.2f}%;bhi_walk_improv={walk_imp:.2f}%;"
            f"base_walk_share={by_pol['interleave']['walk_share']:.3f}"))
    results["_meta"] = {
        "footprint": fp, "run_steps": run_steps, "seconds": secs,
        "snapshot": broker.snapshot(),
    }
    common.emit(rows)
    common.emit_record("cost_sweep", results, rows=rows, quick=quick)
    return results


# ---------------------------------------------------------------------------
# Scenario matrix: policy family x tier topology x latency ratio x workload
# ---------------------------------------------------------------------------

SCENARIO_RATIOS = (2.0, 3.0, 6.0)
SCENARIO_WORKLOADS = ("memcached", "xsbench")


def scenario_machines(quick: bool):
    """The tier topologies under study.  Quick mode shrinks capacities
    with the DRAM-pressure ratio preserved (footprint must exceed DRAM or
    the migration families never engage)."""
    if quick:
        shrink = dict(va_pages=1 << 13, radix_bits=6)
        return {
            "2tier": MachineConfig(dram_pages_per_node=1200,
                                   nvmm_pages_per_node=4800, **shrink),
            "3tier_cxl": MachineConfig(
                tier_pages_per_node=(1200, 2400, 4800), **shrink),
        }
    return {"2tier": benchmark_machine(), "3tier_cxl": cxl_machine()}


def scenario_cost(ratio: float) -> CostConfig:
    """One latency knob per scenario: the slowest tier's read latency is
    ``ratio`` x DRAM (write 4/3 of that, the Optane proportion) and any
    middle (CXL) tier sits halfway between DRAM and the slow tier."""
    base = CostConfig()
    return CostConfig(
        nvmm_read=int(base.dram_read * ratio),
        nvmm_write=int(base.dram_write * ratio * 4 / 3),
        cxl_read=int(base.dram_read * (1 + ratio) / 2),
        cxl_write=int(base.dram_write * (1 + ratio) / 2))


def scenario_families(quick: bool = False):
    """The migration-policy families of the N-tier model (first-touch
    data placement throughout so the families differ only in how the
    periodic scan balances the tiers).  Quick mode shortens the scan
    period to match its shorter traces, or no scan would ever fire."""
    fams = {
        "autonuma": linux_default(),
        "autonuma+BHi+Mig": bhi_mig(),
        "tpp": tpp(demote_wm=0.02),
        "nomad": nomad(),
    }
    if quick:
        fams = {k: dataclasses.replace(p, autonuma_period=64,
                                       autonuma_budget=128)
                for k, p in fams.items()}
    return fams


def scenario_main(quick: bool = False):
    machines = scenario_machines(quick)
    families = scenario_families(quick)
    ratios = (3.0,) if quick else SCENARIO_RATIOS
    wls = SCENARIO_WORKLOADS[:1] if quick else SCENARIO_WORKLOADS
    fp, run_steps = ((1 << 13), 128) if quick else (common.FOOTPRINT, 4096)

    cells = [(topo, r, wl, fam)
             for topo in machines for r in ratios for wl in wls
             for fam in families]
    queries = [SimQuery(trace=TraceSpec(workload=wl, footprint=fp,
                                        run_steps=run_steps),
                        policy=families[fam], cost=scenario_cost(r),
                        machine=machines[topo])
               for topo, r, wl, fam in cells]

    broker = SimBroker(max_lanes=len(queries), lane_sharding="auto",
                       telemetry=common.telemetry())
    # one compile per (tier topology, trace shape) bucket — the broker's
    # own quantization; computed up front so the emitted artifact can
    # assert the whole matrix really shared that few programs
    expected_compiles = len({broker._bucket_key(q, broker.canonical_trace(q))
                             for q in queries})

    t0 = time.time()
    res = broker.run(queries)
    secs = time.time() - t0

    results: dict = {}
    for (topo, rat, wl, fam), r in zip(cells, res):
        ratio = f"{rat:g}x"
        s = r.summary()
        cell = {k: s[k] for k in
                ("runtime_cycles", "total_cycles", "walk_cycles",
                 "stall_cycles", "walk_share", "faults", "data_migrations",
                 "demotions", "nomad_retries", "nomad_flip_demotions",
                 "shadow_pages")}
        cell["data_pages_per_tier"] = s["data_pages_per_tier"]
        cell["leaf_pages_per_tier"] = s["leaf_pages_per_tier"]
        results.setdefault(topo, {}).setdefault(ratio, {}) \
               .setdefault(wl, {})[fam] = cell

    rows = []
    for topo in machines:
        for ratio in results[topo]:
            for wl in results[topo][ratio]:
                by_fam = results[topo][ratio][wl]
                base = by_fam["autonuma"]["total_cycles"]
                for fam, cell in by_fam.items():
                    cell["improv_vs_autonuma"] = common.improvement(
                        base, cell["total_cycles"])
                best = max(by_fam, key=lambda f:
                           by_fam[f]["improv_vs_autonuma"])
                rows.append((
                    f"scenario_matrix/{topo}/{ratio}/{wl}",
                    secs / len(cells),
                    f"best={best};"
                    f"best_improv={by_fam[best]['improv_vs_autonuma']:.2f}%;"
                    f"tpp_demotions={by_fam['tpp']['demotions']:.0f};"
                    f"nomad_retries={by_fam['nomad']['nomad_retries']:.0f}"))

    compile_check = {"expected": expected_compiles,
                     "actual": broker.stats.compiles,
                     "ok": broker.stats.compiles == expected_compiles}
    results["_meta"] = {
        "quick": quick, "footprint": fp, "run_steps": run_steps,
        "seconds": secs, "lanes": len(cells),
        "topologies": {t: list(m.tier_capacities)
                       for t, m in machines.items()},
        "ratios": [f"{r:g}x" for r in ratios], "workloads": list(wls),
        "families": list(families),
        "compile_check": compile_check,
        "snapshot": broker.snapshot(),
    }
    common.emit(rows)
    common.emit_record("scenario_matrix", results, rows=rows, quick=quick)
    assert compile_check["ok"], (
        f"scenario matrix recompiled: expected one compile per (tier "
        f"topology, trace shape) bucket = {expected_compiles}, "
        f"got {broker.stats.compiles}")
    return results


if __name__ == "__main__":
    main()
