"""Pillar-B benchmark: tiered paged-KV serving with Radiant block tables.

Continuous batching with more sequences than the hot pool holds: paused
sequences' KV blocks are demoted and — under Radiant — their block-table
leaf pages follow (upper levels stay pinned).  Compares Radiant against a
Linux-like immobile-table baseline and reports cold-table walks (decode
steps whose table walk would touch the slow tier) and the invariant
violation count.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from . import common
from repro.memsys import tiered_kv as tkv
from repro.serving.engine import Request, TieredServingEngine


def toy_decode(kv, rid):
    G, _, bs, KH, Dh = kv.hot_k.shape
    t = int(np.asarray(kv.seq_len[rid]))
    k = jnp.full((G, KH, Dh), (rid + 1) * 0.01 + t * 1e-4, jnp.bfloat16)
    return k, k


def run_engine(radiant: bool, n_requests: int, prompt: int, new: int):
    eng = TieredServingEngine(n_groups=2, kv_heads=2, head_dim=128,
                              block_size=16, n_hot_blocks=48,
                              n_cold_blocks=1024, n_seqs=n_requests,
                              max_seq=prompt + new + 32, active_slots=4,
                              radiant=radiant)
    for rid in range(n_requests):
        eng.submit(Request(rid=rid, prompt_len=prompt, max_new=new))
    # prefill on admission
    for rid in range(n_requests):
        G, KH, Dh = 2, 2, 128
        ks = jnp.ones((prompt, G, KH, Dh), jnp.bfloat16) * (rid + 1) * 0.01
        eng.prefill(rid, (ks, ks))
    t0 = time.time()
    stats = eng.run(toy_decode, max_ticks=n_requests * new * 4)
    secs = time.time() - t0
    viol = int(tkv.table_invariant_violations(eng.kv))
    return eng, stats, secs, viol


def main(quick: bool = False):
    n_req, prompt, new = (8, 64, 16) if quick else (12, 96, 24)
    rows, results = [], {}
    for name, radiant in [("radiant", True), ("immobile-tables", False)]:
        eng, stats, secs, viol = run_engine(radiant, n_req, prompt, new)
        s = np.asarray(eng.kv.stats)
        results[name] = dict(tokens=stats.tokens, swaps_in=stats.swaps_in,
                             swaps_out=stats.swaps_out,
                             cold_walks=stats.cold_walks, violations=viol,
                             blk_promote=int(s[0]), blk_demote=int(s[1]),
                             leaf_promote=int(s[2]), leaf_demote=int(s[3]),
                             tok_per_s=stats.tokens / max(secs, 1e-9))
        r = results[name]
        rows.append((f"kv_tiering/{name}", secs,
                     f"tokens={r['tokens']};swaps={r['swaps_in']}/{r['swaps_out']};"
                     f"cold_walks={r['cold_walks']};violations={viol};"
                     f"leaf_migs={r['leaf_promote']}+{r['leaf_demote']}"))
    common.emit(rows)
    common.emit_record("kv_tiering", results, rows=rows, quick=quick)
    return results


if __name__ == "__main__":
    main()
