"""Roofline analysis over the dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell, three terms in seconds:

    compute    = FLOPs / (chips x 197e12 bf16 FLOP/s)
    memory     = HBM bytes / (chips x 819e9 B/s)
    collective = collective bytes-on-wire / (chips x 50e9 B/s per ICI link)

Two variants of each:
  * ``hlo_*``      — straight from ``compiled.cost_analysis()`` and the
    parsed SPMD HLO, as the assignment prescribes.  CAVEAT (measured, see
    EXPERIMENTS.md §Roofline): XLA cost analysis counts while-loop bodies
    ONCE, so any scanned structure (layer stacks, microbatches, attention
    chunks) is undercounted by its trip count.  These numbers are reported
    verbatim but NOT used for bottleneck identification.
  * ``ana_*``      — first-principles estimates with the implementation's
    actual behaviors priced in (full-remat recompute, block-causal 2x
    attention waste, FSDP gathers per microbatch, TP/DP collective
    traffic).  Used to identify the dominant term and drive §Perf.

MODEL_FLOPS = 6·N_active·tokens (train) / 2·N_active·tokens (inference);
the ratio MODEL_FLOPS / ana_flops exposes remat & masked-block waste.
"""
from __future__ import annotations

import json
from pathlib import Path

PEAK_FLOPS = 197e12        # bf16 per chip (v5e-class)
HBM_BW = 819e9             # B/s per chip
ICI_BW = 50e9              # B/s per link

ART = Path(__file__).resolve().parent.parent / "artifacts"
DRY = ART / "dryrun"


def _arch_cfg(arch_id):
    from repro import configs
    return configs.get_config(arch_id)


def _shape(shape_id):
    from repro.configs.base import SHAPES
    return SHAPES[shape_id]


def analytic_terms(rec: dict) -> dict:
    """First-principles FLOPs / HBM bytes / collective bytes per chip."""
    cfg = _arch_cfg(rec["arch"])
    shape = _shape(rec["shape"])
    chips = rec.get("n_chips", 256)
    tp = 16
    dp = chips // tp
    P = cfg.n_params()
    Pa = cfg.n_active_params()
    pbytes = 2.0 * P                      # bf16
    kind = shape.kind
    B, S = shape.global_batch, shape.seq_len
    tokens = B * S
    d, L = cfg.d_model, cfg.n_layers
    H, KH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    fsdp = rec.get("rules_fsdp", False)
    strategy = rec.get("strategy", {}) or {}
    mb = strategy.get("microbatches", 1)
    variant = rec.get("variant") or ""
    moe_ep = variant.startswith("moe_ep")
    kv_f8 = "kv_dtype" in strategy or variant == "kv_f8"
    # under experts-over-data, expert weights are never gathered and their
    # grads need no DP reduction; tokens travel via all-to-all instead
    pbytes_gather = pbytes - (2.0 * cfg.n_expert_params() if moe_ep else 0)

    n_attn = sum(1 for i in range(L)
                 if cfg.attn_every <= 1 or i % cfg.attn_every
                 == cfg.attn_every - 1) if not cfg.rwkv else 0

    if kind == "train":
        # fwd 2ND + bwd 4ND + full-remat recompute fwd 2ND
        flops = 8.0 * Pa * tokens
        # attention: scores+pv 4*S^2*H*Dh per seq-layer; block-causal
        # computes masked blocks too (x2 over causal-optimal); fwd+bwd+
        # recompute => x4 over single fwd
        attn = 4.0 * B * S * S * H * Dh * n_attn * 2.0 / 2.0 * 4.0
        flops += attn
        act_bytes = 12.0 * tokens * d * L * 2.0          # rw per layer, bf16
        hbm = 3.0 * pbytes + 16.0 * P + act_bytes        # params + opt + acts
        # collectives: DP grad reduce + TP act all-reduces (+FSDP gathers)
        coll = 2.0 * (pbytes_gather / tp) * (dp - 1) / dp  # grad all-reduce
        coll += 4.0 * 2.0 * (tokens * d * 2.0 / dp) * (tp - 1) / tp
        if fsdp:
            coll += 3.0 * (pbytes_gather / tp) * (dp - 1) / dp * mb
        if moe_ep:
            topk = cfg.moe.top_k if cfg.moe else 1
            # dispatch + combine all-to-all, fwd + bwd
            coll += 4.0 * (tokens * d * 2.0 / dp) * topk * (dp - 1) / dp
        coll_per_chip = coll / 1.0                        # already per chip-ish
        hbm_per_chip = hbm / chips
        flops_per_chip = flops / chips
        model = 6.0 * Pa * tokens
    elif kind == "prefill":
        flops = 2.0 * Pa * tokens
        attn = 4.0 * B * S * S * H * Dh * n_attn / 2.0 * 2.0  # block-causal
        flops += attn
        kv_bytes = 2.0 * n_attn * tokens * KH * Dh * 2.0
        hbm = pbytes + 6.0 * tokens * d * L * 2.0 + kv_bytes
        coll = 2.0 * (tokens * d * 2.0 / dp) * (tp - 1) / tp * L
        if fsdp:
            coll += (pbytes / tp) * (dp - 1) / dp
        flops_per_chip = flops / chips
        hbm_per_chip = hbm / chips
        coll_per_chip = coll
        model = 2.0 * Pa * tokens
    else:  # decode: one token per sequence
        flops = 2.0 * Pa * B + 4.0 * B * S * H * Dh * n_attn
        kv_elt = 1.0 if kv_f8 else 2.0
        kv_read = 2.0 * n_attn * B * S * KH * Dh * kv_elt
        hbm = pbytes + kv_read
        coll = 2.0 * (B * d * 2.0 / max(dp, 1)) * (tp - 1) / tp * L
        if fsdp:
            coll += (pbytes / tp) * (dp - 1) / dp
        flops_per_chip = flops / chips
        hbm_per_chip = hbm / chips
        coll_per_chip = coll
        model = 2.0 * Pa * B

    return dict(
        ana_flops_chip=flops_per_chip,
        ana_hbm_chip=hbm_per_chip,
        ana_coll_chip=coll_per_chip,
        model_flops=model,
        t_compute=flops_per_chip / PEAK_FLOPS,
        t_memory=hbm_per_chip / HBM_BW,
        t_collective=coll_per_chip / ICI_BW,
    )


LEVERS = {
    "compute": "compute-bound: raise MFU via causal-block skip / larger "
               "per-chip batch; already near the good regime",
    "memory": "HBM-bound: cut bytes via fused kernels (paged attention), "
              "quantized KV/params, or more TP to shrink per-chip state",
    "collective": "collective-bound: reshard to cut cross-chip traffic "
                  "(less FSDP regather, int8 grad compression, overlap)",
}


def load_cells(mesh: str = "single"):
    cells = []
    d = DRY / mesh
    if not d.exists():
        return cells
    for f in sorted(d.glob("*.json")):
        rec = json.loads(f.read_text())
        cells.append(rec)
    return cells


def list_variant_dirs():
    if not DRY.exists():
        return []
    return sorted(p.name for p in DRY.iterdir()
                  if p.is_dir() and "-" in p.name)


def build_table(mesh: str = "single"):
    rows = []
    for rec in load_cells(mesh):
        if rec.get("status") == "skipped":
            rows.append(dict(arch=rec["arch"], shape=rec["shape"],
                             status="skipped", reason=rec["reason"]))
            continue
        if rec.get("status") != "ok":
            rows.append(dict(arch=rec["arch"], shape=rec["shape"],
                             status="failed", reason=rec.get("error", "")))
            continue
        ana = analytic_terms(rec)
        coll_hlo = sum(v["traffic"] for v in
                       rec.get("collectives", {}).values())
        hlo_flops = rec["cost"]["flops"]
        hlo_bytes = rec["cost"]["bytes_accessed"]
        terms = {"compute": ana["t_compute"], "memory": ana["t_memory"],
                 "collective": ana["t_collective"]}
        dom = max(terms, key=terms.get)
        bound = max(terms.values())
        useful = ana["model_flops"] / max(ana["ana_flops_chip"]
                                          * rec["n_chips"], 1.0)
        # roofline fraction: ideal model-compute time / achievable step time
        t_model = ana["model_flops"] / (rec["n_chips"] * PEAK_FLOPS)
        frac = t_model / max(sum(terms.values()), 1e-12)
        rows.append(dict(
            arch=rec["arch"], shape=rec["shape"], status="ok",
            peak_gib=rec["memory"]["peak_est_bytes"] / (1 << 30),
            t_compute=terms["compute"], t_memory=terms["memory"],
            t_collective=terms["collective"], dominant=dom,
            roofline_frac=frac, useful_ratio=useful,
            hlo_flops_chip=hlo_flops, hlo_bytes_chip=hlo_bytes,
            hlo_coll_chip=coll_hlo,
            t_hlo_compute=hlo_flops / PEAK_FLOPS,
            t_hlo_memory=hlo_bytes / HBM_BW,
            t_hlo_collective=coll_hlo / ICI_BW,
            lever=LEVERS[dom],
        ))
    return rows


def to_markdown(rows, mesh: str) -> str:
    out = [f"### Roofline — {mesh} mesh",
           "",
           "| arch | shape | peak GiB | t_comp (ms) | t_mem (ms) | "
           "t_coll (ms) | dominant | roofline frac | MODEL/impl FLOPs | "
           "HLO t_comp/t_mem/t_coll (ms, raw) |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                       f"{r['status']}: {r['reason'][:60]} | — | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['peak_gib']:.1f} | "
            f"{1e3 * r['t_compute']:.2f} | {1e3 * r['t_memory']:.2f} | "
            f"{1e3 * r['t_collective']:.2f} | **{r['dominant']}** | "
            f"{r['roofline_frac']:.2f} | {r['useful_ratio']:.2f} | "
            f"{1e3 * r['t_hlo_compute']:.2f}/{1e3 * r['t_hlo_memory']:.2f}/"
            f"{1e3 * r['t_hlo_collective']:.2f} |")
    return "\n".join(out)


def main(quick: bool = False):
    from . import common
    all_rows = {}
    for mesh in ("single", "multipod", *list_variant_dirs()):
        rows = build_table(mesh)
        if not rows:
            continue
        all_rows[mesh] = rows
        md = to_markdown(rows, mesh)
        (ART / f"roofline_{mesh}.md").write_text(md)
        for r in rows:
            if r["status"] == "ok":
                print(f"roofline/{mesh}/{r['arch']}/{r['shape']},0.00,"
                      f"dom={r['dominant']};frac={r['roofline_frac']:.2f};"
                      f"peakGiB={r['peak_gib']:.1f}", flush=True)
    (ART / "roofline.json").write_text(
        json.dumps(all_rows, indent=1, default=str))
    # BenchRecord: a summary payload (the full tables stay in
    # artifacts/roofline.json — row dicts carry status strings)
    summary = {mesh: {"rows": len(rows),
                      "ok": sum(1 for r in rows if r["status"] == "ok")}
               for mesh, rows in all_rows.items()}
    common.emit_record("roofline", {"meshes": summary}, quick=quick)
    return all_rows


if __name__ == "__main__":
    main()
