"""Simulation-service throughput: batched broker vs naive per-query runs.

The serving scenario the broker exists for: a burst of concurrent
*independent* what-if queries — many small workload scenarios x policy
bundles — hits the service at once.  Naive execution answers them one
``TieredMemSimulator.run`` at a time (warm: the sequential facade already
shares one compile across policies); the broker buckets them by shape and
answers the whole burst as one 64-lane ``sweep_lanes`` program.

Measured (warm, steady-state) on the benchmark machine and tracked in
``artifacts/bench/service_throughput.json``:

  * ``speedup`` — broker queries/sec over naive queries/sec at 64
    concurrent queries (acceptance bar: >= 3x);
  * ``cached`` — replaying the identical burst against the content-
    addressed result cache (zero device work, zero recompiles);
  * broker stats (flushes, lanes, pad lanes, compiles).

Quick mode is the CI smoke: a small bucket of 2 lanes, artifact only (no
bar — CI runners are too noisy for a throughput gate).

``chaos_main`` (CLI: ``--chaos``) is the resilience variant: the same
burst traffic under a seeded fault plan — one guaranteed transient
device failure plus a 1% background failure rate on ``sweep.device`` —
through a retrying broker.  It gates on *zero stranded futures* (every
future resolves or fails with a typed ServiceError), on the broker
ending non-degraded, and on the retry path actually having fired;
results land in ``artifacts/bench/chaos.json`` (committed — CI diffs
the gate fields).
"""
from __future__ import annotations

import json
import time

from . import common
from repro.core import (CostConfig, MachineConfig, PolicyConfig,
                        TieredMemSimulator, TraceSpec, sweep_compile_count,
                        FIRST_TOUCH, INTERLEAVE, PT_BIND_ALL, PT_BIND_HIGH,
                        PT_FOLLOW_DATA)
from repro.obs import FlightRecorder, validate_postmortem
from repro.obs.inject import FaultInjector, fail_lane, fail_once, fail_rate
from repro.service import ResilienceConfig, ServiceError, SimBroker, SimQuery

SERVICE_WORKLOADS = ("memcached", "xsbench", "btree", "bfs")


def service_machine() -> MachineConfig:
    """The what-if query box: small enough that one scenario simulates in
    well under a second — service traffic is many small questions, not one
    figure-scale run."""
    return MachineConfig(n_threads=4, dram_pages_per_node=300,
                         nvmm_pages_per_node=1200, va_pages=1 << 11,
                         l1_tlb_sets=4, l1_tlb_ways=2, stlb_sets=8,
                         stlb_ways=4, pde_pwc_entries=4, pdpte_pwc_entries=2)


def burst_queries(mc: MachineConfig, n_specs: int, policies,
                  footprint: int = 64, run_steps: int = 80,
                  seed0: int = 100):
    """n_specs workload scenarios x len(policies) bundles, all landing in
    one shape bucket (specs pad to a shared power-of-two step count)."""
    specs = [TraceSpec(workload=SERVICE_WORKLOADS[i % len(SERVICE_WORKLOADS)],
                       footprint=footprint, run_steps=run_steps,
                       seed=seed0 + i)
             for i in range(n_specs)]
    return [SimQuery(trace=spec, policy=pc, machine=mc)
            for spec in specs for pc in policies]


def four_policies():
    return [PolicyConfig(data_policy=d, pt_policy=p, autonuma=False)
            for d in (FIRST_TOUCH, INTERLEAVE)
            for p in (PT_FOLLOW_DATA, PT_BIND_HIGH)]


REPS = 3          # best-of-N wall clock (single runs are scheduler-noisy)


def run_naive(queries, canonical, reps=1):
    best, out = float("inf"), None
    for _ in range(reps):
        t0 = time.time()
        out = [TieredMemSimulator(mc=q.machine, cc=q.cost,
                                  pc=q.policy).run(tr)
               for q, tr in zip(queries, canonical)]
        best = min(best, time.time() - t0)
    return out, best


def main(quick: bool = False):
    mc = service_machine()
    policies = four_policies()
    if quick:                      # CI smoke: small bucket, 2 lanes
        queries = burst_queries(mc, 1, policies[:2], footprint=64,
                                run_steps=56)
        max_lanes = 2
    else:
        queries = burst_queries(mc, 16, policies)      # 64 queries
        max_lanes = 64
    n = len(queries)

    tel = common.telemetry()
    broker = SimBroker(max_lanes=max_lanes, lane_sharding="auto",
                       telemetry=tel)
    canonical = [broker.canonical_trace(q) for q in queries]

    # warm both paths: compiles + fault-schedule host passes out of the
    # measurement (steady-state serving is the claim)
    run_naive(queries[:1], canonical[:1])
    broker.run(queries)
    broker.cache.clear()

    reps = 1 if quick else REPS
    naive_res, naive_s = run_naive(queries, canonical, reps=reps)

    broker_s, broker_res, stats = float("inf"), None, None
    for _ in range(reps):
        broker.cache.clear()
        stats0 = broker.stats.as_dict()
        t0 = time.time()
        broker_res = broker.run(queries)
        secs = time.time() - t0
        if secs < broker_s:
            broker_s = secs
            stats = {k: v - stats0[k]
                     for k, v in broker.stats.as_dict().items()}
            # the ratio is not delta-able; recompute it over the window
            stats["pad_ratio"] = (stats["pad_lanes"]
                                  / max(stats["pad_lanes"]
                                        + stats["lanes_run"], 1))

    compiles_before = sweep_compile_count()
    t0 = time.time()
    cached_res = broker.run(queries)
    cached_s = time.time() - t0
    cached_recompiles = sweep_compile_count() - compiles_before

    # the broker — and its cache — must answer exactly what naive answers
    for a, b in zip(naive_res * 2, broker_res + cached_res, strict=True):
        assert a.summary()["faults"] == b.summary()["faults"]

    speedup = (n / broker_s) / (n / naive_s)
    results = {
        "n_queries": n,
        "machine": {"n_threads": mc.n_threads, "va_pages": mc.va_pages},
        "trace_steps": canonical[0].n_steps,
        "naive": {"seconds": naive_s, "qps": n / naive_s},
        "broker": {"seconds": broker_s, "qps": n / broker_s,
                   "speedup": speedup},
        "cached": {"seconds": cached_s, "qps": n / cached_s,
                   "recompiles": cached_recompiles,
                   "speedup_vs_naive": naive_s / cached_s},
        "broker_stats": stats,       # measured-run delta (warm-up excluded)
        # end-to-end observability over the whole driver run (warm-up,
        # measured reps and cached replay): lifecycle histograms, per-
        # bucket compile counters, cache + migration totals
        "snapshot": broker.snapshot(),
    }
    common.ART.mkdir(parents=True, exist_ok=True)
    trace_path = common.ART / "service_trace.json"
    if tel.export_trace(trace_path):
        results["trace_file"] = str(trace_path)
    rows = [
        (f"service_throughput/naive/{n}q", naive_s, f"qps={n / naive_s:.1f}"),
        (f"service_throughput/broker/{n}q", broker_s,
         f"qps={n / broker_s:.1f};speedup={speedup:.2f}x;"
         f"flushes={stats['flushes']};compiles={stats['compiles']}"),
        (f"service_throughput/cached/{n}q", cached_s,
         f"qps={n / cached_s:.1f};recompiles={cached_recompiles}"),
    ]
    common.emit(rows)
    common.emit_record("service_throughput", results, rows=rows, quick=quick)
    return results


def chaos_main(quick: bool = False):
    """Chaos mode: burst traffic under a seeded fault plan — one
    guaranteed transient hiccup, a 1% background device-fault rate, and
    one *persistently poisoned lane* that the broker must bisect out,
    quarantine, and document with a flight-recorder postmortem.

    The gates are liveness and observability, not speed: every future
    terminates (result or typed error), nothing is stranded or leaked,
    the broker ends non-degraded, the bounded-retry path demonstrably
    fired, and the confirmed poison produced a schema-valid postmortem
    artifact under ``artifacts/postmortem/``.
    """
    mc = service_machine()
    policies = four_policies()
    n_bursts = 2 if quick else 6
    tel = common.telemetry()
    injector = FaultInjector([
        fail_once("sweep.device"),                  # guaranteed hiccup
        fail_rate("sweep.device", 0.01, seed=42),   # 1% background rate
    ])
    flight = FlightRecorder(tel, common.ART.parent / "postmortem")
    broker = SimBroker(
        max_lanes=4 if quick else 64, lane_sharding="auto", telemetry=tel,
        injector=injector, flight=flight,
        resilience=ResilienceConfig(max_retries=3, backoff_base=0.005))

    def burst(b: int):
        if quick:
            return burst_queries(mc, 2, policies[:2], run_steps=56,
                                 seed0=1000 * (b + 1))
        return burst_queries(mc, 16, policies, seed0=1000 * (b + 1))

    # the seeded poison: burst 0's first lane fails *persistently*
    # (transient=False — no retry escape), forcing the full isolation
    # path: bisection -> solo failure -> quarantine -> postmortem dump
    poison_digest = broker.query_digest(burst(0)[0])
    injector.add(fail_lane("sweep.device", poison_digest, transient=False))

    t0 = time.time()
    futs = []
    for b in range(n_bursts):           # fresh trace content every burst
        futs += broker.submit_many(burst(b))
        broker.drain()
    secs = time.time() - t0
    n = len(futs)

    stranded = [f for f in futs if not f.done()]
    assert not stranded, f"{len(stranded)} stranded futures under chaos"
    failed: dict = {}
    resolved = 0
    for f in futs:
        try:
            f.result()
            resolved += 1
        except ServiceError as e:       # typed failure: the contract
            failed[type(e).__name__] = failed.get(type(e).__name__, 0) + 1
    assert broker.pending_lanes() == 0 and not broker._fut_index, \
        "broker leaked pending state after drain"
    assert not broker.degraded_buckets(), \
        "broker still degraded after fault-free drain"
    assert broker.stats.retries >= 1, \
        "fault plan never exercised the retry path"
    assert failed.get("PoisonedQueryError", 0) >= 1, \
        f"seeded poison lane never confirmed: {failed}"

    # the poison's postmortem: at least one dump, schema-valid, carrying
    # recent spans, a metrics delta, and the quarantined lane digest
    assert flight.dumps, "no postmortem produced for the poisoned lane"
    pm = json.loads(flight.dumps[0].read_text())
    pm_problems = validate_postmortem(pm)
    assert not pm_problems, f"postmortem schema problems: {pm_problems}"
    assert len(pm["spans"]) >= 1, "postmortem carries no spans"
    assert pm["metrics_delta"], "postmortem carries no metrics delta"
    assert poison_digest in pm["state"].get("quarantine", []), \
        "postmortem state is missing the quarantined digest"

    results = {
        "n_queries": n, "bursts": n_bursts, "seconds": secs,
        "qps": n / secs,
        "gates": {"stranded": len(stranded), "resolved": resolved,
                  "typed_failures": failed,
                  "degraded_buckets": broker.degraded_buckets(),
                  "degraded": len(broker.degraded_buckets()),
                  "retries": broker.stats.retries,
                  "quarantined": broker.stats.quarantined,
                  "postmortems": len(flight.dumps)},
        "poison_digest": poison_digest,
        "postmortems": [str(p) for p in flight.dumps],
        "faults": injector.stats(),
        "snapshot": broker.snapshot(),
    }
    rows = [(f"service_chaos/{n}q", secs,
             f"qps={n / secs:.1f};retries={broker.stats.retries};"
             f"injected={results['faults']['total_injected']};"
             f"postmortems={len(flight.dumps)};stranded=0")]
    common.emit(rows)
    common.emit_record("chaos", results, rows=rows, quick=quick)
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--chaos", action="store_true",
                    help="run the fault-injection variant (chaos.json)")
    args = ap.parse_args()
    (chaos_main if args.chaos else main)(quick=args.quick)
