"""Benchmark entry point: one module per paper table/figure + the Pillar-B
serving benchmark + the roofline table + the service-layer drivers.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig9,fig10]

Prints ``name,seconds,derived`` CSV rows (as the harness skeleton asks) and
writes JSON artifacts under artifacts/bench/.  ``--help`` lists every
registered figure; an unknown ``--only`` target is an error, not a silent
no-op.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

# name -> (module basename[:entry function], one-line description); import
# is deferred so --help and --only validation stay instant.  The entry
# function defaults to ``main`` and takes ``quick: bool``.
FIGURES = {
    "fig1": ("fig1_startup", "startup/populate-phase cost breakdown"),
    "fig5": ("fig5_ptdist", "PT-page NUMA distribution"),
    "fig6": ("fig6_walklat", "page-walk latency by PT placement"),
    "fig7": ("fig7_bind", "bind-all OOM pathology vs BHi"),
    "fig9": ("fig9_fullsystem", "full-system policy comparison"),
    "fig10": ("fig10_multitenant", "multi-tenant fill-and-free scenario"),
    "fig11": ("fig11_interleave", "interleaved data placement"),
    "fig13": ("fig13_thp", "transparent huge pages"),
    "table4": ("table4_summary", "headline geomean summary vs paper"),
    "kv_tiering": ("kv_tiering", "tiered paged-KV serving benchmark"),
    "roofline": ("roofline", "roofline over dry-run artifacts"),
    "fault_batch": ("fault_batch", "batched fault-engine micro-benchmark"),
    "steady_state": ("steady_state",
                     "time-blocked steady-state stepper micro-benchmark"),
    "cost_sweep": ("cost_sweep", "CXL what-if NVMM latency-ratio sweep"),
    "scenario_matrix": ("cost_sweep:scenario_main",
                        "policy family x tier topology x latency ratio x "
                        "workload matrix through the broker"),
    "service_throughput": ("service_throughput",
                           "query-broker throughput vs naive execution"),
    "service_chaos": ("service_throughput:chaos_main",
                      "broker under a 1% injected device-fault rate; "
                      "gates on zero stranded futures"),
}


def main() -> None:
    figure_list = "\n".join(f"  {n:<20} {d}"
                            for n, (_, d) in FIGURES.items())
    ap = argparse.ArgumentParser(
        formatter_class=argparse.RawDescriptionHelpFormatter,
        epilog=f"registered figures:\n{figure_list}")
    ap.add_argument("--quick", action="store_true",
                    help="2 workloads, short traces (CI-scale)")
    ap.add_argument("--only", default=None,
                    help="comma-separated figure subset, e.g. fig9,table4 "
                         "(see the registered list below)")
    ap.add_argument("--verbose", action="store_true",
                    help="print each driver's telemetry snapshot (metrics "
                         "registry + trace counts) after it finishes")
    args = ap.parse_args()

    names = list(FIGURES)
    if args.only:
        names = [n.strip() for n in args.only.split(",") if n.strip()]
        unknown = [n for n in names if n not in FIGURES]
        if unknown:
            ap.error(f"unknown --only target(s) {', '.join(unknown)}; "
                     f"registered: {', '.join(FIGURES)}")

    import importlib
    import json

    from . import common

    print("name,seconds,derived", flush=True)
    failures = []
    drivers: dict = {}
    suite_t0 = time.time()
    for name in names:
        target = FIGURES[name][0]
        modname, _, func = target.partition(":")
        mod = importlib.import_module(f"benchmarks.{modname}")
        # scope the shared telemetry to this driver so --verbose (and any
        # snapshot the driver embeds) reads one driver's worth of data
        common.telemetry().reset()
        common.begin_driver(name)
        t0 = time.time()
        try:
            getattr(mod, func or "main")(quick=args.quick)
            drivers[name] = {"seconds": time.time() - t0, "status": "ok"}
            print(f"{name}/done,{time.time() - t0:.1f},ok", flush=True)
        except Exception as e:  # noqa: BLE001 — report, keep going
            failures.append(name)
            traceback.print_exc()
            drivers[name] = {"seconds": time.time() - t0,
                             "status": "failed",
                             "error": f"{type(e).__name__}: {e}"}
            print(f"{name}/done,{time.time() - t0:.1f},"
                  f"FAILED:{type(e).__name__}", flush=True)
        if args.verbose:
            snap = common.telemetry().snapshot()
            print(f"# telemetry[{name}] "
                  f"{json.dumps(snap, sort_keys=True, default=float)}",
                  flush=True)
    ok = len(names) - len(failures)
    # the per-invocation run manifest: which drivers ran under which run
    # id, each one's wall clock and exit status, and the failure summary
    manifest = {
        "schema": "run-manifest/v1",
        "run_id": common.run_id(),
        "quick": bool(args.quick),
        "only": names,
        "started": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                 time.gmtime(suite_t0)),
        "wall_seconds": time.time() - suite_t0,
        "drivers": drivers,
        "failures": failures,
    }
    common.ART.mkdir(parents=True, exist_ok=True)
    (common.ART / "run_manifest.json").write_text(
        json.dumps(manifest, indent=1, default=float))
    print(f"# summary: {ok}/{len(names)} drivers ok"
          + (f"; FAILED: {', '.join(failures)}" if failures else ""),
          flush=True)
    if failures:
        print(f"benchmark drivers failed: {', '.join(failures)}",
              file=sys.stderr, flush=True)
        sys.exit(1)


if __name__ == "__main__":
    main()
