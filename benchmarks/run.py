"""Benchmark entry point: one module per paper table/figure + the Pillar-B
serving benchmark + the roofline table.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig9,fig10]

Prints ``name,seconds,derived`` CSV rows (as the harness skeleton asks) and
writes JSON artifacts under artifacts/bench/.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="2 workloads, short traces (CI-scale)")
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset, e.g. fig9,table4")
    args = ap.parse_args()

    from . import (fault_batch, fig1_startup, fig5_ptdist, fig6_walklat,
                   fig7_bind, fig9_fullsystem, fig10_multitenant,
                   fig11_interleave, fig13_thp, kv_tiering, roofline,
                   table4_summary)

    modules = [
        ("fig1", fig1_startup), ("fig5", fig5_ptdist),
        ("fig6", fig6_walklat), ("fig7", fig7_bind),
        ("fig9", fig9_fullsystem), ("fig10", fig10_multitenant),
        ("fig11", fig11_interleave), ("fig13", fig13_thp),
        ("table4", table4_summary), ("kv_tiering", kv_tiering),
        ("roofline", roofline), ("fault_batch", fault_batch),
    ]
    if args.only:
        keep = set(args.only.split(","))
        modules = [(n, m) for n, m in modules if n in keep]

    print("name,seconds,derived", flush=True)
    failures = []
    for name, mod in modules:
        t0 = time.time()
        try:
            mod.main(quick=args.quick)
            print(f"{name}/done,{time.time() - t0:.1f},ok", flush=True)
        except Exception as e:  # noqa: BLE001 — report, keep going
            failures.append(name)
            traceback.print_exc()
            print(f"{name}/done,{time.time() - t0:.1f},"
                  f"FAILED:{type(e).__name__}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
