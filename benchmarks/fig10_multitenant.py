"""Paper Fig. 10 + Table 5: the multi-tenant (cloud) scenario.

Fill apps occupy DRAM; the benchmark app lands on NVMM; the fill apps then
exit and AutoNUMA promotes the benchmark's hot data — but only Radiant
(Mig) brings the leaf PT pages back to DRAM.  Also emits the Table-5
migration/skip accounting.
"""
from __future__ import annotations

from . import common
from repro.core import benchmark_machine, bhi_mig, linux_default, pad_trace, workloads


def main(quick: bool = False):
    mc = benchmark_machine()
    steps = common.QUICK_RUN_STEPS if quick else common.RUN_STEPS
    names = common.WORKLOADS[:2] if quick else common.WORKLOADS_SMALL
    traces = {n: workloads.multi_tenant(mc, n, 1 << 17, steps)
              for n in names}
    pad = max(t.n_steps for t in traces.values())
    traces = {k: pad_trace(t, pad) for k, t in traces.items()}

    policies = [("autonuma", linux_default()), ("BHi+Mig", bhi_mig())]
    # multi-tenant traces carry per-trace segment maps; the sweep engine
    # batches those per lane alongside the policies
    grid, secs = common.run_sweep(mc, [pc for _, pc in policies],
                                  list(traces.values()))
    results = {}
    rows = []
    for (wname, trace), lane_row in zip(traces.items(), grid):
        base = None
        for (pname, _), res in zip(policies, lane_row):
            m = common.phase_metrics(res, trace)
            if base is None:
                base = m
            imp = {k: common.improvement(base[f"run_{k}_cycles"],
                                         m[f"run_{k}_cycles"])
                   for k in ("total", "walk", "stall")}
            results.setdefault(wname, {})[pname] = {**m, "improv": imp}
            rows.append((f"fig10/{wname}/{pname}", secs,
                         f"total%={imp['total']:.1f};walk%={imp['walk']:.1f};"
                         f"stall%={imp['stall']:.1f}"))
            if pname == "BHi+Mig":
                rows.append((
                    f"table5/{wname}", 0.0,
                    f"data_migs={m['data_migrations']};"
                    f"pte_success={m['l4_mig_success']};"
                    f"already_dest={m['l4_mig_already_dest']};"
                    f"in_dram={m['l4_mig_in_dram']};"
                    f"sibling={m['l4_mig_sibling_guard']};"
                    f"lock_skip={m['l4_mig_lock_skip']}"))
    common.emit(rows)
    for k in ("total", "walk", "stall"):
        g = common.geomean_improvement(
            [results[w]["BHi+Mig"]["improv"][k] for w in results])
        print(f"fig10/geomean/BHi+Mig/{k},0.00,{g:.2f}%", flush=True)
    common.emit_record("fig10_multitenant", results, rows=rows, quick=quick)
    return results


if __name__ == "__main__":
    main()
