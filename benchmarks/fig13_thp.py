"""Paper Fig. 13 + Table 4 row 4: transparent huge pages.

THP maps at the mid level (3-level walks, no PTE pages): BHi effectively
binds the whole table; Mig has nothing to migrate and BHi+Mig == BHi.
AutoNUMA disabled per the paper's setting.
"""
from __future__ import annotations

from . import common
from repro.core import benchmark_machine, bhi, bhi_mig, linux_default


def main(quick: bool = False):
    mc = benchmark_machine(thp=True)
    steps = common.QUICK_RUN_STEPS if quick else common.RUN_STEPS
    names = common.WORKLOADS[:2] if quick else common.WORKLOADS_SMALL
    traces = common.make_traces(mc, steps, names)
    policies = [("thp-base", linux_default(autonuma=False)),
                ("thp-BHi", bhi(autonuma=False)),
                ("thp-BHi+Mig", bhi_mig(autonuma=False))]
    grid, secs = common.run_sweep(mc, [pc for _, pc in policies],
                                  list(traces.values()))
    results, rows = {}, []
    for (wname, trace), lane_row in zip(traces.items(), grid):
        base = None
        for (pname, _), res in zip(policies, lane_row):
            m = common.phase_metrics(res, trace)
            if base is None:
                base = m
            imp = {k: common.improvement(base[f"run_{k}_cycles"],
                                         m[f"run_{k}_cycles"])
                   for k in ("total", "walk", "stall")}
            results.setdefault(wname, {})[pname] = {**m, "improv": imp}
            rows.append((f"fig13/{wname}/{pname}", secs,
                         f"total%={imp['total']:.1f};walk%={imp['walk']:.1f}"))
    common.emit(rows)
    for k in ("total", "walk"):
        g = common.geomean_improvement(
            [results[w]["thp-BHi"]["improv"][k] for w in results])
        print(f"fig13/geomean/BHi/{k},0.00,{g:.2f}%", flush=True)
    common.emit_record("fig13_thp", results, rows=rows, quick=quick)
    return results


if __name__ == "__main__":
    main()
