"""Paper Fig. 5: page-table placement under the interleave policy.

After populating ~70% of the footprint with interleave, PT pages are
spread round-robin over all four nodes even though DRAM has free memory;
BHi keeps the upper levels (and under THP, everything) on DRAM.
"""
from __future__ import annotations

import numpy as np

from . import common
from repro.core import (INTERLEAVE, PT_BIND_HIGH, PT_FOLLOW_DATA,
                        PolicyConfig, benchmark_machine, workloads)


def main(quick: bool = False):
    mc = benchmark_machine()
    tr = workloads.kv_store(mc, int(common.FOOTPRINT * 0.7) // mc.n_threads
                            * mc.n_threads, run_steps=64, name="memcached")
    names_pts = [("interleave", PT_FOLLOW_DATA),
                 ("interleave+BHi", PT_BIND_HIGH)]
    policies = [PolicyConfig(data_policy=INTERLEAVE, pt_policy=pt,
                             autonuma=False) for _, pt in names_pts]
    sweep_res, secs = common.run_sweep(mc, policies, tr)
    results, rows = {}, []
    for (pname, _), res in zip(names_pts, sweep_res):
        st = res.final_state
        leaf = np.asarray(st.leaf_node)
        mid = np.asarray(st.mid_node)
        data = np.asarray(st.data_node)
        dist = {
            "leaf_per_node": [int(np.sum(leaf == n)) for n in range(4)],
            "mid_per_node": [int(np.sum(mid == n)) for n in range(4)],
            "data_per_node": [int(np.sum(data == n)) for n in range(4)],
            "dram_free": int(np.asarray(st.node_free)[:2].sum()),
        }
        results[pname] = dist
        pt_nvmm = sum(dist["leaf_per_node"][2:]) + sum(dist["mid_per_node"][2:])
        pt_all = sum(dist["leaf_per_node"]) + sum(dist["mid_per_node"])
        rows.append((f"fig5/memcached/{pname}", secs,
                     f"pt_on_nvmm={100*pt_nvmm/max(pt_all,1):.0f}%;"
                     f"dram_free_pages={dist['dram_free']}"))
    common.emit(rows)
    common.emit_record("fig5_ptdist", results, rows=rows, quick=quick)
    return results


if __name__ == "__main__":
    main()
