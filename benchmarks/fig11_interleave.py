"""Paper Fig. 11/12 + Table 4 row 3: interleaved allocation, AutoNUMA off.

Interleave spreads data AND page-table pages round-robin over all four
nodes (paper section 3.2/Fig. 5); BHi pulls only the upper PT levels back
to DRAM.  Also reports the Fig. 12 page-walk-latency improvement.
"""
from __future__ import annotations

from . import common
from repro.core import (INTERLEAVE, PT_BIND_HIGH, PT_FOLLOW_DATA,
                        PolicyConfig, benchmark_machine)


def main(quick: bool = False):
    mc = benchmark_machine()
    steps = common.QUICK_RUN_STEPS if quick else common.RUN_STEPS
    names = common.WORKLOADS[:2] if quick else common.WORKLOADS_SMALL
    traces = common.make_traces(mc, steps, names)
    policies = [
        ("interleave", PolicyConfig(data_policy=INTERLEAVE,
                                    pt_policy=PT_FOLLOW_DATA, autonuma=False)),
        ("interleave+BHi", PolicyConfig(data_policy=INTERLEAVE,
                                        pt_policy=PT_BIND_HIGH,
                                        autonuma=False)),
    ]
    grid, secs = common.run_sweep(mc, [pc for _, pc in policies],
                                  list(traces.values()))
    results, rows = {}, []
    for (wname, trace), lane_row in zip(traces.items(), grid):
        base = None
        for (pname, _), res in zip(policies, lane_row):
            m = common.phase_metrics(res, trace)
            if base is None:
                base = m
            imp = {k: common.improvement(base[f"run_{k}_cycles"],
                                         m[f"run_{k}_cycles"])
                   for k in ("total", "walk", "stall")}
            # Fig. 12: average page-walk latency in the run phase
            walk_lat = m["run_walk_cycles"] / max(m["run_walks"], 1)
            results.setdefault(wname, {})[pname] = {**m, "improv": imp,
                                                    "walk_lat": walk_lat}
            rows.append((f"fig11/{wname}/{pname}", secs,
                         f"total%={imp['total']:.1f};walk%={imp['walk']:.1f};"
                         f"walk_lat={walk_lat:.0f}cy"))
    common.emit(rows)
    for k in ("total", "walk", "stall"):
        g = common.geomean_improvement(
            [results[w]["interleave+BHi"]["improv"][k] for w in results])
        print(f"fig11/geomean/BHi/{k},0.00,{g:.2f}%", flush=True)
    common.emit_record("fig11_interleave", results, rows=rows, quick=quick)
    return results


if __name__ == "__main__":
    main()
