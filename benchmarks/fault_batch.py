"""Populate-phase fault-engine micro-benchmark (perf-trajectory tracker).

Measures warm steps/sec of the batched conflict-aware phase B against the
retained sequential ``fori_loop`` reference, on a fault-dominated
(populate) trace and a steady-state control, at 1 lane (the plain
``TieredMemSimulator`` path) and an 8-lane vmapped policy sweep — the
configuration where the old per-thread ``lax.cond`` lowered to a select
and cost ~1.5x per lane.  Writes ``artifacts/bench/fault_batch.json`` so
the populate-phase perf trajectory is tracked from PR 3 onward; the
acceptance bar is >= 1.3x on the 8-lane populate sweep with the
steady-state control at parity or better.
"""
from __future__ import annotations

import time

from . import common
from repro.core import (CostConfig, PolicyConfig, TieredMemSimulator, sweep,
                        benchmark_machine, workloads, FIRST_TOUCH,
                        INTERLEAVE, PT_BIND_ALL, PT_BIND_HIGH,
                        PT_FOLLOW_DATA)


def eight_policies():
    pols = [PolicyConfig(data_policy=d, pt_policy=p, autonuma=False)
            for d in (FIRST_TOUCH, INTERLEAVE)
            for p in (PT_FOLLOW_DATA, PT_BIND_ALL, PT_BIND_HIGH)]
    pols += [PolicyConfig(data_policy=d, pt_policy=PT_BIND_HIGH, mig=True,
                          autonuma=False) for d in (FIRST_TOUCH, INTERLEAVE)]
    return pols


def _timed(fn):
    fn()                       # compile + warm (schedule host pass cached)
    t0 = time.time()
    fn()
    return time.time() - t0


def bench_trace(mc, tr, pols, cc):
    tel = common.telemetry()
    out = {"steps": tr.n_steps, "populate_steps": tr.populate_steps}
    for lanes, label in ((1, "1lane"), (len(pols), f"{len(pols)}lane")):
        row = {}
        for mode in ("sequential", "batched"):
            if lanes == 1:
                sim = TieredMemSimulator(mc=mc, cc=cc, pc=pols[0],
                                         phase_b=mode, debug=True,
                                         telemetry=tel)
                secs = _timed(lambda: sim.run(tr))
            else:
                secs = _timed(lambda: sweep(mc, cc, pols, tr, phase_b=mode,
                                            debug=True, telemetry=tel))
            row[mode] = {"seconds": secs,
                         "lane_steps_per_sec": tr.n_steps * lanes / secs}
        row["speedup"] = (row["batched"]["lane_steps_per_sec"]
                          / row["sequential"]["lane_steps_per_sec"])
        out[label] = row
    return out


def main(quick: bool = False):
    mc = benchmark_machine()
    cc = CostConfig()
    pols = eight_policies()
    pop_fp = 1 << 12 if quick else 1 << 14
    steady_steps = 512 if quick else 2048

    # fault-dominated: sequential heap growth, nearly every step faults
    tr_pop = workloads.kv_store(mc, pop_fp, run_steps=64, seed=10,
                                name="populate")
    # steady-state control: short populate, long zipfian run phase
    tr_run = workloads.kv_store(mc, 1 << 12, run_steps=steady_steps,
                                seed=10, name="steady")

    results = {"populate": bench_trace(mc, tr_pop, pols, cc),
               "steady": bench_trace(mc, tr_run, pols, cc)}
    rows = []
    for phase in ("populate", "steady"):
        for label in ("1lane", f"{len(pols)}lane"):
            r = results[phase][label]
            rows.append((
                f"fault_batch/{phase}/{label}",
                r["batched"]["seconds"],
                f"speedup={r['speedup']:.2f}x;"
                f"batched_sps={r['batched']['lane_steps_per_sec']:.0f};"
                f"sequential_sps={r['sequential']['lane_steps_per_sec']:.0f}"))
    common.emit(rows)
    results["telemetry"] = common.telemetry().snapshot()
    common.emit_record("fault_batch", results, rows=rows, quick=quick)
    return results


if __name__ == "__main__":
    main()
