"""Paper Fig. 6: page-walk latency over time under first-touch.

Walk latency jumps when PT allocation spills to NVMM (DRAM full); the
timeline shows per-window average walk cycles.
"""
from __future__ import annotations

import numpy as np

from . import common
from repro.core import benchmark_machine, bhi_mig, linux_default, workloads


def main(quick: bool = False):
    mc = benchmark_machine()
    tr = workloads.kv_store(mc, common.FOOTPRINT, run_steps=4096,
                            seed=10, name="redis")
    pairs = [("first-touch", linux_default()),
             ("Radiant(BHi+Mig)", bhi_mig())]
    sweep_res, secs = common.run_sweep(mc, [pc for _, pc in pairs], tr)
    results, rows = {}, []
    for (pname, _), res in zip(pairs, sweep_res):
        tl = res.timeline
        win = 256
        wc = np.diff(tl["walk_cycles"][::win])
        wn = np.maximum(np.diff(tl["walks"][::win]), 1)
        lat = (wc / wn)
        results[pname] = {"walk_latency_curve": lat.tolist()}
        rows.append((f"fig6/redis/{pname}", secs,
                     f"start_lat={lat[1]:.0f}cy;end_lat={lat[-1]:.0f}cy;"
                     f"peak_lat={lat.max():.0f}cy"))
    common.emit(rows)
    common.emit_record("fig6_walklat", results, rows=rows, quick=quick)
    return results


if __name__ == "__main__":
    main()
