"""Paper Fig. 1 + section 6.5: application startup (populate) time.

Populating a Redis-like store: once DRAM fills, the default kernel
allocates PT pages on NVMM; Radiant keeps the upper levels in DRAM.
AutoNUMA disabled per the paper.  Emits the cumulative-cycles timeline
(the Fig. 1 curve) and the startup improvement.
"""
from __future__ import annotations

import numpy as np

from . import common
from repro.core import benchmark_machine, bhi, bhi_mig, linux_default, workloads


def main(quick: bool = False):
    mc = benchmark_machine()
    tr = workloads.kv_store(mc, common.FOOTPRINT,
                            run_steps=64, seed=10, name="redis")
    results, rows = {}, []
    base = None
    policies = [("first-touch", linux_default(autonuma=False)),
                ("BHi", bhi(autonuma=False)),
                ("BHi+Mig", bhi_mig(autonuma=False))]
    # all three policies share one compiled artifact (the step is
    # policy-generic): one throwaway run hoists the XLA compile out of
    # every timed lane so sim_steps_per_sec is warm and comparable
    common.run(mc, policies[0][1], tr)
    for pname, pc in policies:
        res, secs = common.run(mc, pc, tr)
        m = common.phase_metrics(res, tr)
        if base is None:
            base = m
        imp = common.improvement(base["startup_total_cycles"],
                                 m["startup_total_cycles"])
        walk_imp = common.improvement(base["startup_walk_cycles"],
                                      m["startup_walk_cycles"])
        tl = res.timeline["total_cycles"][:tr.populate_steps]
        # populate phase is fault-dominated: this figure is the 1-lane
        # wall-clock probe of the batched fault engine (fault_batch.py
        # tracks the multi-lane sweep trajectory)
        sim_sps = tr.n_steps / max(secs, 1e-9)
        results[pname] = {
            "startup_total": m["startup_total_cycles"],
            "startup_walk": m["startup_walk_cycles"],
            "improv": imp, "walk_improv": walk_imp,
            "sim_steps_per_sec": sim_sps,
            "curve": np.asarray(tl[::max(len(tl) // 128, 1)]).tolist(),
        }
        rows.append((f"fig1/redis-populate/{pname}", secs,
                     f"startup%={imp:.1f};walk%={walk_imp:.1f};"
                     f"sim_sps={sim_sps:.0f}"))
    common.emit(rows)
    common.emit_record("fig1_startup", results, rows=rows, quick=quick)
    return results


if __name__ == "__main__":
    main()
