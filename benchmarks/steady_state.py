"""Steady-state stepper micro-benchmark (perf-trajectory tracker).

The paper's point — and the ROADMAP's standing ~2x item — is that the
*steady-state* hot path (TLB lookups and page walks, no faults) dominates
big-memory workloads.  This driver measures warm steps/sec of the
time-blocked engine (``engine="blocked"``: event-free step windows run as
one scan step, see ``core/sim.py``) against the retained per-step
reference, on a steady-state-dominated trace at 1 lane and an 8-lane
vmapped policy sweep, plus an AutoNUMA-cadence variant (a scan tick every
``autonuma_period`` steps turns one window in ``period/block`` into an
event window — the realistic lower bound on the win).  Writes
``artifacts/bench/steady_state.json``; the acceptance bar is >= 2x on the
8-lane steady-state sweep (measured ~6-7x on the benchmark machine, ~2x
with the AutoNUMA cadence on), and both engines stay bit-identical
(``tests/test_blocked.py``).
"""
from __future__ import annotations

import dataclasses

from . import common
from .fault_batch import _timed, eight_policies
from repro.core import (CostConfig, TieredMemSimulator, sweep,
                        benchmark_machine, workloads)


def autonuma_policies():
    return [dataclasses.replace(p, autonuma=True, autonuma_period=512,
                                autonuma_budget=256)
            for p in eight_policies()]


def bench_trace(mc, tr, pols, cc):
    tel = common.telemetry()
    out = {"steps": tr.n_steps, "populate_steps": tr.populate_steps}
    for lanes, label in ((1, "1lane"), (len(pols), f"{len(pols)}lane")):
        row = {}
        for engine in ("per_step", "blocked"):
            if lanes == 1:
                sim = TieredMemSimulator(mc=mc, cc=cc, pc=pols[0],
                                         engine=engine, debug=True,
                                         telemetry=tel)
                secs = _timed(lambda: sim.run(tr))
            else:
                secs = _timed(lambda: sweep(mc, cc, pols, tr, engine=engine,
                                            debug=True, telemetry=tel))
            row[engine] = {"seconds": secs,
                           "lane_steps_per_sec": tr.n_steps * lanes / secs}
        row["speedup"] = (row["blocked"]["lane_steps_per_sec"]
                          / row["per_step"]["lane_steps_per_sec"])
        out[label] = row
    return out


def main(quick: bool = False):
    mc = benchmark_machine()
    cc = CostConfig()
    pols = eight_policies()
    steady_steps = 1024 if quick else 2048

    # steady-state: short populate, long zipfian run phase, no scan ticks
    tr_run = workloads.kv_store(mc, 1 << 12, run_steps=steady_steps,
                                seed=10, name="steady")

    results = {"steady": bench_trace(mc, tr_run, pols, cc)}
    if not quick:
        # the same trace under an AutoNUMA cadence: one event window per
        # period/block — the realistic lower bound on the blocked win
        results["steady_autonuma"] = bench_trace(mc, tr_run,
                                                 autonuma_policies(), cc)

    rows = []
    for phase, res in results.items():
        for label in ("1lane", f"{len(pols)}lane"):
            r = res[label]
            rows.append((
                f"steady_state/{phase}/{label}",
                r["blocked"]["seconds"],
                f"speedup={r['speedup']:.2f}x;"
                f"blocked_sps={r['blocked']['lane_steps_per_sec']:.0f};"
                f"per_step_sps={r['per_step']['lane_steps_per_sec']:.0f}"))
    common.emit(rows)
    # fast-vs-event window classification + device-time histograms for
    # the measured runs, alongside the headline numbers
    results["telemetry"] = common.telemetry().snapshot()
    common.emit_record("steady_state", results, rows=rows, quick=quick)
    return results


if __name__ == "__main__":
    main()
