"""Steady-state stepper micro-benchmark (perf-trajectory tracker).

The paper's point — and the ROADMAP's standing ~2x item — is that the
*steady-state* hot path (TLB lookups and page walks, no faults) dominates
big-memory workloads.  This driver measures warm steps/sec of the
time-blocked engine (``engine="blocked"``: event-free step windows run as
one scan step, see ``core/sim.py``) against the retained per-step
reference, on a steady-state-dominated trace at 1 lane and an 8-lane
vmapped policy sweep, plus an AutoNUMA-cadence figure row sweeping
``autonuma_period`` in {128, 512, 2048} at the default block of 64.  A
scan tick used to turn its whole window into a per-step replay, halving
the blocked win at period=512; the planner now hoists a lone tick out of
the window body (``core/sim.py``), so the win should stay nearly
cadence-independent.  Writes ``artifacts/bench/steady_state.json``; the
acceptance bars are >= 6x on the 8-lane steady-state sweep and >= 3x at
the period=512 cadence (see ``artifacts/bench/baselines.json``), and
both engines stay bit-identical (``tests/test_blocked.py``,
``tests/test_split_windows.py``).
"""
from __future__ import annotations

import dataclasses

from . import common
from .fault_batch import _timed, eight_policies
from repro.core import (CostConfig, TieredMemSimulator, sweep,
                        benchmark_machine, workloads)


CADENCE_PERIODS = (128, 512, 2048)


def autonuma_policies(period=512):
    return [dataclasses.replace(p, autonuma=True, autonuma_period=period,
                                autonuma_budget=256)
            for p in eight_policies()]


def bench_trace(mc, tr, pols, cc):
    tel = common.telemetry()
    out = {"steps": tr.n_steps, "populate_steps": tr.populate_steps}
    for lanes, label in ((1, "1lane"), (len(pols), f"{len(pols)}lane")):
        row = {}
        for engine in ("per_step", "blocked"):
            if lanes == 1:
                sim = TieredMemSimulator(mc=mc, cc=cc, pc=pols[0],
                                         engine=engine, debug=True,
                                         telemetry=tel)
                secs = _timed(lambda: sim.run(tr))
            else:
                secs = _timed(lambda: sweep(mc, cc, pols, tr, engine=engine,
                                            debug=True, telemetry=tel))
            row[engine] = {"seconds": secs,
                           "lane_steps_per_sec": tr.n_steps * lanes / secs}
        row["speedup"] = (row["blocked"]["lane_steps_per_sec"]
                          / row["per_step"]["lane_steps_per_sec"])
        out[label] = row
    return out


def main(quick: bool = False):
    mc = benchmark_machine()
    cc = CostConfig()
    pols = eight_policies()
    steady_steps = 1024 if quick else 2048

    # steady-state: short populate, long zipfian run phase, no scan ticks
    tr_run = workloads.kv_store(mc, 1 << 12, run_steps=steady_steps,
                                seed=10, name="steady")

    results = {"steady": bench_trace(mc, tr_run, pols, cc)}
    if not quick:
        # the cadence figure row: the same trace with a scan tick every
        # `period` steps.  Lone ticks ride the hoist branch instead of
        # forcing a per-step window replay, so the blocked win should be
        # nearly flat across periods rather than halving at 512.
        results["cadence"] = {
            f"p{period}": bench_trace(mc, tr_run,
                                      autonuma_policies(period), cc)
            for period in CADENCE_PERIODS}

    rows = []

    def phase_rows(phase, res):
        for label in ("1lane", f"{len(pols)}lane"):
            r = res[label]
            rows.append((
                f"steady_state/{phase}/{label}",
                r["blocked"]["seconds"],
                f"speedup={r['speedup']:.2f}x;"
                f"blocked_sps={r['blocked']['lane_steps_per_sec']:.0f};"
                f"per_step_sps={r['per_step']['lane_steps_per_sec']:.0f}"))

    for phase, res in results.items():
        if phase == "cadence":
            for pkey, sub in res.items():
                phase_rows(f"cadence/{pkey}", sub)
        else:
            phase_rows(phase, res)
    common.emit(rows)
    # fast-vs-event window classification + device-time histograms for
    # the measured runs, alongside the headline numbers
    results["telemetry"] = common.telemetry().snapshot()
    common.emit_record("steady_state", results, rows=rows, quick=quick)
    return results


if __name__ == "__main__":
    main()
