"""Quickstart: the paper's headline result in ~1 minute on CPU.

Runs the scaled paper machine under the Linux baseline and under Radiant
(BHi+Mig) on a zipfian key-value workload and prints the cycle breakdown —
reproducing the paper's ~20% total-cycle improvement (Table 4).
"""
import sys
sys.path.insert(0, "src")

from repro.core import (TieredMemSimulator, benchmark_machine, bhi_mig,
                        linux_default, workloads)

mc = benchmark_machine()
trace = workloads.kv_store(mc, footprint=1 << 18, run_steps=4096,
                           name="memcached")

base = None
for name, pc in [("Linux first-touch", linux_default()),
                 ("Radiant BHi+Mig ", bhi_mig())]:
    res = TieredMemSimulator(mc=mc, pc=pc).run(trace)
    s = res.summary()
    tl = res.timeline
    p = trace.populate_steps
    run_total = float(tl["total_cycles"][-1] - tl["total_cycles"][p])
    run_walk = float(tl["walk_cycles"][-1] - tl["walk_cycles"][p])
    if base is None:
        base = (run_total, run_walk)
    print(f"{name}: run-phase cycles={run_total:.3g} "
          f"walk={run_walk:.3g} ({100*run_walk/run_total:.0f}% of cycles) "
          f"PTE pages on DRAM={s['leaf_pages_dram']}/"
          f"{s['leaf_pages_dram']+s['leaf_pages_nvmm']} "
          f"improvement={100*(base[0]-run_total)/base[0]:.1f}%")
print("\n(paper Table 4: BHi+Mig improves total cycles by ~20%)")
