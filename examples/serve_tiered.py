"""Serve a small model with batched requests over the tiered paged-KV
cache: continuous batching, swap-out/in of paused sequences, and Radiant
block-table management (upper levels pinned, leaf pages migrate with
their blocks).
"""
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.memsys import tiered_kv as tkv
from repro.serving.engine import Request, TieredServingEngine

cfg = configs.reduced(configs.get_config("qwen1.5-0.5b"))
KH, DH = cfg.n_kv_heads, cfg.head_dim
GROUPS = cfg.n_layers


def fake_model_kv(kv, rid):
    """Stand-in for the decoder's per-layer KV projections."""
    t = int(np.asarray(kv.seq_len[rid]))
    key = jax.random.PRNGKey(rid * 1000 + t)
    k = jax.random.normal(key, (GROUPS, KH, DH), jnp.bfloat16) * 0.1
    return k, k


def main():
    eng = TieredServingEngine(
        n_groups=GROUPS, kv_heads=KH, head_dim=DH, block_size=16,
        n_hot_blocks=256, n_cold_blocks=2048, n_seqs=16, max_seq=512,
        active_slots=4, radiant=True)
    rng = np.random.default_rng(0)
    for rid in range(10):
        plen = int(rng.integers(32, 128))
        eng.submit(Request(rid=rid, prompt_len=plen, max_new=32))
        ks = jax.random.normal(jax.random.PRNGKey(rid),
                               (plen, GROUPS, KH, DH), jnp.bfloat16) * 0.1
        eng.prefill(rid, (ks, ks))
    stats = eng.run(fake_model_kv, max_ticks=2000)
    s = np.asarray(eng.kv.stats)
    print(f"served tokens={stats.tokens} swaps={stats.swaps_in}/"
          f"{stats.swaps_out} cold_table_walks={stats.cold_walks}")
    print(f"block migs: promote={s[0]} demote={s[1]}; "
          f"leaf-table migs: promote={s[2]} demote={s[3]}")
    print(f"Radiant invariant violations: "
          f"{int(tkv.table_invariant_violations(eng.kv))}")
    assert stats.cold_walks == 0


if __name__ == "__main__":
    main()
