"""The paper's section 6.3 multi-tenant scenario, end to end.

Fill apps occupy DRAM, the benchmark app lands on NVMM, the fill apps
exit, AutoNUMA promotes the data — and only Radiant's Mig brings the
PTE pages home.  Prints the before/after placement and cycle deltas.
"""
import sys
sys.path.insert(0, "src")

import numpy as np

from repro.core import (TieredMemSimulator, benchmark_machine, bhi_mig,
                        linux_default, workloads)

mc = benchmark_machine()
trace = workloads.multi_tenant(mc, "memcached", bench_footprint=1 << 17,
                               run_steps=6144)
p = trace.populate_steps

for name, pc in [("Linux+AutoNUMA", linux_default()),
                 ("Radiant BHi+Mig", bhi_mig())]:
    res = TieredMemSimulator(mc=mc, pc=pc).run(trace)
    s = res.summary()
    tl = res.timeline
    run_total = float(tl["total_cycles"][-1] - tl["total_cycles"][p])
    run_walk = float(tl["walk_cycles"][-1] - tl["walk_cycles"][p])
    print(f"{name}: run cycles={run_total:.4g} walk={run_walk:.4g} | "
          f"PTE pages DRAM/NVMM = {s['leaf_pages_dram']}/"
          f"{s['leaf_pages_nvmm']} | PTE migrations={s['l4_mig_success']} "
          f"(already-in-dest={s['l4_mig_already_dest']}, "
          f"within-tier={s['l4_mig_in_dram']}, "
          f"sibling-guard={s['l4_mig_sibling_guard']}, "
          f"lock-skip={s['l4_mig_lock_skip']})")
print("\n(paper Fig. 10: walk cycles improve ~33-61%; "
      "PTE pages return to DRAM only with Mig)")
