"""End-to-end driver: train a ~100M-parameter qwen-family model.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

Uses the full production stack: config -> sharded params -> AdamW ->
microbatched train step -> periodic checkpoints -> resume.  On CPU this
runs a few hundred steps in minutes; loss drops from ~ln(vocab) as the
model learns the synthetic n-gram structure.
"""
import argparse
import dataclasses
import sys
sys.path.insert(0, "src")

from repro import configs
from repro.launch import train as train_launch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    # ~100M params: qwen1.5-0.5b topology, narrowed
    base = configs.get_config("qwen1.5-0.5b")
    cfg = dataclasses.replace(base, name="qwen-100m", d_model=512,
                              n_heads=8, n_kv_heads=8, d_ff=1408,
                              n_layers=12, vocab=32768)
    configs.REGISTRY[cfg.name] = cfg
    loss = train_launch.main([
        "--arch", cfg.name, "--steps", str(args.steps),
        "--global-batch", "16", "--seq-len", "256", "--lr", "1e-3",
        "--microbatches", "2", "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "100", "--resume", "auto", "--log-every", "20"])
    print(f"final loss: {loss:.4f}")


if __name__ == "__main__":
    main()
