"""The simulation service: broker correctness, batching, caching, search.

The contract chain: a broker lane == a ``sweep_lanes`` lane == a
sequential ``TieredMemSimulator`` run (bit-identical placements/counters,
cycles to f32 rounding) == the pure-Python oracle (pinned in
tests/test_sweep.py).  On top of that, the broker must *batch*: a
64-query mixed-policy burst compiles at most once per bucket, repeats are
answered from the content-addressed cache with zero recompiles, and the
scheduler honors max-wait, deadlines and priorities.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (CostConfig, MachineConfig, PolicyConfig, Trace,
                        TieredMemSimulator, TraceSpec, pad_trace,
                        sweep_compile_count, sweep_lanes, trace_digest,
                        FIRST_TOUCH, INTERLEAVE, PT_BIND_ALL, PT_BIND_HIGH,
                        PT_FOLLOW_DATA)
from repro.service import (ResultCache, SimBroker, SimQuery, grid_search,
                           policy_grid, successive_halving)
from repro.service import broker as broker_mod

from test_sweep import assert_lane_matches_sequential


def tiny_machine():
    return MachineConfig(n_threads=4, dram_pages_per_node=300,
                         nvmm_pages_per_node=1200, va_pages=1 << 11,
                         l1_tlb_sets=4, l1_tlb_ways=2, stlb_sets=8,
                         stlb_ways=4, pde_pwc_entries=4, pdpte_pwc_entries=2)


def random_trace(mc, steps=64, seed=0, free_at=None, name="rand"):
    rng = np.random.default_rng(seed)
    T = mc.n_threads
    va = rng.integers(0, mc.va_pages, (steps, T)).astype(np.int32)
    va[rng.random((steps, T)) < 0.05] = -1
    free_seg = np.full((steps,), -1, np.int32)
    if free_at is not None:
        free_seg[free_at] = 0
    seg = np.zeros((mc.n_map,), np.int32)
    seg[mc.n_map // 2:] = 1
    return Trace(va=va, is_write=rng.random((steps, T)) < 0.3,
                 free_seg=free_seg, llc=np.full((steps,), 0.4, np.float32),
                 seg_of_map=seg, name=name)


MIXED_POLICIES = [
    PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_FOLLOW_DATA,
                 autonuma=True, autonuma_period=16, autonuma_budget=32),
    PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_BIND_HIGH, mig=True,
                 autonuma=True, autonuma_period=16, autonuma_budget=16),
    PolicyConfig(data_policy=INTERLEAVE, pt_policy=PT_BIND_ALL,
                 autonuma=True, autonuma_period=16, autonuma_budget=8),
]


# ---------------------------------------------------------------------------
# sweep_lanes: the broker's execution primitive
# ---------------------------------------------------------------------------
def test_sweep_lanes_independent_tuples_match_sequential():
    """One lane per (cost, policy, trace) tuple — no cross product — and
    an over-provisioned budget bound must both be invisible per lane."""
    mc = tiny_machine()
    tr_a = random_trace(mc, seed=1, free_at=40, name="a")
    tr_b = random_trace(mc, seed=2, name="b")
    ccs = [CostConfig(), CostConfig(nvmm_read=1500), CostConfig()]
    trs = [tr_a, tr_b, tr_a]
    res = sweep_lanes(mc, ccs, MIXED_POLICIES, trs, budget=512)
    assert len(res) == 3
    for cc, pc, tr, r in zip(ccs, MIXED_POLICIES, trs, res):
        seq = TieredMemSimulator(mc=mc, cc=cc, pc=pc).run(tr)
        assert_lane_matches_sequential(r, seq)


def test_sweep_lanes_validation():
    mc = tiny_machine()
    tr = random_trace(mc, seed=3)
    with pytest.raises(ValueError, match="lane lists"):
        sweep_lanes(mc, [CostConfig()], MIXED_POLICIES, [tr, tr, tr])
    with pytest.raises(ValueError, match="budget override"):
        sweep_lanes(mc, [CostConfig()], [MIXED_POLICIES[0]], [tr], budget=8)
    with pytest.raises(ValueError, match="at least one lane"):
        sweep_lanes(mc, [], [], [])


# ---------------------------------------------------------------------------
# broker: correctness and batching
# ---------------------------------------------------------------------------
def test_broker_results_bit_identical_to_sequential():
    """Mixed burst (raw traces incl. a mid-run segment free + a spec-
    addressed workload, mixed policies and costs) — every per-query
    result equals its direct sequential run on the canonical trace."""
    mc = tiny_machine()
    broker = SimBroker(max_lanes=8, lane_sharding="auto")
    spec = TraceSpec(workload="xsbench", footprint=64, run_steps=16)
    traces = [random_trace(mc, seed=4, free_at=30, name="f"),
              random_trace(mc, seed=5, name="g"), spec]
    queries = [SimQuery(trace=tr, policy=pc, machine=mc,
                        cost=CostConfig(nvmm_read=750 + 250 * i))
               for i, tr in enumerate(traces) for pc in MIXED_POLICIES[:2]]
    results = broker.run(queries)
    for q, res in zip(queries, results):
        canonical = broker.canonical_trace(q)
        seq = TieredMemSimulator(mc=q.machine, cc=q.cost,
                                 pc=q.policy).run(canonical)
        assert_lane_matches_sequential(res, seq)


def test_burst_compiles_once_per_bucket_and_caches():
    """The acceptance scenario: a 64-query mixed-policy burst (16 traces
    x 4 policies, one shape bucket) compiles exactly once; a second burst
    of *different* trace content in the same bucket compiles zero more;
    replaying the first burst is pure cache (zero recompiles, zero
    lanes)."""
    mc = tiny_machine()
    policies = [PolicyConfig(data_policy=d, pt_policy=p, autonuma=False)
                for d in (FIRST_TOUCH, INTERLEAVE)
                for p in (PT_FOLLOW_DATA, PT_BIND_HIGH)]
    traces = [random_trace(mc, seed=100 + i, name=f"t{i}") for i in range(16)]
    queries = [SimQuery(trace=tr, policy=pc, machine=mc)
               for tr in traces for pc in policies]
    broker = SimBroker(max_lanes=64, lane_sharding="auto")

    before = sweep_compile_count()
    futs = broker.submit_many(queries)        # 64th submit flushes
    assert all(f.done() for f in futs)
    assert sweep_compile_count() == before + 1
    assert broker.stats.flushes == 1
    assert broker.stats.lanes_run == 64 and broker.stats.pad_lanes == 0

    traces2 = [random_trace(mc, seed=200 + i, name=f"u{i}")
               for i in range(16)]
    queries2 = [SimQuery(trace=tr, policy=pc, machine=mc)
                for tr in traces2 for pc in policies]
    broker.run(queries2)
    assert sweep_compile_count() == before + 1, \
        "same bucket, new trace content must reuse the compiled program"

    lanes_before = broker.stats.lanes_run
    futs3 = broker.submit_many(queries)
    assert all(f.done() and f.from_cache for f in futs3)
    assert sweep_compile_count() == before + 1
    assert broker.stats.lanes_run == lanes_before
    assert broker.stats.cache_hits == 64
    # cached results are the original objects — identical, not re-derived
    for f0, f3 in zip(futs, futs3):
        assert f3.result() is f0.result()


def test_inflight_dedup_single_lane():
    """Identical queries submitted before the flush share one lane."""
    mc = tiny_machine()
    broker = SimBroker(max_lanes=4)
    tr = random_trace(mc, seed=7)
    q = SimQuery(trace=tr, policy=MIXED_POLICIES[2], machine=mc)
    f1, f2 = broker.submit(q), broker.submit(q)
    assert broker.stats.inflight_joins == 1
    assert broker.pending_lanes() == 1
    broker.drain()
    assert f1.result() is f2.result()


def test_lane_padding_and_forced_future():
    """A 3-lane flush pads to 4 (pow2) and discards the pad; result()
    forces the owning bucket without waiting for capacity."""
    mc = tiny_machine()
    broker = SimBroker(max_lanes=64, max_wait=1e9)
    tr = random_trace(mc, seed=8)
    futs = [broker.submit(SimQuery(trace=tr, policy=pc, machine=mc))
            for pc in MIXED_POLICIES]
    assert not any(f.done() for f in futs)
    res = futs[1].result()                    # forces the flush
    assert all(f.done() for f in futs)
    assert broker.stats.pad_lanes == 1 and broker.stats.lanes_run == 3
    seq = TieredMemSimulator(mc=mc, pc=MIXED_POLICIES[1]).run(tr)
    assert_lane_matches_sequential(res, seq)


# ---------------------------------------------------------------------------
# scheduler: max-wait, deadline, priority (execution stubbed — pure
# scheduling logic, no device work)
# ---------------------------------------------------------------------------
class FakeClock:
    def __init__(self):
        self.now = 1000.0

    def __call__(self):
        return self.now


@pytest.fixture
def stub_exec(monkeypatch):
    flushed = []

    def fake_sweep_lanes(mc, ccs, pcs, trs, phase_b="batched", budget=None,
                         lane_sharding=None, engine="blocked", **kw):
        flushed.append(len(pcs))
        return [f"result-{len(flushed)}-{i}" for i in range(len(pcs))]

    monkeypatch.setattr(broker_mod, "sweep_lanes", fake_sweep_lanes)
    return flushed


def test_max_wait_flush(stub_exec):
    mc = tiny_machine()
    clock = FakeClock()
    broker = SimBroker(max_lanes=64, max_wait=5.0, clock=clock)
    fut = broker.submit(SimQuery(trace=random_trace(mc, seed=9),
                                 policy=MIXED_POLICIES[0], machine=mc))
    assert broker.pump() == 0 and not fut.done()
    clock.now += 5.1
    assert broker.pump() == 1 and fut.done()


def test_deadline_flushes_before_max_wait(stub_exec):
    mc = tiny_machine()
    clock = FakeClock()
    broker = SimBroker(max_lanes=64, max_wait=1e9, clock=clock)
    fut = broker.submit(SimQuery(trace=random_trace(mc, seed=10),
                                 policy=MIXED_POLICIES[0], machine=mc,
                                 deadline=clock.now + 2.0))
    assert broker.pump() == 0
    clock.now += 2.0
    assert broker.pump() == 1 and fut.done()


def test_priority_orders_due_buckets(stub_exec):
    """Two due buckets (distinct shapes): the higher-priority one flushes
    first even though it arrived later."""
    mc = tiny_machine()
    clock = FakeClock()
    broker = SimBroker(max_lanes=64, max_wait=1.0, clock=clock)
    lo = broker.submit(SimQuery(trace=random_trace(mc, seed=11, steps=48),
                                policy=MIXED_POLICIES[0], machine=mc,
                                priority=0))
    clock.now += 0.5
    hi = broker.submit(SimQuery(trace=random_trace(mc, seed=12, steps=96),
                                policy=MIXED_POLICIES[0], machine=mc,
                                priority=5))
    clock.now += 1.0                     # both past max_wait
    broker.pump()
    assert hi.done() and lo.done()
    assert hi.result() == "result-1-0"   # high-priority bucket ran first
    assert lo.result() == "result-2-0"


def test_failed_flush_fails_futures_not_hangs(monkeypatch):
    """A poisoned microbatch must fail its futures with a typed
    PoisonedQueryError (drain() itself survives), quarantine the digests
    so resubmits fail fast, and stay usable once the quarantine TTL
    lapses."""
    from repro.service.resilience import (PoisonedQueryError,
                                          ResilienceConfig)
    mc = tiny_machine()
    clock = FakeClock()
    broker = SimBroker(
        max_lanes=64, max_wait=1e9, clock=clock, sleep=lambda s: None,
        resilience=ResilienceConfig(max_retries=1, quarantine_ttl=10.0))
    tr = random_trace(mc, seed=13)
    futs = [broker.submit(SimQuery(trace=tr, policy=pc, machine=mc))
            for pc in MIXED_POLICIES[:2]]

    boom = RuntimeError("XLA fell over")

    def exploding(*a, **k):
        raise boom

    monkeypatch.setattr(broker_mod, "sweep_lanes", exploding)
    broker.drain()                       # survives the failure
    for f in futs:
        assert f.done()
        with pytest.raises(PoisonedQueryError) as ei:
            f.result()
        assert ei.value.__cause__ is boom
    assert broker.stats.quarantined == 2
    assert broker.stats.retries == 1     # one transient retry, then bisect

    # quarantined digests fail fast on resubmit — zero device calls
    fast = broker.submit(SimQuery(trace=tr, policy=MIXED_POLICIES[0],
                                  machine=mc))
    with pytest.raises(PoisonedQueryError) as ei:
        fast.result()
    assert ei.value.quarantined
    monkeypatch.undo()

    # TTL lapses: bucket is clear and new traffic flows normally
    clock.now += 11.0
    assert broker.pending_lanes() == 0
    res = broker.run([SimQuery(trace=tr, policy=MIXED_POLICIES[0],
                               machine=mc)])[0]
    seq = TieredMemSimulator(mc=mc, pc=MIXED_POLICIES[0]).run(tr)
    assert_lane_matches_sequential(res, seq)


def test_submit_rejects_thread_mismatch(stub_exec):
    mc = tiny_machine()
    wide = MachineConfig(n_threads=8, dram_pages_per_node=300,
                         nvmm_pages_per_node=1200, va_pages=1 << 11)
    tr = random_trace(wide, seed=14)        # 8-thread trace
    broker = SimBroker()
    with pytest.raises(ValueError, match="threads"):
        broker.submit(SimQuery(trace=tr, policy=MIXED_POLICIES[0],
                               machine=mc))


def test_spec_cache_hit_skips_generation(stub_exec):
    """Recipe-addressed cache keys: a repeat spec query is answered
    without ever rebuilding (or re-hashing) the trace."""
    from repro.core import workloads as wl
    mc = tiny_machine()
    broker = SimBroker(max_lanes=1)         # flush per submit
    spec = TraceSpec(workload="bfs", footprint=64, run_steps=16)
    q = SimQuery(trace=spec, policy=MIXED_POLICIES[0], machine=mc)
    f1 = broker.submit(q)
    assert f1.done() and not f1.from_cache
    wl._SPEC_CACHE.clear()                  # forget every built trace
    f2 = broker.submit(q)
    assert f2.done() and f2.from_cache
    assert len(wl._SPEC_CACHE) == 0, \
        "cache hit must not rebuild the trace from its spec"


def test_queries_validate_eagerly(stub_exec):
    mc = tiny_machine()
    with pytest.raises(ValueError, match="Trace or TraceSpec"):
        SimQuery(trace=np.zeros((4, 4)), policy=PolicyConfig(), machine=mc)
    with pytest.raises(ValueError, match="phase_b"):
        SimQuery(trace=random_trace(mc), policy=PolicyConfig(), machine=mc,
                 phase_b="warp")
    from repro.core import stack_policies
    stacked = stack_policies([PolicyConfig(), PolicyConfig()])
    broker = SimBroker()
    with pytest.raises(ValueError, match="plain Python scalars"):
        broker.submit(SimQuery(trace=random_trace(mc), policy=stacked,
                               machine=mc))


# ---------------------------------------------------------------------------
# spec addressing and digests
# ---------------------------------------------------------------------------
def test_trace_spec_canonicalization_and_digest():
    mc = tiny_machine()
    broker = SimBroker()
    spec = TraceSpec(workload="memcached", footprint=64, run_steps=10)
    q = SimQuery(trace=spec, policy=PolicyConfig(), machine=mc)
    tr1 = broker.canonical_trace(q)
    tr2 = broker.canonical_trace(q)
    assert tr1 is tr2, "spec builds are memoized (one generation pass)"
    assert tr1.n_steps == 64, "specs idle-pad to the pow2 floor"
    assert tr1.n_steps % 64 == 0

    nat = spec.build(mc)
    assert trace_digest(tr1) == trace_digest(pad_trace(nat, 64))
    renamed = dataclasses.replace(nat, name="other")
    assert trace_digest(nat) == trace_digest(renamed), \
        "digests are content-addressed; labels don't split the cache"
    assert trace_digest(nat) != trace_digest(
        TraceSpec(workload="memcached", footprint=64, run_steps=10,
                  seed=1).build(mc))
    assert spec.digest(mc) != dataclasses.replace(
        spec, run_steps=11).digest(mc)
    with pytest.raises(ValueError, match="unknown workload"):
        TraceSpec(workload="nope", footprint=64, run_steps=8)


def test_result_cache_lru_bound():
    c = ResultCache(max_entries=2)
    c.put(("a",), 1)
    c.put(("b",), 2)
    assert c.get(("a",)) == 1
    c.put(("c",), 3)                 # evicts ("b",), the LRU entry
    assert c.get(("b",)) is None and len(c) == 2
    assert c.hits == 1 and c.misses == 1
    assert c.evictions == 1
    assert c.stats() == {"hits": 1, "misses": 1, "evictions": 1,
                         "entries": 2}


def test_broker_stats_reset_and_pad_ratio(stub_exec):
    """Satellite: pad_lanes is reported as a ratio alongside the raw
    count, and reset() zeroes the whole window."""
    mc = tiny_machine()
    broker = SimBroker(max_lanes=64, max_wait=1e9)
    tr = random_trace(mc, seed=21)
    futs = [broker.submit(SimQuery(trace=tr, policy=pc, machine=mc))
            for pc in MIXED_POLICIES]            # 3 lanes -> pads to 4
    futs[0].result()
    assert broker.stats.lanes_run == 3 and broker.stats.pad_lanes == 1
    assert broker.stats.pad_ratio == 0.25
    d = broker.stats.as_dict()
    assert d["pad_lanes"] == 1 and d["pad_ratio"] == 0.25

    broker.stats.reset()
    zeroed = broker.stats.as_dict()
    assert all(v == 0 for v in zeroed.values()), zeroed
    assert broker.stats.pad_ratio == 0.0         # no div-by-zero
    # the broker keeps working across the measurement-window bookend
    broker.run([SimQuery(trace=tr, policy=MIXED_POLICIES[0], machine=mc)])
    assert broker.stats.queries == 1


def test_disk_cache_tier_roundtrip_and_byte_cap(tmp_path):
    from repro.service import DiskCacheTier
    tier = DiskCacheTier(tmp_path / "d", max_bytes=1 << 20)
    key = (("m", 4), "batched", "blocked", (1.5, 2), "digest")
    assert tier.get(key) is None and tier.misses == 1
    tier.put(key, {"x": np.arange(8)})
    got = tier.get(key)
    assert tier.hits == 1
    np.testing.assert_array_equal(got["x"], np.arange(8))
    # a fresh tier over the same dir serves the same entry (stable keys)
    tier2 = DiskCacheTier(tmp_path / "d", max_bytes=1 << 20)
    assert tier2.get(key) is not None
    # byte cap evicts oldest-mtime entries
    small = DiskCacheTier(tmp_path / "s", max_bytes=6000)
    for i in range(4):
        small.put((i,), np.zeros(500))   # ~4KB pickled each
        os.utime(small._file((i,)), (i + 1, i + 1))  # force mtime order
    small._evict()
    alive = [i for i in range(4) if small.get((i,)) is not None]
    assert 0 < len(alive) < 4, "cap must evict some but not all"
    assert alive == list(range(4 - len(alive), 4)), \
        "oldest-mtime entries evicted first"
    assert sum(f.stat().st_size
               for f in (tmp_path / "s").glob("*.pkl")) <= 6000


def test_disk_cache_eviction_accounting(tmp_path):
    """Satellite: the disk tier accounts every operation — flush counts
    written entries, eviction counts unlinked ones, and the counters
    reconcile with what is actually on disk."""
    from repro.service import DiskCacheTier
    tier = DiskCacheTier(tmp_path / "d", max_bytes=6000)
    for i in range(4):
        tier.put((i,), np.zeros(500))            # ~4KB pickled each
        os.utime(tier._file((i,)), (i + 1, i + 1))
    tier._evict()
    assert tier.flushes == 4
    on_disk = sum(1 for _ in (tmp_path / "d").glob("*.pkl"))
    assert tier.evictions == 4 - on_disk > 0
    stats = tier.stats()
    assert stats["flushes"] == 4
    assert stats["evictions"] == tier.evictions
    assert stats["entries"] == on_disk
    # gets keep reconciling after eviction
    tier.get((0,))                               # oldest: evicted -> miss
    tier.get((3,))                               # newest: survived -> hit
    assert tier.stats()["misses"] == 1 and tier.stats()["hits"] == 1

    # an oversized blob is refused, not flushed
    tiny = DiskCacheTier(tmp_path / "t", max_bytes=100)
    tiny.put(("big",), np.zeros(500))
    assert tiny.flushes == 0 and tiny.stats()["entries"] == 0


def test_disk_spilled_cache_serves_fresh_process_with_zero_device_work(
        tmp_path):
    """The spill satellite's acceptance: warm the cache through one
    broker, then rebuild EVERYTHING — broker, ResultCache, query objects
    (content keys are process-stable: dataclass reprs + digests, no
    object identity) — over the same spill dir and require the hit to be
    served without a single flush, lane or XLA compile."""
    mc = tiny_machine()
    spec = TraceSpec(workload="xsbench", footprint=64, run_steps=16)

    def fresh_query():
        return SimQuery(trace=spec, policy=PolicyConfig(autonuma=False),
                        machine=tiny_machine())

    warm = SimBroker(max_lanes=1,
                     cache=ResultCache(spill_dir=tmp_path / "cache"))
    res1 = warm.submit(fresh_query()).result()
    assert warm.stats.flushes == 1

    cold = SimBroker(max_lanes=1,
                     cache=ResultCache(spill_dir=tmp_path / "cache"))
    assert len(cold.cache) == 0, "in-memory tier starts empty"
    before = sweep_compile_count()
    fut = cold.submit(fresh_query())
    assert fut.done() and fut.from_cache
    assert cold.stats.flushes == 0 and cold.stats.lanes_run == 0
    assert sweep_compile_count() == before
    assert cold.cache.disk.hits == 1
    res2 = fut.result()
    assert res2.summary() == res1.summary()
    for k in res1.timeline:
        np.testing.assert_array_equal(res1.timeline[k], res2.timeline[k])


# ---------------------------------------------------------------------------
# lane-axis device sharding
# ---------------------------------------------------------------------------
def test_sharded_lanes_match_unsharded_multi_device():
    """The ROADMAP follow-up, proven on a real 2-device mesh: force two
    host CPU devices in a subprocess and require the lane-sharded sweep
    to match the unsharded one exactly."""
    code = textwrap.dedent("""
        import numpy as np
        from repro.core import (MachineConfig, CostConfig, PolicyConfig,
                                Trace, lane_mesh, sweep_lanes)
        import jax
        assert len(jax.devices()) == 2, jax.devices()
        mc = MachineConfig(n_threads=4, dram_pages_per_node=300,
                           nvmm_pages_per_node=1200, va_pages=1 << 11,
                           l1_tlb_sets=4, l1_tlb_ways=2, stlb_sets=8,
                           stlb_ways=4, pde_pwc_entries=4,
                           pdpte_pwc_entries=2)
        rng = np.random.default_rng(0)
        steps = 48
        tr = Trace(va=rng.integers(0, mc.va_pages, (steps, 4)).astype(
                       np.int32),
                   is_write=rng.random((steps, 4)) < 0.3,
                   free_seg=np.full((steps,), -1, np.int32),
                   llc=np.full((steps,), 0.4, np.float32),
                   seg_of_map=np.zeros((mc.n_map,), np.int32))
        pcs = [PolicyConfig(autonuma=False),
               PolicyConfig(data_policy=1, autonuma=False)]
        ccs = [CostConfig()] * 2
        assert lane_mesh(2).devices.size == 2
        plain = sweep_lanes(mc, ccs, pcs, [tr, tr])
        shard = sweep_lanes(mc, ccs, pcs, [tr, tr], lane_sharding="auto")
        for a, b in zip(plain, shard):
            sa, sb = a.summary(), b.summary()
            for k, v in sa.items():
                assert sb[k] == v, (k, v, sb[k])
            for k in a.timeline:
                np.testing.assert_array_equal(a.timeline[k], b.timeline[k])
        print("SHARDED-OK")
    """)
    env = dict(os.environ,
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "")
                          + " --xla_force_host_platform_device_count=2"),
               JAX_PLATFORMS="cpu",
               PYTHONPATH="src" + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=300,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "SHARDED-OK" in proc.stdout


# ---------------------------------------------------------------------------
# search drivers (the broker's dogfood client)
# ---------------------------------------------------------------------------
def test_grid_search_and_successive_halving_reuse_cache():
    mc = tiny_machine()
    broker = SimBroker(max_lanes=16)
    spec = TraceSpec(workload="xsbench", footprint=64, run_steps=16)
    cands = policy_grid({"data_policy": (FIRST_TOUCH, INTERLEAVE),
                         "pt_policy": (PT_FOLLOW_DATA, PT_BIND_HIGH)},
                        base=PolicyConfig(autonuma=False))
    assert len(cands) == 4

    scored = grid_search(broker, mc, spec, cands)
    assert [s for _, s in scored] == sorted(s for _, s in scored)

    out = successive_halving(broker, mc, spec, policies=cands, rungs=2)
    assert out["best_label"] in {pc.label() for pc in cands}
    assert len(out["history"]) == 2
    assert len(out["history"][1]["scores"]) == 2     # 4 -> 2 survivors

    # rung 0 shares the grid_search fidelity -> pure cache hits
    hits = broker.cache.hits
    assert hits >= 4
    # identical re-search is answered without any new lanes
    lanes = broker.stats.lanes_run
    out2 = successive_halving(broker, mc, spec, policies=cands, rungs=2)
    assert out2["best_label"] == out["best_label"]
    assert broker.stats.lanes_run == lanes


def test_policy_sweep_summary_routes_through_broker():
    """launch.analysis grid regeneration rides the service now."""
    from repro.launch.analysis import policy_sweep_summary
    mc = tiny_machine()
    tr = random_trace(mc, seed=33)
    broker = SimBroker(max_lanes=8)
    out = policy_sweep_summary(mc, MIXED_POLICIES[:2], tr, broker=broker)
    assert broker.stats.lanes_run == 2
    labels = [pc.label() for pc in MIXED_POLICIES[:2]]
    assert set(out) == set(labels)
    assert out[labels[0]]["improvement_pct"] == 0.0
    # regenerating the same grid is pure cache
    policy_sweep_summary(mc, MIXED_POLICIES[:2], tr, broker=broker)
    assert broker.stats.lanes_run == 2 and broker.stats.cache_hits == 2


# ---------------------------------------------------------------------------
# throughput driver (quick mode — CI-noise-proof; the >=3x acceptance
# number is recorded by the full benchmark run in
# artifacts/bench/service_throughput.json)
# ---------------------------------------------------------------------------
def test_service_throughput_quick_smoke():
    from benchmarks import service_throughput
    res = service_throughput.main(quick=True)
    assert res["n_queries"] == 2
    assert res["cached"]["recompiles"] == 0
    assert res["broker"]["qps"] > 0 and res["naive"]["qps"] > 0
