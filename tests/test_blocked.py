"""Time-blocked engine vs the per-step reference vs the oracle.

The blocked stepper (fixed step-windows; event-free windows execute as
one scan step with only the TLB/cycle carry threaded through, event
windows replay the per-step path row by row) must be **bit-identical**
to ``engine="per_step"`` — placements, counters, per-thread f32 cycle
accumulators and the full per-step timeline, not merely within rounding
— because the fast window replays the per-step expression tree in
per-step order.  Same for the conflict-group-compacted allocator scan
(``alloc.alloc_many(slot_thread=...)``) against its full-depth scan.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CostConfig, MachineConfig, PolicyConfig,
                        TieredMemSimulator, Trace, sweep,
                        FIRST_TOUCH, INTERLEAVE, PT_BIND_ALL, PT_BIND_HIGH,
                        PT_FOLLOW_DATA)
from repro.core import alloc as alloc_mod
from repro.core.ref import OracleSim
from repro.core.sim import (DEFAULT_BLOCK, SCHED_WINNER, blocked_xs,
                            fault_group_bound, fault_schedule, plan_windows,
                            pow2ceil)

EXACT_KEYS = ("l1_hits", "stlb_hits", "walks", "walk_mem_reads", "faults",
              "slow_allocs", "data_migrations", "demotions",
              "l4_mig_success", "l4_mig_already_dest", "l4_mig_in_dram",
              "l4_mig_sibling_guard", "l4_mig_lock_skip",
              "data_pages_dram", "data_pages_nvmm",
              "leaf_pages_dram", "leaf_pages_nvmm", "oom_killed", "oom_step")
CYCLE_KEYS = ("total_cycles", "walk_cycles", "stall_cycles",
              "data_mem_cycles", "fault_cycles", "migration_cycles")

POLICIES = [
    PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_FOLLOW_DATA,
                 autonuma=True, autonuma_period=16, autonuma_budget=32),
    PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_BIND_HIGH, mig=True,
                 autonuma=True, autonuma_period=16, autonuma_budget=32),
    PolicyConfig(data_policy=INTERLEAVE, pt_policy=PT_FOLLOW_DATA,
                 autonuma=False),
    PolicyConfig(data_policy=INTERLEAVE, pt_policy=PT_BIND_HIGH,
                 autonuma=True, autonuma_period=16, autonuma_budget=16),
]


def tiny_machine(**kw):
    kw.setdefault("n_threads", 4)
    kw.setdefault("dram_pages_per_node", 600)
    kw.setdefault("nvmm_pages_per_node", 2400)
    kw.setdefault("va_pages", 1 << 12)
    return MachineConfig(l1_tlb_sets=4, l1_tlb_ways=2, stlb_sets=8,
                         stlb_ways=4, pde_pwc_entries=4,
                         pdpte_pwc_entries=2, **kw)


def make_trace(mc, va, free_at=None):
    steps = va.shape[0]
    free_seg = np.full((steps,), -1, np.int32)
    if free_at is not None:
        free_seg[free_at] = 0
    seg = np.zeros((mc.n_map,), np.int32)
    seg[mc.n_map // 2:] = 1
    return Trace(va=va.astype(np.int32),
                 is_write=np.ones_like(va, bool),
                 free_seg=free_seg,
                 llc=np.full((steps,), 0.4, np.float32), seg_of_map=seg)


def steady_trace(mc, steps=200, seed=0, touched_frac=0.25, free_at=None):
    """Short populate burst, then a long fault-free re-access phase."""
    rng = np.random.default_rng(seed)
    T = mc.n_threads
    pop_rows = min(max(int(mc.n_map * touched_frac) // T, 1), steps // 3)
    pool = pop_rows * T
    s = np.arange(pop_rows, dtype=np.int64)[:, None]
    t = np.arange(T, dtype=np.int64)[None, :]
    pop = ((s * T + t) << mc.map_shift).astype(np.int64)
    run = (rng.integers(0, pool, (steps - pop_rows, T))
           << mc.map_shift).astype(np.int64)
    va = np.concatenate([pop, run]).astype(np.int32)
    va[rng.random(va.shape) < 0.05] = -1
    return make_trace(mc, va, free_at)


def fault_heavy_trace(mc, steps=160, seed=1, free_at=None):
    rng = np.random.default_rng(seed)
    T = mc.n_threads
    va = np.where(rng.random((steps, T)) < 0.5,
                  rng.integers(0, mc.va_pages // 2, (steps, T)),
                  rng.integers(0, mc.va_pages, (steps, T))).astype(np.int32)
    va[rng.random((steps, T)) < 0.05] = -1
    return make_trace(mc, va, free_at)


def assert_states_bitwise(a, b, label=""):
    flat_a, _ = jax.tree_util.tree_flatten_with_path(a)
    flat_b = jax.tree_util.tree_leaves(b)
    assert len(flat_a) == len(flat_b)
    for (path, la), lb in zip(flat_a, flat_b):
        np.testing.assert_array_equal(
            np.asarray(la), np.asarray(lb),
            err_msg=f"{label}: {jax.tree_util.keystr(path)}")


def assert_blocked_matches_per_step(mc, pc, trace, cc=None, block=16):
    cc = cc or CostConfig()
    blk = TieredMemSimulator(mc=mc, cc=cc, pc=pc, engine="blocked",
                             block=block).run(trace)
    ps = TieredMemSimulator(mc=mc, cc=cc, pc=pc, engine="per_step",
                            debug=True).run(trace)
    assert_states_bitwise(blk.final_state, ps.final_state, pc.label())
    for k in blk.timeline:
        np.testing.assert_array_equal(blk.timeline[k], ps.timeline[k],
                                      err_msg=f"{pc.label()}: tl/{k}")
        assert blk.timeline[k].shape == (trace.n_steps,)
    return blk


def assert_matches_oracle(res, mc, cc, pc, trace):
    oracle = OracleSim(mc, cc, pc)
    oracle.run(trace)
    ref = oracle.summary()
    s = res.summary()
    for k in EXACT_KEYS:
        assert s[k] == ref[k], f"{pc.label()}: oracle {k}: {s[k]} != {ref[k]}"
    for k in CYCLE_KEYS:
        np.testing.assert_allclose(s[k], ref[k], rtol=1e-5,
                                   err_msg=f"{pc.label()}: oracle {k}")


def test_steady_state_trace_bitwise():
    """The target scenario: long fault-free stretches become fast windows
    (several per trace, forced by a small block) and stay bit-identical —
    cycles and timelines included, not just to rounding."""
    mc = tiny_machine()
    cc = CostConfig()
    trace = steady_trace(mc, steps=200, seed=3)
    for pc in POLICIES:
        res = assert_blocked_matches_per_step(mc, pc, trace, cc)
        assert_matches_oracle(res, mc, cc, pc, trace)


def test_fault_heavy_and_free_bitwise():
    """Faults and a mid-run segment free everywhere: nearly every window
    takes the per-step fallback; both phase-B engines agree."""
    mc = tiny_machine()
    cc = CostConfig()
    trace = fault_heavy_trace(mc, seed=5, free_at=100)
    for pc in POLICIES[:2]:
        for phase_b in ("batched", "sequential"):
            blk = TieredMemSimulator(mc=mc, cc=cc, pc=pc, engine="blocked",
                                     block=16, phase_b=phase_b,
                                     debug=True).run(trace)
            ps = TieredMemSimulator(mc=mc, cc=cc, pc=pc, engine="per_step",
                                    phase_b=phase_b, debug=True).run(trace)
            assert_states_bitwise(blk.final_state, ps.final_state,
                                  f"{pc.label()}/{phase_b}")
        assert_matches_oracle(blk, mc, cc, pc, trace)


def test_thp_machine_bitwise():
    mc = tiny_machine(page_order=9)
    cc = CostConfig()
    trace = fault_heavy_trace(mc, seed=51)
    for pc in POLICIES[:2]:
        res = assert_blocked_matches_per_step(mc, pc, trace, cc)
        assert_matches_oracle(res, mc, cc, pc, trace)


def test_oom_trace_bitwise():
    """The OOM latch freezes every lane; post-OOM fast windows must stay
    inert exactly like per-step execution (bind-all pathology)."""
    mc = tiny_machine(dram_pages_per_node=150, nvmm_pages_per_node=1600,
                      va_pages=1 << 11, radix_bits=4)
    cc = CostConfig()
    T = mc.n_threads
    s = np.arange(256, dtype=np.int32)[:, None]
    t = np.arange(T, dtype=np.int32)[None, :]
    va = np.minimum(s * T + t, mc.va_pages - 1).astype(np.int32)
    trace = make_trace(mc, va)
    for ptp in (PT_FOLLOW_DATA, PT_BIND_ALL, PT_BIND_HIGH):
        pc = PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=ptp,
                          autonuma=False)
        res = assert_blocked_matches_per_step(mc, pc, trace, cc)
        assert_matches_oracle(res, mc, cc, pc, trace)
        if ptp == PT_BIND_ALL:
            assert res.summary()["oom_killed"]


def test_resume_mid_block():
    """Splitting a trace in the middle of what the full run tiles as one
    fast window must not change anything: chained blocked runs equal the
    unsplit per-step run bit-for-bit."""
    mc = tiny_machine()
    pc = POLICIES[0]
    trace = steady_trace(mc, steps=120, seed=13)
    full = TieredMemSimulator(mc=mc, pc=pc, engine="per_step",
                              debug=True).run(trace)

    cut = 75                      # not a multiple of any pow2 block size
    first = Trace(va=trace.va[:cut], is_write=trace.is_write[:cut],
                  free_seg=trace.free_seg[:cut], llc=trace.llc[:cut],
                  seg_of_map=trace.seg_of_map)
    second = Trace(va=trace.va[cut:], is_write=trace.is_write[cut:],
                   free_seg=trace.free_seg[cut:], llc=trace.llc[cut:],
                   seg_of_map=trace.seg_of_map)
    sim = TieredMemSimulator(mc=mc, pc=pc, engine="blocked", block=16)
    mid = sim.run(first)
    state = jax.tree.map(jnp.asarray, mid.final_state)
    res = sim.run(second, state=state)
    assert_states_bitwise(res.final_state, full.final_state, "resume")
    np.testing.assert_array_equal(
        np.concatenate([mid.timeline["total_cycles"],
                        res.timeline["total_cycles"]]),
        full.timeline["total_cycles"])


def test_vmapped_sweep_bitwise():
    """Blocked vs per-step engines lane-for-lane in an 8-lane vmapped
    sweep (window events are the union across lanes), and blocked sweep
    lanes vs solo blocked runs."""
    mc = tiny_machine()
    cc = CostConfig()
    trace = fault_heavy_trace(mc, seed=7, free_at=60)
    pols = [PolicyConfig(data_policy=d, pt_policy=p, autonuma=False)
            for d in (FIRST_TOUCH, INTERLEAVE)
            for p in (PT_FOLLOW_DATA, PT_BIND_ALL, PT_BIND_HIGH)]
    pols += [PolicyConfig(data_policy=d, pt_policy=PT_BIND_HIGH, mig=True,
                          autonuma=False) for d in (FIRST_TOUCH, INTERLEAVE)]
    blk = sweep(mc, cc, pols, trace, engine="blocked", block=16)
    ps = sweep(mc, cc, pols, trace, engine="per_step", debug=True)
    for pc, a, b in zip(pols, blk, ps):
        assert_states_bitwise(a.final_state, b.final_state, pc.label())
        for k in a.timeline:
            np.testing.assert_array_equal(a.timeline[k], b.timeline[k],
                                          err_msg=f"{pc.label()}: tl/{k}")
        solo = TieredMemSimulator(mc=mc, cc=cc, pc=pc, engine="blocked",
                                  block=16).run(trace)
        assert_states_bitwise(a.final_state, solo.final_state,
                              f"solo/{pc.label()}")


def test_window_tiling_shape_independence():
    """Window count is shape-derived (ceil(S/block)) and xs shapes depend
    only on the step count plus the pow2-quantized split geometry — never
    on raw event rows (the broker-quantization property, now carrying the
    geometry in the compile key); the plan's emission mask maps emitted
    rows back to exactly S steps."""
    mc = tiny_machine()
    pc = POLICIES[0]
    a, plan_a = blocked_xs(steady_trace(mc, steps=100, seed=1), mc, pc,
                           block=16)
    b, plan_b = blocked_xs(fault_heavy_trace(mc, steps=100, seed=2), mc, pc,
                           block=16)
    # window count from the shape alone, for any content
    assert a[0].shape[0] == b[0].shape[0] == 7      # ceil(100/16) windows
    assert plan_a.n_windows == plan_b.n_windows == 7
    # event rows landing in the same pow2 capacity bucket quantize to one
    # geometry (free executable reuse); xs shapes follow the geometry
    none = np.zeros(100, bool)

    def fault_at(row):
        m = none.copy()
        m[row] = True
        return m

    p1 = plan_windows(none, none, fault_at(19), 100, 16)  # window row 3
    p2 = plan_windows(none, none, fault_at(20), 100, 16)  # window row 4
    assert p1.geom == p2.geom
    assert p1.emit_valid.shape == p2.emit_valid.shape
    # every trace step is emitted exactly once, in order, for any plan
    for plan in (plan_a, plan_b):
        assert int(plan.emit_valid.sum()) == 100
    # the steady trace's dense populate windows stay per-step (full) while
    # its scan-tick windows leave the whole-window path (kind > 0)
    assert (plan_a.kind > 0).any()


def test_alloc_many_conflict_groups_match_full_scan():
    """The compacted allocator scan == the full T-deep scan on random
    winner sets, including OOM latching mid-step (committed results, the
    gates and the carried allocator state; non-acting lanes are
    don't-care by contract)."""
    rng = np.random.default_rng(0)
    T = 16
    amc = MachineConfig(n_threads=T)
    wm = jnp.asarray([5, 5, 5, 5], jnp.int32)
    for trial in range(20):
        n_winners = int(rng.integers(0, T + 1))
        winners = np.zeros(T, bool)
        winners[rng.choice(T, size=n_winners, replace=False)] = True
        need_pt = winners[:, None] & (rng.random((T, 4)) < 0.5)
        need_data = winners & (rng.random(T) < 0.9)
        free = jnp.asarray(rng.integers(0, 12, 4), jnp.int32)
        rec = jnp.asarray(rng.integers(0, 3, 4), jnp.int32)
        ptr = jnp.asarray(int(rng.integers(0, 4)), jnp.int32)
        oom0 = jnp.asarray(bool(rng.random() < 0.1))
        dpol = int(rng.choice([FIRST_TOUCH, INTERLEAVE]))
        ppol = int(rng.choice([PT_FOLLOW_DATA, PT_BIND_ALL, PT_BIND_HIGH]))

        G = pow2ceil(max(n_winners, 1))
        slot = np.cumsum(winners) - 1
        slot_thread = np.full(G, T, np.int64)
        slot_thread[slot[winners]] = np.where(winners)[0]

        args = (free, rec, ptr, oom0, wm, dpol, ppol, amc,
                jnp.asarray(need_pt), jnp.asarray(need_data))
        ref = alloc_mod.alloc_many(*args)
        got = alloc_mod.alloc_many(*args,
                                   slot_thread=jnp.asarray(slot_thread))
        names = ("nodes", "slow", "ok", "act", "gate", "free", "rec",
                 "ptr", "oom")
        act = np.asarray(ref[3])
        for name, r, g in zip(names, ref, got):
            r, g = np.asarray(r), np.asarray(g)
            if name in ("nodes", "slow", "ok"):
                np.testing.assert_array_equal(
                    np.where(act, r, 0), np.where(act, g, 0),
                    err_msg=f"trial {trial}: {name}")
            else:
                np.testing.assert_array_equal(r, g,
                                              err_msg=f"trial {trial}: {name}")


def test_fault_group_bound_and_block_quantization():
    mc = tiny_machine()
    trace = fault_heavy_trace(mc, seed=9)
    sched = fault_schedule(trace, mc)
    bound = fault_group_bound(sched)
    winners = ((sched & SCHED_WINNER) > 0).sum(axis=1)
    assert bound == max(int(winners.max()), 1)
    assert pow2ceil(5) == 8 and pow2ceil(8) == 8 and pow2ceil(0) == 1
    assert DEFAULT_BLOCK == 64
