"""Failure semantics of the service layer, chaos-tested.

Layers under test:

  * :mod:`repro.obs.inject` — the deterministic fault-injection harness
    itself (seeded, schedule-reproducible plans);
  * :mod:`repro.service.resilience` — quarantine TTL, circuit-breaker
    transitions, retry/backoff policy;
  * the broker's failure paths (stubbed execution — pure control flow):
    transient retry, poison-lane bisection + quarantine, degraded-mode
    breaker, deadline shedding, admission control, drain liveness,
    future timeouts, and the ``_fut_index`` leak fix;
  * the disk cache's self-healing read path (real files, torn writes);
  * seeded chaos properties: random fault plans against 64-query bursts
    — every future terminates with a result or a typed error, survivors
    are bit-identical to the fault-free run, the broker recovers to
    non-degraded mode.  Runs under hypothesis when available, with the
    seeded deterministic fallback (the ``tests/test_ntier.py`` pattern).

The end-to-end acceptance scenario (real device execution, 64-query
mixed burst with device failures + disk corruption + expired deadlines,
exact counter pins) is marked ``chaos`` + ``slow``: CI's chaos step runs
it via ``pytest -m chaos``.
"""
import dataclasses
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # property tests skip; the rest run
    HAVE_HYPOTHESIS = False

from repro.obs.inject import (FaultInjector, FaultRule, InjectedFault,
                              NULL_INJECTOR, fail_lane, fail_n, fail_once,
                              fail_rate)
from repro.service import SimBroker, SimQuery
from repro.service import broker as broker_mod
from repro.service.cache import DiskCacheTier, ResultCache
from repro.service.resilience import (BrokerOverloadedError,
                                      BrokerTimeoutError, CircuitBreaker,
                                      DeadlineExceededError,
                                      PoisonedQueryError, Quarantine,
                                      ResilienceConfig, ServiceError)

from test_service import (FakeClock, MIXED_POLICIES, random_trace,
                          tiny_machine)
from test_sweep import assert_lane_matches_sequential


# ---------------------------------------------------------------------------
# the fault-injection harness itself
# ---------------------------------------------------------------------------
def test_fault_rule_validation():
    with pytest.raises(ValueError, match="mode"):
        FaultRule(site="x", mode="sometimes")
    with pytest.raises(ValueError, match="match"):
        FaultRule(site="x", mode="match")
    with pytest.raises(ValueError, match="kind"):
        FaultRule(site="x", kind="explode")


def test_fail_n_schedule_and_accounting():
    inj = FaultInjector([fail_n("sweep.device", 2)])
    for _ in range(2):
        with pytest.raises(InjectedFault) as ei:
            inj.fire("sweep.device")
        assert ei.value.transient and ei.value.site == "sweep.device"
    inj.fire("sweep.device")             # exhausted: passes
    inj.fire("other.site")               # unrelated site never fails
    assert inj.fired == {"sweep.device": 3, "other.site": 1}
    assert inj.injected == {"sweep.device": 2}
    assert inj.stats()["total_injected"] == 2
    assert len(inj.log) == 2


def test_fail_lane_matches_context():
    inj = FaultInjector([fail_lane("sweep.device", "deadbeef")])
    inj.fire("sweep.device", lanes=["aaaa", "bbbb"])    # no match
    with pytest.raises(InjectedFault) as ei:
        inj.fire("sweep.device", lanes=["aaaa", "deadbeef01"])
    assert not ei.value.transient        # lane poison is persistent
    assert ei.value.matched == "deadbeef01"
    with pytest.raises(InjectedFault):
        inj.fire("sweep.device", key="xx-deadbeef-yy")


def test_fail_rate_is_seed_deterministic():
    def schedule(seed):
        inj = FaultInjector([fail_rate("s", 0.3, seed=seed)])
        out = []
        for _ in range(100):
            try:
                inj.fire("s")
                out.append(0)
            except InjectedFault:
                out.append(1)
        return out

    a, b = schedule(7), schedule(7)
    assert a == b and sum(a) > 0
    assert schedule(8) != a


def test_null_injector_is_inert():
    NULL_INJECTOR.fire("sweep.device", lanes=["x"])
    with pytest.raises(RuntimeError, match="shared"):
        NULL_INJECTOR.add(fail_once("sweep.device"))


# ---------------------------------------------------------------------------
# resilience primitives
# ---------------------------------------------------------------------------
def test_quarantine_ttl():
    q = Quarantine(ttl=10.0)
    q.add("aa", now=100.0)
    q.add("bb", now=105.0)
    assert q.check("aa", 109.0) and len(q) == 2
    assert not q.check("cc", 109.0)
    assert not q.check("aa", 110.0)      # expired exactly at TTL, purged
    assert q.digests() == ["bb"]
    q.purge(1000.0)
    assert len(q) == 0


def test_circuit_breaker_transitions():
    br = CircuitBreaker(threshold=3, recovery=2)
    k = ("bucket",)
    assert not br.record_failure(k) and not br.record_failure(k)
    br.record_success(k)                 # success resets the failure streak
    assert not br.record_failure(k) and not br.record_failure(k)
    assert br.record_failure(k)          # third consecutive: opens
    assert br.is_open(k) and br.open_keys() == [k]
    assert not br.record_success(k)      # 1 of 2 recoveries
    br.record_failure(k)                 # failure resets the success streak
    assert br.is_open(k)
    assert not br.record_success(k)
    assert br.record_success(k)          # 2 consecutive: closes
    assert not br.is_open(k)


def test_resilience_config_backoff_and_validation():
    rs = ResilienceConfig(backoff_base=0.1, backoff_cap=0.5)
    assert [rs.backoff(a) for a in range(4)] == [0.1, 0.2, 0.4, 0.5]
    with pytest.raises(ValueError):
        ResilienceConfig(max_retries=-1)
    with pytest.raises(ValueError):
        ResilienceConfig(breaker_threshold=0)


# ---------------------------------------------------------------------------
# broker failure paths (execution stubbed — pure control flow)
# ---------------------------------------------------------------------------
@pytest.fixture
def stub_exec(monkeypatch):
    """Stub sweep_lanes recording (n_lanes, kwargs) per call."""
    calls = []

    def fake_sweep_lanes(mc, ccs, pcs, trs, **kw):
        calls.append((len(pcs), kw))
        return [f"result-{len(calls)}-{i}" for i in range(len(pcs))]

    monkeypatch.setattr(broker_mod, "sweep_lanes", fake_sweep_lanes)
    return calls


def _broker(injector=None, resilience=None, **kw):
    sleeps = []
    kw.setdefault("max_wait", 1e9)
    b = SimBroker(injector=injector, resilience=resilience,
                  sleep=sleeps.append, **kw)
    b._test_sleeps = sleeps
    return b


@pytest.mark.chaos
def test_transient_fault_retried_with_backoff(stub_exec):
    mc = tiny_machine()
    inj = FaultInjector([fail_n("sweep.device", 2)])
    b = _broker(injector=inj, max_lanes=2,
                resilience=ResilienceConfig(max_retries=2, backoff_base=0.01))
    tr = random_trace(mc, seed=20)
    futs = [b.submit(SimQuery(trace=tr, policy=pc, machine=mc))
            for pc in MIXED_POLICIES[:2]]
    assert [f.result() for f in futs] == ["result-1-0", "result-1-1"]
    assert b.stats.retries == 2 and b.stats.quarantined == 0
    assert b._test_sleeps == [0.01, 0.02]
    assert not b.degraded_buckets()
    assert b._fut_index == {}


@pytest.mark.chaos
def test_persistent_lane_poisoned_by_bisection(stub_exec):
    mc = tiny_machine()
    traces = [random_trace(mc, seed=30 + i, name=f"p{i}") for i in range(4)]
    queries = [SimQuery(trace=tr, policy=MIXED_POLICIES[0], machine=mc)
               for tr in traces]
    probe = SimBroker()                  # digests are broker-independent
    bad_digest = probe.query_digest(queries[2])
    inj = FaultInjector([fail_lane("sweep.device", bad_digest)])
    b = _broker(injector=inj, max_lanes=4)
    futs = b.submit_many(queries)        # 4th submit flushes

    # innocent lanes resolved from the bisection halves, guilty poisoned
    assert futs[0].result() == "result-1-0"
    assert futs[1].result() == "result-1-1"
    assert futs[3].result() == "result-2-0"
    with pytest.raises(PoisonedQueryError) as ei:
        futs[2].result()
    assert ei.value.digest == bad_digest and not ei.value.quarantined
    assert isinstance(ei.value.__cause__, InjectedFault)
    assert [n for n, _ in stub_exec] == [2, 1]   # pairs run; device never
    assert b.stats.retries == 0                  # saw the poisoned lane
    assert b.stats.quarantined == 1

    # resubmit fails fast out of quarantine — no new execution
    with pytest.raises(PoisonedQueryError) as ei:
        b.submit(queries[2]).result()
    assert ei.value.quarantined and len(stub_exec) == 2
    assert b._fut_index == {}


@pytest.mark.chaos
def test_breaker_degrades_bucket_then_recovers(stub_exec):
    mc = tiny_machine()
    inj = FaultInjector([fail_n("broker.flush", 2)])
    b = _broker(injector=inj, max_lanes=1,
                resilience=ResilienceConfig(max_retries=0,
                                            breaker_threshold=2,
                                            breaker_recovery=1))
    qs = [SimQuery(trace=random_trace(mc, seed=40 + i, name=f"d{i}"),
                   policy=MIXED_POLICIES[0], machine=mc) for i in range(3)]
    fa, fb = b.submit(qs[0]), b.submit(qs[1])
    with pytest.raises(PoisonedQueryError):
        fa.result()
    with pytest.raises(PoisonedQueryError):
        fb.result()
    assert len(b.degraded_buckets()) == 1        # breaker tripped open

    # degraded flush: per-lane debug=True execution; clean pass closes it
    fc = b.submit(qs[2])
    assert fc.result() == "result-1-0"
    assert stub_exec[-1][0] == 1 and stub_exec[-1][1]["debug"] is True
    assert b.degraded_buckets() == []
    assert b._fut_index == {}


@pytest.mark.chaos
def test_deadline_shed_at_flush(stub_exec):
    mc = tiny_machine()
    clock = FakeClock()
    b = _broker(max_lanes=64, clock=clock)
    tr = random_trace(mc, seed=50)
    doomed = b.submit(SimQuery(trace=tr, policy=MIXED_POLICIES[0],
                               machine=mc, deadline=clock.now + 2.0))
    alive = b.submit(SimQuery(trace=tr, policy=MIXED_POLICIES[1],
                              machine=mc))
    clock.now += 5.0
    assert b.pump() == 1
    with pytest.raises(DeadlineExceededError) as ei:
        doomed.result()
    assert ei.value.deadline == 1002.0 and ei.value.now == 1005.0
    assert alive.result() == "result-1-0"
    assert b.stats.shed == 1 and b.stats.flushes == 1

    # a fully-shed flush never reaches the device (flush count frozen)
    dead = b.submit(SimQuery(trace=tr, policy=MIXED_POLICIES[2],
                             machine=mc, deadline=clock.now - 1.0))
    assert dead.done()                   # submit's pump sheds it
    with pytest.raises(DeadlineExceededError):
        dead.result()
    assert b.stats.shed == 2 and b.stats.flushes == 1 and len(stub_exec) == 1
    assert b._fut_index == {}


@pytest.mark.chaos
def test_admission_cap_rejects_lowest_priority(stub_exec):
    mc = tiny_machine()
    clock = FakeClock()
    b = _broker(max_lanes=64, clock=clock,
                resilience=ResilienceConfig(max_pending_lanes=2))
    mk = lambda i, prio: SimQuery(  # noqa: E731
        trace=random_trace(mc, seed=60 + i, name=f"a{i}"),
        policy=MIXED_POLICIES[0], machine=mc, priority=prio)
    fa = b.submit(mk(0, 0))
    clock.now += 1.0
    fb = b.submit(mk(1, 0))
    clock.now += 1.0

    # at cap, equal priority: the newcomer loses
    with pytest.raises(BrokerOverloadedError) as ei:
        b.submit(mk(2, 0)).result()
    assert ei.value.cap == 2
    # at cap, higher priority: the youngest lowest-priority lane loses
    fd = b.submit(mk(3, 5))
    with pytest.raises(BrokerOverloadedError):
        fb.result()
    assert b.stats.rejected == 2
    b.drain()
    assert fa.result() == "result-1-1" and fd.result() == "result-1-0"
    assert b.pending_lanes() == 0 and b._fut_index == {}


@pytest.mark.chaos
def test_drain_terminates_when_flush_keeps_raising(stub_exec, monkeypatch):
    """The livelock regression: _flush raising without retiring lanes
    must not loop drain() forever — bounded attempts, then the bucket is
    abandoned and its futures fail."""
    mc = tiny_machine()
    b = _broker(max_lanes=64)
    fut = b.submit(SimQuery(trace=random_trace(mc, seed=70),
                            policy=MIXED_POLICIES[0], machine=mc))

    def broken_flush(bkey):
        raise RuntimeError("flush wedged")

    monkeypatch.setattr(b, "_flush", broken_flush)
    b.drain()                            # must terminate
    assert fut.done()
    with pytest.raises(RuntimeError, match="abandoning") as ei:
        fut.result()
    assert "flush wedged" in str(ei.value.__cause__)
    assert b.pending_lanes() == 0 and b._fut_index == {}


def test_force_raises_when_bucket_vanishes(stub_exec):
    mc = tiny_machine()
    b = _broker(max_lanes=64)
    fut = b.submit(SimQuery(trace=random_trace(mc, seed=71),
                            policy=MIXED_POLICIES[0], machine=mc))
    b._buckets.clear()                   # simulate the broken invariant
    with pytest.raises(RuntimeError, match="vanished"):
        fut.result()


def test_pump_equal_priority_ties_break_oldest_first(stub_exec):
    mc = tiny_machine()
    clock = FakeClock()
    b = SimBroker(max_lanes=64, max_wait=1.0, clock=clock)
    older = b.submit(SimQuery(trace=random_trace(mc, seed=72, steps=48),
                              policy=MIXED_POLICIES[0], machine=mc))
    clock.now += 0.5
    newer = b.submit(SimQuery(trace=random_trace(mc, seed=73, steps=96),
                              policy=MIXED_POLICIES[0], machine=mc))
    clock.now += 1.0                     # both buckets past max_wait
    assert b.pump() == 2
    assert older.result() == "result-1-0"    # oldest enqueue flushed first
    assert newer.result() == "result-2-0"


def test_future_timeout_typed_and_retriable(stub_exec):
    mc = tiny_machine()
    b = _broker(max_lanes=64, clock=FakeClock())
    fut = b.submit(SimQuery(trace=random_trace(mc, seed=74),
                            policy=MIXED_POLICIES[0], machine=mc))
    with pytest.raises(BrokerTimeoutError) as ei:
        fut.result(timeout=0.0)
    assert ei.value.timeout == 0.0
    assert not fut.done()                # still pending, not failed
    assert fut.result(timeout=100.0) == "result-1-0"


def test_fut_index_empty_after_every_settlement_path(stub_exec):
    mc = tiny_machine()
    b = _broker(max_lanes=4)
    tr = random_trace(mc, seed=75)
    qs = [SimQuery(trace=tr, policy=pc, machine=mc) for pc in MIXED_POLICIES]
    futs = b.submit_many(qs)
    assert len(b._fut_index) == 3
    b.drain()
    assert b._fut_index == {}            # resolve path pops (the leak fix)
    again = b.submit_many(qs)            # cache hits never register
    assert all(f.from_cache for f in again) and b._fut_index == {}


# ---------------------------------------------------------------------------
# disk cache: self-healing reads
# ---------------------------------------------------------------------------
def test_disk_cache_quarantines_corrupt_entry_and_reheals(tmp_path):
    tier = DiskCacheTier(tmp_path)
    key = ("k", 1)
    tier.put(key, {"v": 42})
    assert tier.get(key) == {"v": 42}

    f = tier._file(key)
    blob = f.read_bytes()
    f.write_bytes(blob[:len(blob) // 2])         # torn write on disk
    assert tier.get(key) is None                 # detected, not served
    assert tier.corrupt == 1
    assert not f.exists()                        # quarantined to sidecar
    assert (tmp_path / "quarantine" / f.name).exists()
    assert tier.stats()["quarantined"] == 1

    tier.put(key, {"v": 42})                     # recompute-and-rewrite
    assert tier.get(key) == {"v": 42}
    assert tier.corrupt == 1                     # healed: no re-detection


def test_disk_cache_detects_garbage_and_injected_torn_write(tmp_path):
    inj = FaultInjector([fail_once("cache.disk.write", kind="corrupt")])
    tier = DiskCacheTier(tmp_path, injector=inj)
    key = ("k", 2)
    tier.put(key, [1, 2, 3])                     # injected torn write
    assert tier.get(key) is None and tier.corrupt == 1
    tier.put(key, [1, 2, 3])                     # rule exhausted: clean
    assert tier.get(key) == [1, 2, 3]

    # flipped payload byte: checksum catches what framing cannot
    f = tier._file(key)
    blob = bytearray(f.read_bytes())
    blob[-1] ^= 0xFF
    f.write_bytes(bytes(blob))
    assert tier.get(key) is None and tier.corrupt == 2


def test_disk_cache_injected_read_error_is_miss_not_corruption(tmp_path):
    inj = FaultInjector([fail_once("cache.disk.read")])
    tier = DiskCacheTier(tmp_path, injector=inj)
    key = ("k", 3)
    tier.put(key, "value")
    assert tier.get(key) is None                 # injected I/O error
    assert tier.corrupt == 0 and tier.misses == 1
    assert tier.get(key) == "value"              # file was never touched


def test_result_cache_spill_recomputes_through_corruption(tmp_path):
    cache = ResultCache(max_entries=2, spill_dir=tmp_path)
    for i in range(3):                           # overflow the memory LRU
        cache.put(("k", i), f"v{i}")
    assert cache.get(("k", 0)) == "v0"           # promoted back from disk

    f = cache.disk._file(("k", 1))
    f.write_bytes(b"garbage")
    assert cache.get(("k", 1)) is None           # corrupt disk + mem miss
    assert cache.disk.corrupt == 1


# ---------------------------------------------------------------------------
# chaos properties: random seeded fault plans vs 64-query bursts
# ---------------------------------------------------------------------------
def chaos_case(seed):
    rng = random.Random(seed)
    mc = tiny_machine()
    traces = [random_trace(mc, seed=1000 + i, name=f"z{i}")
              for i in range(8)]
    combos = [(tr, pc) for tr in traces for pc in MIXED_POLICIES]

    def det_sweep(mc_, ccs, pcs, trs, **kw):
        # content-determined lane results: identical with and without
        # faults, so survivor comparison is meaningful
        return [f"r:{tr.name}:{pc.label()}" for pc, tr in zip(pcs, trs)]

    clock = FakeClock()
    inj = FaultInjector()
    if rng.random() < 0.7:
        inj.add(fail_n("sweep.device", rng.randint(1, 3)))
    if rng.random() < 0.5:
        inj.add(fail_n("broker.flush", rng.randint(1, 2)))
    if rng.random() < 0.4:
        inj.add(fail_rate("sweep.device", 0.08, seed=seed))
    b = SimBroker(
        max_lanes=8, max_wait=0.5, clock=clock, sleep=lambda s: None,
        injector=inj,
        resilience=ResilienceConfig(max_retries=rng.randint(0, 2),
                                    backoff_base=0.001,
                                    breaker_threshold=2, breaker_recovery=1,
                                    quarantine_ttl=1000.0))
    for _ in range(rng.randint(0, 2)):
        tr, pc = rng.choice(combos)
        inj.add(fail_lane("sweep.device", b.query_digest(
            SimQuery(trace=tr, policy=pc, machine=mc))))

    import repro.service.broker as bmod
    orig = bmod.sweep_lanes
    bmod.sweep_lanes = det_sweep
    try:
        futs, baselines = [], []
        for _ in range(64):
            tr, pc = rng.choice(combos)
            deadline = clock.now - 1.0 if rng.random() < 0.15 else None
            futs.append(b.submit(SimQuery(trace=tr, policy=pc, machine=mc,
                                          deadline=deadline)))
            baselines.append(f"r:{tr.name}:{pc.label()}")
            if rng.random() < 0.2:
                clock.now += rng.uniform(0.0, 0.3)
        b.drain()

        stranded = [f for f in futs if not f.done()]
        assert not stranded, f"{len(stranded)} futures stranded"
        for fut, base in zip(futs, baselines):
            try:
                r = fut.result()
            except ServiceError:
                continue                 # typed failure: acceptable
            assert r == base, "survivor result diverged from fault-free run"
        assert b._fut_index == {}, "settled futures leaked index entries"

        # the broker must come back: clean traffic closes any breaker
        for i in range(10):
            if not b.degraded_buckets():
                break
            clock.now += 1.0
            try:
                b.run([SimQuery(
                    trace=random_trace(mc, seed=7000 + seed % 1000 + i,
                                       name=f"rec{i}"),
                    policy=MIXED_POLICIES[0], machine=mc)])
            except ServiceError:
                pass
        assert not b.degraded_buckets(), "broker stuck in degraded mode"
    finally:
        bmod.sweep_lanes = orig


@pytest.mark.chaos
@pytest.mark.parametrize("seed", range(5))
def test_chaos_fixed_seeds(seed):
    """Deterministic chaos coverage (runs without hypothesis)."""
    chaos_case(seed)


if HAVE_HYPOTHESIS:
    @pytest.mark.chaos
    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=0, max_value=10 ** 6))
    def test_chaos_property(seed):
        chaos_case(seed)


# ---------------------------------------------------------------------------
# acceptance: real execution, every failure mode at once, exact counters
# ---------------------------------------------------------------------------
@pytest.mark.chaos
@pytest.mark.slow
def test_chaos_acceptance_64_query_burst(tmp_path):
    """ISSUE 8 acceptance: a seeded plan injecting a transient device
    failure, one persistent poison lane, two torn disk-cache writes and
    four expired deadlines into a 64-query mixed burst.  Every future
    terminates (result or typed error), innocent results are
    bit-identical to a fault-free run, corrupt cache entries are
    quarantined and recomputed, and the snapshot pins exact counters."""
    mc = tiny_machine()
    policies = [dataclasses.replace(MIXED_POLICIES[0], autonuma=False),
                dataclasses.replace(MIXED_POLICIES[1], autonuma=False,
                                    mig=False),
                dataclasses.replace(MIXED_POLICIES[2], autonuma=False),
                dataclasses.replace(MIXED_POLICIES[0], autonuma=False,
                                    mig=True)]
    traces = [random_trace(mc, seed=300 + i, name=f"c{i}")
              for i in range(16)]
    combos = [(tr, pc) for tr in traces for pc in policies]    # 64 lanes

    # fault-free reference run (its own broker, no injection, no spill)
    ref = SimBroker(max_lanes=64)
    ref_results = ref.run([SimQuery(trace=tr, policy=pc, machine=mc)
                           for tr, pc in combos])

    probe = SimBroker()
    poisoned_digest = probe.query_digest(
        SimQuery(trace=combos[0][0], policy=combos[0][1], machine=mc))
    plan = FaultInjector([
        fail_n("sweep.device", 1),                       # transient hiccup
        fail_lane("sweep.device", poisoned_digest),      # persistent poison
        fail_n("cache.disk.write", 2, kind="corrupt"),   # torn spills
    ])
    clock = FakeClock()
    sleeps = []
    b1 = SimBroker(max_lanes=128, max_wait=1e9, clock=clock,
                   sleep=sleeps.append, injector=plan,
                   cache=ResultCache(spill_dir=tmp_path))
    queries = []
    for i, (tr, pc) in enumerate(combos):
        # the last four queries carry deadlines that expire before flush
        dl = clock.now + 5.0 if i >= 60 else None
        queries.append(SimQuery(trace=tr, policy=pc, machine=mc,
                                deadline=dl))
    futs = b1.submit_many(queries)
    clock.now += 6.0                     # blow the four deadlines
    assert b1.pump() == 1
    b1.drain()

    # zero stranded; exactly one poisoned, four shed, 59 innocent results
    assert all(f.done() for f in futs) and b1._fut_index == {}
    with pytest.raises(PoisonedQueryError) as ei:
        futs[0].result()
    assert ei.value.digest == poisoned_digest
    for i in (60, 61, 62, 63):
        with pytest.raises(DeadlineExceededError):
            futs[i].result()
    for i in range(1, 60):
        assert_lane_matches_sequential(futs[i].result(), ref_results[i])

    snap = b1.snapshot()
    assert snap["broker"]["queries"] == 64
    assert snap["broker"]["retries"] == 1        # the transient hiccup
    assert snap["broker"]["shed"] == 4
    assert snap["broker"]["quarantined"] == 1
    assert snap["broker"]["rejected"] == 0
    assert snap["broker"]["flushes"] == 1
    assert snap["broker"]["lanes_run"] == 59     # bisection halves: 30+15+
    assert snap["broker"]["pad_lanes"] == 4      # 7+4+2+1 lanes, 2+1+1 pads
    assert snap["quarantine"] == {"size": 1, "digests": [poisoned_digest]}
    assert snap["degraded_buckets"] == []        # 2 failures < threshold 3
    assert snap["faults"]["injected"] == {"sweep.device": 8,   # 2 batch
                                          "cache.disk.write": 2}  # attempts
    assert snap["faults"]["total_injected"] == 10  # + 5 bisect + 1 leaf
    assert sleeps == [0.01]                       # one backoff before retry
    # resubmitting the poisoned query fails fast while quarantined
    with pytest.raises(PoisonedQueryError) as ei:
        b1.submit(queries[0]).result()
    assert ei.value.quarantined

    # phase 2: a cold broker on the same spill dir self-heals the two
    # torn entries (detected, quarantined, recomputed) and serves the rest
    b2 = SimBroker(max_lanes=128, max_wait=1e9,
                   cache=ResultCache(spill_dir=tmp_path))
    futs2 = b2.submit_many([SimQuery(trace=tr, policy=pc, machine=mc)
                            for tr, pc in combos[1:]])
    b2.drain()
    for fut, ref_res in zip(futs2, ref_results[1:]):
        assert_lane_matches_sequential(fut.result(), ref_res)
    assert b2._fut_index == {}
    snap2 = b2.snapshot()
    assert snap2["cache"]["disk"]["corrupt"] == 2
    assert snap2["cache"]["disk"]["quarantined"] == 2
    assert snap2["broker"]["cache_hits"] == 57   # 59 spilled - 2 torn
    assert snap2["broker"]["lanes_run"] == 6     # 2 healed + 4 never-run
    assert snap2["broker"]["flushes"] == 1
