"""Sharding rules + multi-device integration (subprocess: 8 host devices).

The in-process tests cover the pure rule logic; the subprocess tests give
jax 8 CPU devices (XLA_FLAGS must be set before jax init, and the main
pytest process must keep seeing 1 device for the smoke tests).
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.models.modules import ParamSpec

SRC = str(Path(__file__).resolve().parent.parent / "src")


def run_sub(code: str, devices: int = 8):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_spec_for_rules():
    import jax
    from repro.distributed import sharding as sh
    # 1-device mesh: everything falls back to replication
    mesh = sh.make_mesh((1, 1), ("data", "model"))
    s = ParamSpec((64, 128), ("embed", "ff"))
    assert sh.spec_for(s, mesh) == jax.sharding.PartitionSpec(None, None)


def test_train_step_on_mesh_fsdp_and_tp():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.distributed import sharding as sh
        from repro.models import make_params, param_specs
        from repro.training import optimizer as opt_mod
        from repro.training.train import TrainConfig, make_train_step
        from repro.data.pipeline import DataConfig, batch_at

        mesh = sh.make_mesh((2, 4), ("data", "model"))
        cfg = configs.reduced(configs.get_config("qwen1.5-0.5b"))
        specs = param_specs(cfg)
        for rules in (sh.DEFAULT_RULES, sh.FSDP_RULES):
            p_sh = sh.param_shardings(specs, mesh, rules)
            with mesh:
                params = make_params(cfg, jax.random.PRNGKey(0))
                params = jax.tree.map(jax.device_put, params, p_sh)
                opt_state = opt_mod.init_opt_state(params)
                tc = TrainConfig(microbatches=2, seq_shard=True)
                step = jax.jit(make_train_step(cfg, tc, mesh))
                dc = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
                losses = []
                for i in range(3):
                    params, opt_state, m = step(params, opt_state,
                                                batch_at(dc, i))
                    losses.append(float(m["loss"]))
                assert all(np.isfinite(losses)), losses
        print("mesh train ok", losses)
    """)


def test_compressed_train_step_matches_plain():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.distributed import sharding as sh
        from repro.models import make_params
        from repro.training import optimizer as opt_mod
        from repro.training.train import (TrainConfig,
                                          make_compressed_train_step,
                                          make_train_step)
        from repro.data.pipeline import DataConfig, batch_at

        mesh = sh.make_mesh((4, 2), ("data", "model"))
        cfg = configs.reduced(configs.get_config("qwen1.5-0.5b"))
        dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
        batch = batch_at(dc, 0)
        outs = {}
        for name, compress in (("plain", None), ("int8", "int8")):
            params = make_params(cfg, jax.random.PRNGKey(0))
            opt_state = opt_mod.init_opt_state(params)
            tc = TrainConfig(compress_grads=compress)
            with mesh:
                step = jax.jit(make_compressed_train_step(cfg, tc, mesh))
                p, o, m = step(params, opt_state, batch)
            outs[name] = (p, float(m["loss"]))
        assert abs(outs["plain"][1] - outs["int8"][1]) < 1e-3
        deltas = []
        for a, b in zip(jax.tree.leaves(outs["plain"][0]),
                        jax.tree.leaves(outs["int8"][0])):
            d = np.abs(np.asarray(a, np.float32)
                       - np.asarray(b, np.float32)).max()
            deltas.append(d)
        # int8 grad quantization perturbs the update only slightly
        assert max(deltas) < 5e-2, max(deltas)
        print("compressed ok", outs["plain"][1], max(deltas))
    """)


def test_elastic_checkpoint_restore_across_meshes():
    run_sub("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.checkpoint import ckpt
        from repro.distributed import sharding as sh
        from repro.models import make_params, param_specs

        cfg = configs.reduced(configs.get_config("qwen1.5-0.5b"))
        specs = param_specs(cfg)
        params = make_params(cfg, jax.random.PRNGKey(0))
        d = tempfile.mkdtemp()
        ckpt.save(d, 1, params)

        # restore onto a different mesh shape (elastic DP resize)
        mesh = sh.make_mesh((4, 2), ("data", "model"))
        p_sh = sh.param_shardings(specs, mesh, sh.FSDP_RULES)
        example = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        back = ckpt.restore(d, 1, example, shardings=p_sh)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("elastic restore ok")
    """)
