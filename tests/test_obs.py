"""End-to-end telemetry: metrics registry, span recorder, no-op default.

Three contracts:

1. **Primitives** — counters/gauges/histograms with fixed log buckets
   (mergeable snapshots), the span recorder's Chrome/Perfetto
   ``trace_event`` export, and the validator that gates CI traces.
2. **Bitwise identity** — every hook is host-side: the blocked engine's
   outputs (placements, counters, timelines) are bit-identical with
   telemetry+tracing on and off, for both ``sweep_lanes`` and the
   sequential facade.
3. **The acceptance burst** — a 64-query mixed burst through an
   instrumented broker yields a snapshot whose compile-count, cache-hit
   and lanes/pad-lanes figures are asserted exactly, plus a
   Perfetto-loadable trace carrying one span per query lifecycle stage
   (admit -> queue -> flush -> sweep -> resolve).
"""
import json
import math

import numpy as np
import pytest

from repro.core import (CostConfig, MachineConfig, PolicyConfig,
                        TieredMemSimulator, sweep_compile_count, sweep_lanes,
                        FIRST_TOUCH, INTERLEAVE, PT_BIND_HIGH, PT_FOLLOW_DATA)
from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry, NULL,
                       NullTelemetry, SpanRecorder, Telemetry, or_null,
                       validate_trace_events)
from repro.obs import validate as validate_cli
from repro.service import SimBroker, SimQuery
from repro.service.broker import _bucket_label

from test_service import random_trace, tiny_machine


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_counter_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("hits", tier="mem")
    c.inc()
    c.inc(3)
    assert reg.counter("hits", tier="mem") is c, "get-or-create"
    assert reg.counter("hits", tier="disk") is not c, "labels split"
    assert reg.value("hits", tier="mem") == 4
    assert reg.value("hits", tier="disk") == 0
    assert reg.value("hits") is None and reg.value("nope") is None

    g = reg.gauge("depth")
    g.set(7)
    g.inc(-2)
    assert reg.value("depth") == 5

    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("hits")

    snap = reg.snapshot()
    assert snap == {"depth": 5, "hits{tier=disk}": 0, "hits{tier=mem}": 4}
    assert list(snap) == sorted(snap), "deterministic ordering"
    reg.reset()
    assert reg.snapshot() == {}


def test_histogram_fixed_log_buckets():
    h = Histogram(lo=1e-3, base=2.0, n_buckets=8)
    # boundaries never rescale: bucket i spans (lo*2^(i-1), lo*2^i]
    assert h.bucket_of(1e-3) == 0
    assert h.bucket_of(0.0) == 0          # underflow clamps
    assert h.bucket_of(2e-3) == 1
    assert h.bucket_of(2.1e-3) == 2
    assert h.bucket_of(1e9) == 7          # overflow clamps
    assert h.bucket_le(0) == 1e-3
    assert math.isinf(h.bucket_le(7))

    for v in (0.5e-3, 1.5e-3, 1.5e-3, 3e-3):
        h.observe(v)
    s = h.snapshot()
    assert s["count"] == 4 and s["min"] == 0.5e-3 and s["max"] == 3e-3
    assert s["sum"] == pytest.approx(6.5e-3)
    assert s["mean"] == pytest.approx(6.5e-3 / 4)
    # sparse buckets keyed by inclusive upper bound
    assert s["buckets"] == {"0.001": 1, "0.002": 2, "0.004": 1}

    # fixed boundaries => two snapshots merge bucket-by-bucket
    h2 = Histogram(lo=1e-3, base=2.0, n_buckets=8)
    h2.observe(1.5e-3)
    merged = dict(s["buckets"])
    for k, n in h2.snapshot()["buckets"].items():
        merged[k] = merged.get(k, 0) + n
    assert merged["0.002"] == 3

    empty = Histogram().snapshot()
    assert empty == {"count": 0, "sum": 0.0, "buckets": {}}

    with pytest.raises(ValueError):
        Histogram(lo=0)


# ---------------------------------------------------------------------------
# span recorder + trace_event export
# ---------------------------------------------------------------------------
class TickClock:
    def __init__(self, step=0.5):
        self.t, self.step = 100.0, step

    def __call__(self):
        self.t += self.step
        return self.t


def test_span_recorder_trace_event_export(tmp_path):
    rec = SpanRecorder(clock=TickClock(), process_name="unit")
    with rec.span("outer", cat="test", args={"k": 1}):
        rec.instant("tick")
    rec.add_span("explicit", rec.now(), rec.now(), tid=3)

    assert rec.span_names() == ["outer", "explicit"]
    obj = rec.to_trace_json()
    assert obj["displayTimeUnit"] == "ms"
    meta, *events = obj["traceEvents"]
    assert meta["ph"] == "M" and meta["args"]["name"] == "unit"
    inst, outer, explicit = events
    assert inst["ph"] == "i" and inst["ts"] >= 0
    assert outer["ph"] == "X" and outer["args"] == {"k": 1}
    assert outer["dur"] == pytest.approx(1.0e6)     # 2 ticks x 0.5 s, in us
    assert explicit["tid"] == 3
    assert explicit["dur"] == pytest.approx(0.5e6)
    assert validate_trace_events(obj) == []

    path = tmp_path / "t.json"
    rec.export(path)
    assert validate_trace_events(json.loads(path.read_text())) == []

    rec.reset()
    assert rec.events == [] and rec.dropped == 0


def test_span_recorder_bounded():
    rec = SpanRecorder(clock=TickClock(), max_events=2)
    for i in range(4):
        rec.instant(f"e{i}")
    assert len(rec.events) == 2 and rec.dropped == 2
    obj = rec.to_trace_json()
    assert obj["otherData"]["dropped_events"] == 2


def test_validator_catches_malformed_traces():
    assert validate_trace_events({}) == ["traceEvents missing or not a list"]
    bad = {"traceEvents": [
        {"name": "a", "ph": "X", "ts": -1, "dur": 2, "pid": 0, "tid": 0},
        {"name": "b", "ph": "Z", "ts": 0},
        {"name": "c", "ph": "B", "ts": 0, "pid": 0, "tid": 0},
        {"ph": "E", "ts": 1, "pid": 0, "tid": 1},
    ]}
    problems = validate_trace_events(bad)
    assert any("bad ts" in p for p in problems)
    assert any("unknown ph" in p for p in problems)
    assert any("E without matching B" in p for p in problems)
    assert any("unclosed B" in p for p in problems)
    good = {"traceEvents": [
        {"name": "s", "ph": "X", "ts": 0.0, "dur": 1.0, "pid": 0, "tid": 0}]}
    assert validate_trace_events(good) == []


def test_validate_cli(tmp_path, capsys):
    rec = SpanRecorder(clock=TickClock())
    with rec.span("s"):
        pass
    ok = tmp_path / "ok.json"
    rec.export(ok)
    assert validate_cli.main([str(ok)]) == 0
    assert "ok — " in capsys.readouterr().out

    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": []}))
    assert validate_cli.main([str(bad)]) == 1
    assert validate_cli.main([str(tmp_path / "missing.json")]) == 1
    assert validate_cli.main([]) == 2


# ---------------------------------------------------------------------------
# telemetry facade + the no-op default
# ---------------------------------------------------------------------------
def test_null_telemetry_is_inert_and_shared():
    assert or_null(None) is NULL
    tel = Telemetry()
    assert or_null(tel) is tel

    assert not NULL.enabled and not NULL.tracing
    # every write is absorbed; metric twins are shared singletons
    assert NULL.counter("x") is NULL.counter("y", a=1)
    NULL.counter("x").inc(5)
    NULL.gauge("g").set(3)
    NULL.histogram("h").observe(1.0)
    assert NULL.counter("x").snapshot() == 0
    with NULL.span("s", args={"a": 1}):
        pass
    NULL.add_span("s", 0.0, 1.0)
    NULL.instant("i")
    assert NULL.now() is None
    assert NULL.snapshot() == {"metrics": {}}
    assert NULL.export_trace("/nonexistent/x.json") is False
    NULL.reset()
    assert isinstance(NULL, NullTelemetry)


def test_telemetry_facade_tracing_toggle(tmp_path):
    off = Telemetry()                      # metrics on, tracing off
    assert off.enabled and not off.tracing
    off.counter("c").inc()
    assert off.now() is None
    off.add_span("never", 0.0, 1.0)        # no-op without a tracer
    with off.span("also-never"):
        pass
    assert off.snapshot() == {"metrics": {"c": 1}}
    assert off.export_trace(tmp_path / "no.json") is False

    on = Telemetry(tracing=True, clock=TickClock())
    assert on.tracing
    with on.span("s"):
        pass
    on.add_span("t", on.now(), on.now())
    snap = on.snapshot()
    assert snap["trace"]["events"] == 2 and snap["trace"]["dropped"] == 0
    assert on.export_trace(tmp_path / "yes.json") is True
    assert validate_trace_events(
        json.loads((tmp_path / "yes.json").read_text())) == []
    on.reset()
    assert on.snapshot() == {"metrics": {},
                             "trace": {"events": 0, "dropped": 0}}


# ---------------------------------------------------------------------------
# bitwise identity: telemetry hooks never touch the compiled engines
# ---------------------------------------------------------------------------
TELEM_POLICIES = [
    PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_FOLLOW_DATA,
                 autonuma=True, autonuma_period=16, autonuma_budget=32),
    PolicyConfig(data_policy=INTERLEAVE, pt_policy=PT_BIND_HIGH, mig=True,
                 autonuma=True, autonuma_period=16, autonuma_budget=16),
]


def assert_bitwise_equal(a, b, label):
    import jax
    fa = jax.tree_util.tree_leaves(a.final_state)
    fb = jax.tree_util.tree_leaves(b.final_state)
    for x, y in zip(fa, fb, strict=True):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=label)
    for k in a.timeline:
        np.testing.assert_array_equal(a.timeline[k], b.timeline[k],
                                      err_msg=f"{label}: tl/{k}")


def test_sweep_lanes_bitwise_identical_with_telemetry():
    """The tentpole guarantee: tracing-on blocked-engine outputs are
    bit-identical to telemetry-off, and the spans/counters recorded the
    run's window classification."""
    mc = tiny_machine()
    tr_a = random_trace(mc, seed=41, free_at=30, name="a")
    tr_b = random_trace(mc, seed=42, name="b")
    ccs = [CostConfig(), CostConfig(nvmm_read=1500)]
    trs = [tr_a, tr_b]

    plain = sweep_lanes(mc, ccs, TELEM_POLICIES, trs)
    tel = Telemetry(tracing=True)
    traced = sweep_lanes(mc, ccs, TELEM_POLICIES, trs, telemetry=tel)
    for i, (p, t) in enumerate(zip(plain, traced)):
        assert_bitwise_equal(p, t, f"lane {i}")

    m = tel.metrics
    assert m.value("sweep.calls", engine="blocked") == 1
    assert m.value("sweep.lanes", engine="blocked") == 2
    n_windows = (m.value("sweep.windows_fast")
                 + m.value("sweep.windows_event"))
    assert n_windows == 1, "64-step trace, block=64 -> one window"
    names = tel.tracer.span_names()
    assert names.count("sweep.prepare") == 1
    assert names.count("sweep.device") == 1
    assert sum(n.startswith("window.") for n in names) == n_windows
    assert m.value("sweep.device_seconds")["count"] == 1


def test_simulator_bitwise_identical_with_telemetry():
    mc = tiny_machine()
    tr = random_trace(mc, steps=160, seed=43, free_at=100)
    pc = TELEM_POLICIES[1]
    plain = TieredMemSimulator(mc=mc, pc=pc).run(tr)
    tel = Telemetry(tracing=True)
    traced = TieredMemSimulator(mc=mc, pc=pc, telemetry=tel).run(tr)
    assert_bitwise_equal(plain, traced, "simulator")

    m = tel.metrics
    assert m.value("sim.runs", engine="blocked") == 1
    n_windows = m.value("sim.windows_fast") + m.value("sim.windows_event")
    assert n_windows == math.ceil(160 / 64)
    names = tel.tracer.span_names()
    assert names.count("sim.run") == 1
    assert sum(n.startswith("window.") for n in names) == n_windows


# ---------------------------------------------------------------------------
# the acceptance burst: 64 mixed queries, exact snapshot, loadable trace
# ---------------------------------------------------------------------------
def burst_machine():
    """Distinct shape/config from every other test so the XLA compile
    count measured here is this burst's own, not a jit-cache hit from a
    sibling test in the same process."""
    return MachineConfig(n_threads=4, dram_pages_per_node=280,
                         nvmm_pages_per_node=1120, va_pages=1 << 10,
                         l1_tlb_sets=4, l1_tlb_ways=2, stlb_sets=8,
                         stlb_ways=4, pde_pwc_entries=4, pdpte_pwc_entries=2)


def test_64_query_burst_snapshot_and_trace(tmp_path):
    mc = burst_machine()
    policies = [PolicyConfig(data_policy=d, pt_policy=p, autonuma=False)
                for d in (FIRST_TOUCH, INTERLEAVE)
                for p in (PT_FOLLOW_DATA, PT_BIND_HIGH)]
    traces = [random_trace(mc, steps=96, seed=300 + i, name=f"b{i}")
              for i in range(16)]
    queries = [SimQuery(trace=tr, policy=pc, machine=mc)
               for tr in traces for pc in policies]
    assert len(queries) == 64

    tel = Telemetry(tracing=True)
    broker = SimBroker(max_lanes=64, telemetry=tel)
    before = sweep_compile_count()
    broker.run(queries)
    assert sweep_compile_count() == before + 1

    bkey = broker._bucket_key(queries[0],
                              broker.canonical_trace(queries[0]))
    blabel = _bucket_label(bkey)
    m = tel.metrics

    # exact figures: one bucket, one flush, one compile, 64 distinct
    # lanes, zero padding (64 is already a power of two), zero hits yet
    assert m.value("broker.queries") == 64
    assert m.value("broker.compiles", bucket=blabel) == 1
    assert m.value("broker.flushes", bucket=blabel) == 1
    assert m.value("broker.lanes_run", bucket=blabel) == 64
    assert m.value("broker.pad_lanes", bucket=blabel) == 0
    assert m.value("broker.cache_hits") is None
    assert m.value("cache.mem.misses") == 64
    assert m.value("broker.queue_wait_seconds")["count"] == 64
    assert m.value("broker.flush_seconds")["count"] == 1
    assert m.value("sweep.lanes", engine="blocked") == 64
    # summary lifts: one per-family counter line for the whole burst
    assert m.value("sim.promotions", family="autonuma") == 0
    assert m.value("sim.data_pages", tier=0) is not None

    # replay: answered entirely from cache — no new flush/lanes/compiles
    broker.run(queries)
    assert m.value("broker.queries") == 128
    assert m.value("broker.cache_hits") == 64
    assert m.value("cache.mem.hits") == 64
    assert m.value("broker.lanes_run", bucket=blabel) == 64
    assert m.value("broker.compiles", bucket=blabel) == 1

    # broker.snapshot() is the blessed artifact payload and agrees
    snap = broker.snapshot()
    assert snap["broker"]["queries"] == 128
    assert snap["broker"]["compiles"] == 1
    assert snap["broker"]["lanes_run"] == 64
    assert snap["broker"]["pad_lanes"] == 0
    assert snap["broker"]["pad_ratio"] == 0.0
    assert snap["broker"]["cache_hits"] == 64
    assert snap["cache"]["hits"] == 64 and snap["cache"]["misses"] == 64
    assert snap["pending_lanes"] == 0
    assert snap["telemetry"]["metrics"][f"broker.compiles{{bucket={blabel}}}"] == 1

    # one span per lifecycle stage: every query admits (both passes),
    # every distinct lane queues, the bucket flushes/sweeps/resolves once
    names = tel.tracer.span_names()
    assert names.count("query.admit") == 128
    assert names.count("query.queue") == 64
    assert names.count("bucket.flush") == 1
    assert names.count("sweep.device") == 1
    assert names.count("query.resolve") == 1
    assert sum(n.startswith("window.") for n in names) >= 1
    admits = [e for e in tel.tracer.events
              if e.get("name") == "query.admit" and e["ph"] == "X"]
    assert sum(e["args"]["cache_hit"] for e in admits) == 64

    # the exported trace is well-formed, balanced, Perfetto-loadable JSON
    path = tmp_path / "burst_trace.json"
    assert tel.export_trace(path)
    obj = json.loads(path.read_text())
    assert validate_trace_events(obj) == []
    assert validate_cli.main([str(path)]) == 0


def test_burst_pad_lanes_ratio_counted():
    """A 3-lane flush pads to 4: the pad shows up in both the raw counter
    and the ratio, in stats and registry alike."""
    mc = burst_machine()
    tr = random_trace(mc, steps=96, seed=400)
    tel = Telemetry()
    broker = SimBroker(max_lanes=64, max_wait=1e9, telemetry=tel)
    futs = [broker.submit(SimQuery(trace=tr, policy=pc, machine=mc))
            for pc in [PolicyConfig(data_policy=d, autonuma=False)
                       for d in (FIRST_TOUCH, INTERLEAVE)]
            + [PolicyConfig(pt_policy=PT_BIND_HIGH, autonuma=False)]]
    futs[0].result()
    assert broker.stats.pad_lanes == 1 and broker.stats.pad_ratio == 0.25
    bkey = broker._bucket_key(futs[0].query,
                              broker.canonical_trace(futs[0].query))
    assert tel.metrics.value("broker.pad_lanes",
                             bucket=_bucket_label(bkey)) == 1
