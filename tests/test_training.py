"""Training stack: optimizer numerics, loss-goes-down, factored parity,
grad compression fidelity, data pipeline determinism/elasticity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import DataConfig, batch_at
from repro.models import make_params
from repro.training import optimizer as opt_mod
from repro.training.train import (TrainConfig, dequantize_int8,
                                  make_train_step, quantize_int8)


def test_adamw_matches_reference():
    cfg = opt_mod.OptConfig(lr=1e-2, warmup_steps=0, total_steps=10**9,
                            weight_decay=0.0, grad_clip=1e9)
    params = {"w": jnp.asarray([1.0, -2.0, 3.0], jnp.float32)}
    grads = {"w": jnp.asarray([0.1, 0.2, -0.3], jnp.float32)}
    state = opt_mod.init_opt_state(params)
    new_p, state = opt_mod.adamw_update(cfg, params, grads, state)
    # closed-form first step: m_hat = g, v_hat = g^2  =>  delta = sign(g)
    lr = float(opt_mod.schedule(cfg, state["step"]))
    want = np.asarray([1.0, -2.0, 3.0]) - lr * np.sign([0.1, 0.2, -0.3])
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, atol=1e-4)


def test_factored_update_runs_and_tracks_adamw():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (16, 8), jnp.float32)}
    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (16, 8),
                                    jnp.float32) * 0.1}
    full = opt_mod.init_opt_state(params)
    fact = opt_mod.init_opt_state(params, factored=True)
    cfg_full = opt_mod.OptConfig(lr=1e-3, warmup_steps=0)
    cfg_fact = opt_mod.OptConfig(lr=1e-3, warmup_steps=0, factored=True)
    p1, _ = opt_mod.adamw_update(cfg_full, params, grads, full)
    p2, _ = opt_mod.adamw_update(cfg_fact, params, grads, fact)
    # same direction, comparable magnitude (factored v is an approximation)
    d1 = np.asarray(p1["w"] - params["w"]).ravel()
    d2 = np.asarray(p2["w"] - params["w"]).ravel()
    cos = d1 @ d2 / (np.linalg.norm(d1) * np.linalg.norm(d2))
    assert cos > 0.7, cos


def test_int8_roundtrip_error_bound():
    g = jax.random.normal(jax.random.PRNGKey(0), (256,), jnp.float32)
    q, scale = quantize_int8(g)
    back = dequantize_int8(q, scale)
    assert float(jnp.max(jnp.abs(back - g))) <= float(scale) * 0.5 + 1e-6


def test_loss_decreases_small_model():
    cfg = configs.reduced(configs.get_config("qwen1.5-0.5b"))
    key = jax.random.PRNGKey(0)
    params = make_params(cfg, key)
    tc = TrainConfig(opt=opt_mod.OptConfig(lr=3e-3, warmup_steps=5,
                                           total_steps=10000))
    step = jax.jit(make_train_step(cfg, tc))
    opt_state = opt_mod.init_opt_state(params)
    dc = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
    losses = []
    for i in range(30):
        batch = batch_at(dc, i)
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_microbatching_matches_full_batch():
    cfg = configs.reduced(configs.get_config("qwen1.5-0.5b"))
    key = jax.random.PRNGKey(0)
    tc1 = TrainConfig(microbatches=1)
    tc4 = TrainConfig(microbatches=4)
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    batch = batch_at(dc, 0)
    outs = []
    for tc in (tc1, tc4):
        params = make_params(cfg, key)
        opt_state = opt_mod.init_opt_state(params)
        step = jax.jit(make_train_step(cfg, tc))
        params, _, metrics = step(params, opt_state, batch)
        outs.append((params, metrics))
    np.testing.assert_allclose(float(outs[0][1]["loss"]),
                               float(outs[1][1]["loss"]), rtol=2e-2)
    for a, b in zip(jax.tree.leaves(outs[0][0]), jax.tree.leaves(outs[1][0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=3e-2)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_deterministic_and_elastic():
    dc = DataConfig(vocab=1000, seq_len=32, global_batch=8)
    b1 = batch_at(dc, 7)
    b2 = batch_at(dc, 7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # host shards concatenate to the global batch, for any host count
    for n_hosts in (2, 4):
        per = dc.global_batch // n_hosts
        shards = [batch_at(dc, 7, host_rows=(h * per, per))["tokens"]
                  for h in range(n_hosts)]
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(s) for s in shards]),
            np.asarray(b1["tokens"]))


def test_data_targets_shifted():
    dc = DataConfig(vocab=1000, seq_len=16, global_batch=2)
    b = batch_at(dc, 0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["targets"][:, :-1]))
