"""Model-zoo smoke + consistency tests (reduced configs, 1 CPU device).

Every assigned architecture instantiates its reduced variant and runs one
train loss (finite, ~ln(vocab) at init) and, where applicable, prefill +
one decode step.  ``test_prefill_decode_consistency`` checks the strongest
invariant: decoding token-by-token reproduces the full-sequence forward.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import (decode_step, forward, init_decode_state,
                          input_specs, lm_loss, make_params, prefill)


def make_batch(cfg, key, B, S, kind="train"):
    spec = input_specs(cfg, S, B, kind)
    batch = {}
    for k, v in spec.items():
        if v.dtype == jnp.int32:
            batch[k] = jax.random.randint(key, v.shape, 0, cfg.vocab)
        else:
            batch[k] = (jax.random.normal(key, v.shape, jnp.float32)
                        * 0.02).astype(v.dtype)
    if "mrope_pos" in batch:
        batch["mrope_pos"] = jnp.tile(
            jnp.arange(S, dtype=jnp.int32)[None, :, None], (B, 1, 3))
    return batch


@pytest.mark.parametrize("arch_id", configs.ARCH_IDS)
def test_arch_smoke(arch_id):
    cfg = configs.reduced(configs.get_config(arch_id))
    key = jax.random.PRNGKey(0)
    params = make_params(cfg, key)
    B, S = 2, 64
    batch = make_batch(cfg, key, B, S, "train")
    loss = jax.jit(lambda p, b: lm_loss(cfg, p, b))(params, batch)
    assert jnp.isfinite(loss)
    # random init => loss ~ uniform over the vocab (tied embeddings skew
    # the init distribution, hence the loose bound)
    assert abs(float(loss) - np.log(cfg.vocab)) < 2.0, float(loss)
    if cfg.has_decode:
        pre = {k: v for k, v in batch.items() if k != "targets"}
        logits, _ = jax.jit(lambda p, b: prefill(cfg, p, b))(params, pre)
        assert logits.shape == (B, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        state = init_decode_state(cfg, B, S + 4)
        state, lg = jax.jit(
            lambda p, s, t: decode_step(cfg, p, s, t,
                                        jnp.asarray(S, jnp.int32)))(
            params, state, jnp.zeros((B,), jnp.int32))
        assert np.isfinite(np.asarray(lg, np.float32)).all()


ATOL = {"qwen1.5-0.5b": 0.12, "jamba-v0.1-52b": 0.12,
        # rwkv's data-dependent decay round-trips through bf16 twice per
        # token in decode but once per chunk in the parallel path
        "rwkv6-3b": 0.35}


@pytest.mark.parametrize("arch_id", ["qwen1.5-0.5b", "rwkv6-3b",
                                     "jamba-v0.1-52b"])
def test_prefill_decode_consistency(arch_id):
    """Teacher-forced decode must reproduce the parallel forward pass."""
    cfg = configs.reduced(configs.get_config(arch_id))
    key = jax.random.PRNGKey(1)
    params = make_params(cfg, key)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)

    # full forward logits at every position
    h, _, _ = forward(cfg, params, {"tokens": toks}, remat_policy="none")
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    full_logits = jnp.einsum("bsd,dv->bsv", h, head)

    # token-by-token decode
    state = init_decode_state(cfg, B, S)
    outs = []
    step = jax.jit(lambda p, s, t, i: decode_step(cfg, p, s, t, i))
    for t in range(S):
        state, lg = step(params, state, toks[:, t],
                         jnp.asarray(t, jnp.int32))
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)

    a = np.asarray(dec_logits, np.float32)
    b = np.asarray(full_logits, np.float32)
    tol = ATOL[arch_id]
    np.testing.assert_allclose(a, b, atol=tol, rtol=tol)
    # random-init logits are near-ties, so argmax is not a stable check;
    # bound the mean deviation instead (bf16 accumulation-order noise)
    assert np.abs(a - b).mean() < 0.02, np.abs(a - b).mean()


def test_vlm_loss_uses_text_positions_only():
    cfg = configs.reduced(configs.get_config("qwen2-vl-2b"))
    key = jax.random.PRNGKey(2)
    params = make_params(cfg, key)
    batch = make_batch(cfg, key, 2, 64, "train")
    loss = lm_loss(cfg, params, batch)
    assert jnp.isfinite(loss)
    assert batch["tokens"].shape[1] == 48      # 3/4 text split
    assert batch["patch_embeds"].shape[1] == 16
