"""Serving engine: scheduling, Radiant table maintenance, fault-free runs."""
import jax.numpy as jnp
import numpy as np

from repro.memsys import tiered_kv as tkv
from repro.serving.engine import Request, TieredServingEngine


def toy_decode(kv, rid):
    G, _, bs, KH, Dh = kv.hot_k.shape
    k = jnp.full((G, KH, Dh), (rid + 1) * 0.01, jnp.bfloat16)
    return k, k


def build(radiant=True, n_hot=32):
    eng = TieredServingEngine(n_groups=1, kv_heads=1, head_dim=128,
                              block_size=8, n_hot_blocks=n_hot,
                              n_cold_blocks=256, n_seqs=6, max_seq=96,
                              active_slots=2, radiant=radiant)
    for rid in range(6):
        eng.submit(Request(rid=rid, prompt_len=24, max_new=8))
        ks = jnp.ones((24, 1, 1, 128), jnp.bfloat16) * (rid + 1)
        eng.requests[rid] = eng.requests[rid]
        eng.prefill(rid, (ks, ks))
    return eng


def test_all_requests_complete():
    eng = build()
    stats = eng.run(toy_decode, max_ticks=500)
    assert all(r.state == "done" for r in eng.requests.values())
    assert stats.tokens == 6 * 8


def test_radiant_no_cold_walks_and_invariant():
    eng = build(radiant=True, n_hot=12)   # pressure: 6 seqs x 4 blocks
    stats = eng.run(toy_decode, max_ticks=500)
    assert stats.cold_walks == 0
    assert int(tkv.table_invariant_violations(eng.kv)) == 0
    assert int(np.asarray(eng.kv.stats)[tkv.STAT_LEAF_PROMOTE]) > 0


def test_immobile_tables_pay_cold_walks():
    eng = build(radiant=False, n_hot=12)
    stats = eng.run(toy_decode, max_ticks=500)
    assert stats.cold_walks > 0         # the paper's baseline pathology


def test_release_recycles_pool():
    eng = build()
    eng.run(toy_decode, max_ticks=500)
    kv = eng.kv
    # everything freed: full free lists
    assert int(kv.hot_free_top) == kv.hot_k.shape[1]
    assert int(kv.cold_free_top) == kv.cold_k.shape[1]
    assert int(kv.leaf_free_top) == kv.leaf_tier.shape[0]
