"""N-tier machine model + TPP/Nomad policy families, locked to the oracle.

Four lock points:

1. **Degenerate tiers** — a 3-tier machine whose middle tier has zero
   capacity reproduces the classic 2-tier machine bit-for-bit (cycles,
   counters, timelines; placements up to the tier-major node renaming)
   for every pre-existing policy bundle.
2. **TPP / Nomad vs oracle** — the new migration families running through
   the production blocked/batched engine match the pure-Python
   ``OracleSim`` exactly on counters and placements (cycles to f32
   rounding), including the Nomad transactional counters, and blocked
   stays bit-identical to the retained ``per_step`` reference.
3. **Property fuzz** — random traces x random (tier count, capacities,
   policy family, cost model): blocked == oracle.  Runs under hypothesis
   when available, with a seeded deterministic fallback (the
   ``tests/test_memsys.py`` pattern).
4. **Fault-schedule invariants** — the host conflict model holds under
   N-tier machines: DO bits equal an independent mapped-ness replay,
   exactly one WINNER per (step, granule), and every bit is monotone in
   the trace prefix (``fault_schedule(tr[:k]) == fault_schedule(tr)[:k]``).

Plus the reference-path gate: ``engine="per_step"`` / ``phase_b=
"sequential"`` are debug-only everywhere (simulator, sweep, service).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # property tests skip; the rest run
    HAVE_HYPOTHESIS = False

from repro.core import (CostConfig, MachineConfig, PolicyConfig,
                        TieredMemSimulator, Trace, fault_schedule,
                        sweep_compile_count, sweep_lanes,
                        FIRST_TOUCH, INTERLEAVE, MIG_NOMAD, MIG_TPP,
                        PT_BIND_ALL, PT_BIND_HIGH, PT_FOLLOW_DATA,
                        nomad, tpp)
from repro.core.ref import OracleSim
from repro.core.sim import (SCHED_DO, SCHED_NEED_LEAF, SCHED_NEED_MID,
                            SCHED_NEED_ROOT, SCHED_NEED_TOP, SCHED_WINNER)
from repro.service import SimBroker, SimQuery

EXACT_KEYS = ("l1_hits", "stlb_hits", "walks", "walk_mem_reads", "faults",
              "slow_allocs", "data_migrations", "demotions",
              "l4_mig_success", "l4_mig_already_dest", "l4_mig_in_dram",
              "l4_mig_sibling_guard", "l4_mig_lock_skip",
              "data_pages_dram", "data_pages_nvmm",
              "leaf_pages_dram", "leaf_pages_nvmm", "oom_killed", "oom_step",
              # N-tier / policy-family extensions
              "data_pages_per_tier", "leaf_pages_per_tier", "shadow_pages",
              "nomad_retries", "nomad_flip_demotions", "nomad_shadow_drops")
CYCLE_KEYS = ("total_cycles", "walk_cycles", "stall_cycles",
              "data_mem_cycles", "fault_cycles", "migration_cycles")
PLACEMENT_ARRAYS = ("data_node", "leaf_node", "mid_node", "top_node",
                    "root_node", "node_free", "shadow_node")

TLB_KW = dict(l1_tlb_sets=4, l1_tlb_ways=2, stlb_sets=8, stlb_ways=4,
              pde_pwc_entries=4, pdpte_pwc_entries=2)


def tiny_machine(tiers=None, **kw):
    """Small machine; ``tiers`` is pages-per-node fastest-first, default
    the classic 2-tier (600, 2400)."""
    kw.setdefault("n_threads", 4)
    kw.setdefault("va_pages", 1 << 12)
    if tiers is None:
        return MachineConfig(dram_pages_per_node=600,
                             nvmm_pages_per_node=2400, **TLB_KW, **kw)
    return MachineConfig(tier_pages_per_node=tuple(tiers), **TLB_KW, **kw)


def random_trace(mc, steps=160, seed=0, free_at=None, write_p=0.3,
                 name="rand"):
    rng = np.random.default_rng(seed)
    T = mc.n_threads
    va = np.where(rng.random((steps, T)) < 0.5,
                  rng.integers(0, mc.va_pages // 2, (steps, T)),
                  rng.integers(0, mc.va_pages, (steps, T))).astype(np.int32)
    va[rng.random((steps, T)) < 0.05] = -1       # idle slots
    wr = rng.random((steps, T)) < write_p
    free_seg = np.full((steps,), -1, np.int32)
    if free_at is not None:
        free_seg[free_at] = 0
    seg = np.zeros((mc.n_map,), np.int32)
    seg[mc.n_map // 2:] = 1
    llc = np.full((steps,), 0.4, np.float32)
    return Trace(va=va, is_write=wr, free_seg=free_seg, llc=llc,
                 seg_of_map=seg, name=name)


def assert_matches_oracle(res, mc, cc, pc, trace):
    oracle = OracleSim(mc, cc, pc)
    oracle.run(trace)
    ref = oracle.summary()
    s = res.summary()
    for k in EXACT_KEYS:
        assert s[k] == ref[k], f"{pc.label()}: {k}: jax={s[k]} oracle={ref[k]}"
    for k in CYCLE_KEYS:
        np.testing.assert_allclose(s[k], ref[k], rtol=1e-5,
                                   err_msg=f"{pc.label()}: {k}")


def assert_results_bitwise(a, b, label=""):
    for arr in PLACEMENT_ARRAYS:
        np.testing.assert_array_equal(
            np.asarray(getattr(a.final_state, arr)),
            np.asarray(getattr(b.final_state, arr)),
            err_msg=f"{label}: {arr}")
    for k in a.timeline:
        np.testing.assert_array_equal(a.timeline[k], b.timeline[k],
                                      err_msg=f"{label}: tl/{k}")


# ---------------------------------------------------------------------------
# 1. Degenerate tiers: zero-capacity middle tier == the 2-tier machine
# ---------------------------------------------------------------------------

# Every pre-existing policy shape: data x PT x mig x autonuma(exchange)
DEGENERATE_POLICIES = [
    PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_FOLLOW_DATA,
                 mig=False, autonuma=False),
    PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_BIND_HIGH, mig=True,
                 autonuma=True, autonuma_period=16, autonuma_budget=32),
    PolicyConfig(data_policy=INTERLEAVE, pt_policy=PT_BIND_HIGH, mig=True,
                 autonuma=True, autonuma_period=16, autonuma_budget=32),
    PolicyConfig(data_policy=INTERLEAVE, pt_policy=PT_BIND_ALL,
                 mig=False, autonuma=True, autonuma_period=16,
                 autonuma_budget=32, autonuma_exchange=False),
]


def remap_nodes(arr, nt):
    """2-tier node ids -> N-tier tier-major ids: the slow pair (2, 3)
    becomes the slowest tier's pair (2(nt-1), 2(nt-1)+1)."""
    arr = np.asarray(arr)
    return np.where(arr >= 2, arr + 2 * (nt - 2), arr)


@pytest.mark.parametrize("pidx", range(len(DEGENERATE_POLICIES)))
def test_zero_capacity_middle_tier_bitwise(pidx):
    """tier_pages_per_node=(600, 0, 2400) must reproduce the classic
    (600, 2400) machine bit-for-bit: same cycles, counters and timelines,
    placements equal under the tier-major node renaming, and the empty
    tier's nodes never allocated."""
    pc = DEGENERATE_POLICIES[pidx]
    mc2 = tiny_machine()
    mc3 = tiny_machine(tiers=(600, 0, 2400))
    assert mc3.alloc_nodes == (0, 1, 4, 5)
    cc = CostConfig()
    tr2 = random_trace(mc2, seed=pidx, free_at=100 if pidx == 1 else None)
    tr3 = Trace(va=tr2.va, is_write=tr2.is_write, free_seg=tr2.free_seg,
                llc=tr2.llc, seg_of_map=tr2.seg_of_map, name="rand3")
    r2 = TieredMemSimulator(mc=mc2, cc=cc, pc=pc).run(tr2)
    r3 = TieredMemSimulator(mc=mc3, cc=cc, pc=pc).run(tr3)

    s2, s3 = r2.summary(), r3.summary()
    for k in EXACT_KEYS:
        if k.endswith("per_tier"):
            continue                     # shapes differ; checked below
        assert s2[k] == s3[k], f"{pc.label()}: {k}: {s2[k]} != {s3[k]}"
    for k in CYCLE_KEYS:                 # bitwise, not rtol: same f32 ops
        assert s2[k] == s3[k], f"{pc.label()}: {k}: {s2[k]} != {s3[k]}"
    assert s3["data_pages_per_tier"] == [s2["data_pages_per_tier"][0], 0,
                                         s2["data_pages_per_tier"][1]]
    assert s3["leaf_pages_per_tier"] == [s2["leaf_pages_per_tier"][0], 0,
                                         s2["leaf_pages_per_tier"][1]]
    for k in r2.timeline:
        np.testing.assert_array_equal(r2.timeline[k], r3.timeline[k],
                                      err_msg=f"{pc.label()}: tl/{k}")
    for arr in ("data_node", "leaf_node", "mid_node", "top_node",
                "root_node", "shadow_node"):
        np.testing.assert_array_equal(
            remap_nodes(getattr(r2.final_state, arr), 3),
            np.asarray(getattr(r3.final_state, arr)),
            err_msg=f"{pc.label()}: {arr}")
    free3 = np.asarray(r3.final_state.node_free)
    np.testing.assert_array_equal(free3[[0, 1, 4, 5]],
                                  np.asarray(r2.final_state.node_free))
    np.testing.assert_array_equal(free3[[2, 3]], [0, 0])


# ---------------------------------------------------------------------------
# 2. TPP / Nomad locked to the oracle on a genuine 3-tier machine
# ---------------------------------------------------------------------------

TIER3 = (600, 1200, 2400)
FAMILY_POLICIES = [
    tpp(autonuma_period=16, autonuma_budget=32),
    tpp(data_policy=INTERLEAVE, demote_wm=0.05, autonuma_period=16,
        autonuma_budget=32),
    nomad(autonuma_period=16, autonuma_budget=32),
    PolicyConfig(data_policy=INTERLEAVE, pt_policy=PT_BIND_HIGH, mig=True,
                 autonuma=True, mig_policy=MIG_NOMAD,
                 autonuma_period=16, autonuma_budget=32),
]


@pytest.mark.parametrize("pidx", range(len(FAMILY_POLICIES)))
def test_tpp_nomad_oracle_equivalence(pidx):
    mc = tiny_machine(tiers=TIER3)
    pc = FAMILY_POLICIES[pidx]
    cc = CostConfig()
    tr = random_trace(mc, seed=30 + pidx, free_at=100 if pidx >= 2 else None)
    res = TieredMemSimulator(mc=mc, cc=cc, pc=pc).run(tr)
    assert_matches_oracle(res, mc, cc, pc, tr)
    # blocked engine stays bit-identical to the per-step reference under
    # the new families (the retained oracle path, satellite 4)
    ps = TieredMemSimulator(mc=mc, cc=cc, pc=pc, engine="per_step",
                            debug=True).run(tr)
    assert_results_bitwise(res, ps, f"{pc.label()}: blocked vs per_step")


def test_tpp_nomad_under_memory_pressure():
    """Footprint >> DRAM so the TPP demotion watermark and the Nomad
    abort/shadow machinery actually fire; counters must prove it."""
    mc = tiny_machine(tiers=(200, 400, 1600), va_pages=1 << 11)
    cc = CostConfig()
    saw_demotions = saw_nomad = False
    for i, pc in enumerate((tpp(demote_wm=0.10, autonuma_period=16,
                                autonuma_budget=32),
                            nomad(autonuma_period=16, autonuma_budget=32))):
        tr = random_trace(mc, steps=256, seed=60 + i, write_p=0.5)
        res = TieredMemSimulator(mc=mc, cc=cc, pc=pc).run(tr)
        assert_matches_oracle(res, mc, cc, pc, tr)
        s = res.summary()
        if int(pc.mig_policy) == MIG_TPP:
            saw_demotions = s["demotions"] > 0
        else:
            saw_nomad = (s["nomad_retries"] + s["nomad_flip_demotions"]
                         + s["nomad_shadow_drops"] + s["shadow_pages"]) > 0
    assert saw_demotions, "TPP never demoted under 8x-DRAM pressure"
    assert saw_nomad, "Nomad transactional machinery never engaged"


def test_nomad_abort_retry_oracle_locked():
    """A churn trace — hot set larger than DRAM, write-heavy — forces
    promotion aborts on concurrent writes; the transactional retry path
    must actually fire and stay exact against the oracle."""
    mc = tiny_machine(tiers=(150, 300, 1600), va_pages=1 << 11)
    rng = np.random.default_rng(2)
    steps, T = 256, mc.n_threads
    va = rng.integers(0, 512, (steps, T)).astype(np.int32)
    wr = rng.random((steps, T)) < 0.9
    tr = Trace(va=va, is_write=wr,
               free_seg=np.full(steps, -1, np.int32),
               llc=np.full(steps, 0.4, np.float32),
               seg_of_map=np.zeros(mc.n_map, np.int32), name="churn")
    pc = nomad(autonuma_period=16, autonuma_budget=64)
    cc = CostConfig()
    res = TieredMemSimulator(mc=mc, cc=cc, pc=pc).run(tr)
    assert_matches_oracle(res, mc, cc, pc, tr)
    s = res.summary()
    assert s["nomad_retries"] > 0, "abort/retry path not exercised"
    assert s["nomad_flip_demotions"] > 0 and s["shadow_pages"] > 0


def test_tpp_nomad_sweep_lanes_and_broker_bitwise():
    """The new policy codes flow through the batched sweep engine and the
    service broker bit-identically to solo runs (acceptance criterion)."""
    mc = tiny_machine(tiers=TIER3)
    cc = CostConfig()
    pols = [tpp(autonuma_period=16, autonuma_budget=32),
            nomad(autonuma_period=16, autonuma_budget=32),
            PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_BIND_HIGH,
                         mig=True, autonuma=True, autonuma_period=16,
                         autonuma_budget=32)]
    tr = random_trace(mc, seed=77, write_p=0.4)
    solos = [TieredMemSimulator(mc=mc, cc=cc, pc=pc).run(tr) for pc in pols]

    lanes = sweep_lanes(mc, [cc] * len(pols), pols, [tr] * len(pols))
    for pc, lane, solo in zip(pols, lanes, solos):
        assert_results_bitwise(lane, solo, f"sweep_lanes/{pc.label()}")

    broker = SimBroker(max_lanes=len(pols))
    results = broker.run([SimQuery(trace=tr, policy=pc, cost=cc, machine=mc)
                          for pc in pols])
    for pc, res, solo in zip(pols, results, solos):
        assert_results_bitwise(res, solo, f"broker/{pc.label()}")


def test_broker_compiles_once_per_tier_topology():
    """Bucket keys include the machine: a burst mixing 2-tier and 3-tier
    queries of one trace shape compiles exactly once per topology, and a
    second burst with fresh trace content compiles zero more."""
    mc2 = tiny_machine()
    mc3 = tiny_machine(tiers=TIER3)
    pols = [tpp(autonuma_period=16, autonuma_budget=32),
            nomad(autonuma_period=16, autonuma_budget=32)]
    broker = SimBroker(max_lanes=64, max_wait=0.0)

    def burst(seed):
        qs = [SimQuery(trace=random_trace(mc, seed=seed + i, name=f"b{seed}"),
                       policy=pc, machine=mc)
              for i, mc in enumerate((mc2, mc3)) for pc in pols]
        return broker.run(qs)

    before = sweep_compile_count()
    burst(500)
    assert sweep_compile_count() == before + 2, \
        "expected one compile per (tier topology, trace shape) bucket"
    burst(600)
    assert sweep_compile_count() == before + 2, \
        "same buckets, new trace content must reuse both compiled programs"


# ---------------------------------------------------------------------------
# 3. Property fuzz: random traces x random (tiers, policy family, cost)
# ---------------------------------------------------------------------------

def fuzz_case(seed):
    """Derive a full (machine, cost, policy, trace) case from one seed and
    check blocked == oracle."""
    rng = np.random.default_rng(seed)
    n_tiers = int(rng.integers(2, 5))
    mids = [int(rng.choice([0, 300, 800])) for _ in range(n_tiers - 2)]
    tiers = (int(rng.choice([200, 600])), *mids,
             int(rng.choice([1600, 2400])))
    mc = tiny_machine(tiers=tiers, va_pages=1 << 11)
    cc = CostConfig(cxl_read=int(rng.choice([300, 450, 600])),
                    cxl_write=int(rng.choice([400, 500, 700])),
                    nvmm_read=int(rng.choice([600, 750, 900])))
    family = int(rng.choice([0, MIG_TPP, MIG_NOMAD]))
    kw = dict(data_policy=int(rng.choice([FIRST_TOUCH, INTERLEAVE])),
              pt_policy=int(rng.choice([PT_FOLLOW_DATA, PT_BIND_HIGH])),
              mig=bool(rng.random() < 0.5), autonuma=True,
              autonuma_period=16, autonuma_budget=32)
    if family == MIG_TPP:
        pc = PolicyConfig(mig_policy=MIG_TPP,
                          tpp_demote_wm=float(rng.choice([0.0, 0.05])), **kw)
    elif family == MIG_NOMAD:
        pc = PolicyConfig(mig_policy=MIG_NOMAD, **kw)
    else:
        pc = PolicyConfig(**kw)
    tr = random_trace(mc, steps=96, seed=seed,
                      free_at=48 if rng.random() < 0.5 else None,
                      write_p=0.4)
    res = TieredMemSimulator(mc=mc, cc=cc, pc=pc).run(tr)
    assert_matches_oracle(res, mc, cc, pc, tr)


@pytest.mark.parametrize("seed", range(3))
def test_fuzz_blocked_vs_oracle_fixed_seeds(seed):
    """Deterministic property-style coverage (runs without hypothesis)."""
    fuzz_case(seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(st.integers(min_value=3, max_value=10 ** 6))
    def test_property_blocked_vs_oracle(seed):
        fuzz_case(seed)


# ---------------------------------------------------------------------------
# 4. fault_schedule invariants under the N-tier model
# ---------------------------------------------------------------------------

def replay_miss_set(tr, mc):
    """Independent mapped-ness replay: bool[steps, threads] of phase-A
    misses (active access to an unmapped granule), first-winner mapping."""
    va = np.asarray(tr.va)
    seg = np.asarray(tr.seg_of_map)
    free_seg = np.asarray(tr.free_seg)
    mapped = np.zeros(mc.n_map, bool)
    miss = np.zeros(va.shape, bool)
    for s in range(va.shape[0]):
        if free_seg[s] >= 0:
            mapped[seg == free_seg[s]] = False
        for t in range(va.shape[1]):
            if va[s, t] < 0:
                continue
            m = min(int(va[s, t]) >> mc.map_shift, mc.n_map - 1)
            if not mapped[m]:
                miss[s, t] = True
        # all of this step's winners map their granules afterwards
        for t in range(va.shape[1]):
            if miss[s, t]:
                mapped[min(int(va[s, t]) >> mc.map_shift, mc.n_map - 1)] = True
    return miss


def prefix_trace(tr, k):
    return Trace(va=tr.va[:k], is_write=tr.is_write[:k],
                 free_seg=tr.free_seg[:k], llc=tr.llc[:k],
                 seg_of_map=tr.seg_of_map, name=f"{tr.name}[:{k}]")


@pytest.mark.parametrize("seed", range(3))
def test_fault_schedule_invariants(seed):
    mc = tiny_machine(tiers=TIER3, va_pages=1 << 11)
    tr = random_trace(mc, steps=128, seed=seed, free_at=64)
    sched = fault_schedule(tr, mc)
    va = np.asarray(tr.va)

    # (a) DO bits == the phase-A miss set of an independent replay
    np.testing.assert_array_equal((sched & SCHED_DO) > 0,
                                  replay_miss_set(tr, mc))

    # (b) exactly one WINNER per (step, granule); the winner is the
    #     lowest-indexed DO thread of its granule; WINNER implies DO
    do = (sched & SCHED_DO) > 0
    win = (sched & SCHED_WINNER) > 0
    assert not (win & ~do).any()
    for s in range(va.shape[0]):
        gran = {}
        for t in np.where(do[s])[0]:
            m = min(int(va[s, t]) >> mc.map_shift, mc.n_map - 1)
            gran.setdefault(m, []).append(t)
        for m, threads in gran.items():
            w = [t for t in threads if win[s, t]]
            assert w == [threads[0]], \
                f"step {s} granule {m}: winners {w}, threads {threads}"

    # (c) NEED_* bits only on winners, and each level's existence set is
    #     claimed by at most one winner per step
    for bit in (SCHED_NEED_ROOT, SCHED_NEED_TOP, SCHED_NEED_MID,
                SCHED_NEED_LEAF):
        assert not (((sched & bit) > 0) & ~win).any()

    # (d) monotone in the trace prefix: every bit of the full schedule is
    #     reproduced by scheduling the prefix alone
    for k in (1, 37, 64, 100, 128):
        np.testing.assert_array_equal(fault_schedule(prefix_trace(tr, k), mc),
                                      sched[:k], err_msg=f"prefix {k}")


# ---------------------------------------------------------------------------
# 5. Reference paths are debug-only (simulator, sweep engine, service)
# ---------------------------------------------------------------------------

def test_reference_paths_require_debug_flag():
    mc = tiny_machine()
    pc = PolicyConfig(autonuma=False)
    tr = random_trace(mc, steps=16, seed=1)
    with pytest.raises(ValueError, match="debug=True"):
        TieredMemSimulator(mc=mc, pc=pc, engine="per_step")
    with pytest.raises(ValueError, match="debug=True"):
        TieredMemSimulator(mc=mc, pc=pc, phase_b="sequential")
    with pytest.raises(ValueError, match="debug=True"):
        sweep_lanes(mc, [CostConfig()], [pc], [tr], engine="per_step")
    with pytest.raises(ValueError, match="debug=True"):
        sweep_lanes(mc, [CostConfig()], [pc], [tr], phase_b="sequential")
    with pytest.raises(ValueError, match="debug=True"):
        SimQuery(trace=tr, policy=pc, machine=mc, engine="per_step")
    with pytest.raises(ValueError, match="debug=True"):
        SimQuery(trace=tr, policy=pc, machine=mc, phase_b="sequential")
    # with the flag, the oracle paths still run (and still agree)
    ref = TieredMemSimulator(mc=mc, pc=pc, engine="per_step",
                             phase_b="sequential", debug=True).run(tr)
    prod = TieredMemSimulator(mc=mc, pc=pc).run(tr)
    assert_results_bitwise(prod, ref, "debug reference")


# ---------------------------------------------------------------------------
# 6. Per-tier summary fields pinned on 3- and 4-tier machines (oracle)
# ---------------------------------------------------------------------------

def test_per_tier_summary_fields_pinned_on_3_and_4_tier():
    """``RunResult.summary``'s per-tier placement lists, pinned on
    genuinely 3- and 4-tier machines: length == tier count, tier 0
    reconciles with the scalar dram fields and tiers 1+ with the scalar
    nvmm fields, pressure actually spreads pages past the fast tier, and
    every entry equals the pure-Python oracle's."""
    cases = [
        ((300, 600, 2400),
         tpp(demote_wm=0.05, autonuma_period=16, autonuma_budget=32), 70),
        ((300, 600, 1200, 4800),
         nomad(autonuma_period=16, autonuma_budget=32), 71),
    ]
    cc = CostConfig()
    for tiers, pc, seed in cases:
        mc = tiny_machine(tiers=tiers, va_pages=1 << 11)
        tr = random_trace(mc, steps=256, seed=seed, write_p=0.5)
        res = TieredMemSimulator(mc=mc, cc=cc, pc=pc).run(tr)
        s = res.summary()
        nt = len(tiers)
        assert len(s["data_pages_per_tier"]) == nt, tiers
        assert len(s["leaf_pages_per_tier"]) == nt, tiers
        # the legacy 2-tier scalars remain the fast/slower split
        assert s["data_pages_per_tier"][0] == s["data_pages_dram"]
        assert sum(s["data_pages_per_tier"][1:]) == s["data_pages_nvmm"]
        assert s["leaf_pages_per_tier"][0] == s["leaf_pages_dram"]
        assert sum(s["leaf_pages_per_tier"][1:]) == s["leaf_pages_nvmm"]
        assert sum(s["data_pages_per_tier"][1:]) > 0, \
            f"{tiers}: pressure never engaged the slower tiers"

        oracle = OracleSim(mc, cc, pc)
        oracle.run(tr)
        ref = oracle.summary()
        assert s["data_pages_per_tier"] == ref["data_pages_per_tier"], tiers
        assert s["leaf_pages_per_tier"] == ref["leaf_pages_per_tier"], tiers
        assert_matches_oracle(res, mc, cc, pc, tr)
