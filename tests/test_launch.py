"""Launch-layer units: HLO collective parsing, analytic FLOPs, cell
validity, and the checkpoint/restart fault-tolerance loop."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro import configs
from repro.configs.base import SHAPES, cell_is_valid
from repro.launch.analysis import model_flops, parse_collectives

SRC = str(Path(__file__).resolve().parent.parent / "src")

HLO = """
  %ag = bf16[16,128]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar.1 = f32[64]{0} all-reduce(%y), replica_groups={{0,1}}, to_apply=%sum
  %cp = bf16[8,8]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %rs = (f32[32]{0}, f32[32]{0}) reduce-scatter(%a, %b), replica_groups={{0,1,2,3}}
"""


def test_parse_collectives():
    out = parse_collectives(HLO)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 16 * 128 * 2
    # ring model: (g-1)/g of the buffer for g=4
    assert abs(out["all-gather"]["traffic"]
               - 16 * 128 * 2 * 0.75) < 1e-6
    assert out["all-reduce"]["traffic"] == 2 * 64 * 4 * 0.5
    assert out["collective-permute"]["traffic"] == 8 * 8 * 2
    assert out["reduce-scatter"]["bytes"] == 2 * 32 * 4


def test_model_flops_scaling():
    cfg = configs.get_config("qwen2.5-14b")
    train = model_flops(cfg, SHAPES["train_4k"])
    prefill = model_flops(cfg, SHAPES["prefill_32k"])
    decode = model_flops(cfg, SHAPES["decode_32k"])
    # 6ND vs 2ND over the same token count
    assert abs(train / prefill - 3.0) < 1e-6
    assert decode == pytest.approx(2.0 * cfg.n_active_params() * 128)


def test_cell_validity_matrix():
    """The 40-cell matrix: 31 valid, 9 skipped per assignment."""
    valid = skipped = 0
    for arch_id in configs.ARCH_IDS:
        cfg = configs.get_config(arch_id)
        for shape in SHAPES.values():
            ok, reason = cell_is_valid(cfg, shape)
            if ok:
                valid += 1
            else:
                skipped += 1
                assert reason
    assert valid == 31 and skipped == 9


def test_moe_flops_use_active_params():
    mav = configs.get_config("llama4-maverick-400b-a17b")
    dense_equiv = model_flops(mav, SHAPES["train_4k"])
    assert dense_equiv < 6.0 * mav.n_params() * 4096 * 256 / 10  # ~28x less


@pytest.mark.slow
def test_checkpoint_restart_fault_tolerance(tmp_path):
    """Kill a training run mid-flight; the relaunch resumes from the last
    complete checkpoint and finishes with the same step count."""
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    args = [sys.executable, "-m", "repro.launch.train",
            "--arch", "qwen1.5-0.5b", "--reduced", "--steps", "12",
            "--global-batch", "4", "--seq-len", "32",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "4",
            "--resume", "auto", "--log-every", "2"]
    # run 1: killed after the first checkpoint lands
    p = subprocess.Popen(args, env=env, stdout=subprocess.PIPE,
                         stderr=subprocess.STDOUT, text=True)
    import time
    deadline = time.time() + 500
    while time.time() < deadline:
        from repro.checkpoint import ckpt as _c
        if _c.latest_step(str(tmp_path)) is not None:
            break
        time.sleep(1)
        if p.poll() is not None:
            break
    p.kill()
    p.wait()
    from repro.checkpoint import ckpt as _c
    first = _c.latest_step(str(tmp_path))
    assert first is not None and first >= 4

    # run 2: resumes and completes
    r = subprocess.run(args, env=env, capture_output=True, text=True,
                       timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert f"resumed from step" in r.stdout
    assert _c.latest_step(str(tmp_path)) == 12
