"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.block_copy import block_copy_kernel
from repro.kernels.paged_attention import paged_attention_kernel
from repro.kernels.pt_walk import pt_walk_kernel

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("B,KH,G,Dh,P,bs,NB", [
    (1, 1, 1, 128, 8, 8, 2),
    (2, 2, 4, 128, 16, 16, 4),
    (3, 4, 2, 256, 32, 8, 5),
    (2, 2, 8, 128, 16, 32, 3),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(B, KH, G, Dh, P, bs, NB, dtype):
    q = jnp.asarray(RNG.normal(size=(B, KH, G, Dh)), dtype)
    kp = jnp.asarray(RNG.normal(size=(KH, P, bs, Dh)), dtype)
    vp = jnp.asarray(RNG.normal(size=(KH, P, bs, Dh)), dtype)
    tables = jnp.asarray(
        RNG.choice(P, size=B * NB, replace=False).reshape(B, NB), jnp.int32)
    lengths = jnp.asarray(RNG.integers(1, NB * bs + 1, B), jnp.int32)
    got = paged_attention_kernel(q, kp, vp, tables, lengths, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, tables, lengths)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("n_leaf,fanout,n", [
    (4, 64, 256), (16, 64, 512), (8, 128, 1024)])
def test_pt_walk_sweep(n_leaf, fanout, n):
    upper = jnp.asarray(RNG.permutation(n_leaf), jnp.int32)
    upper = upper.at[0].set(-1)                     # an unallocated leaf
    ltier = jnp.asarray(RNG.integers(0, 2, n_leaf), jnp.int32)
    lent = jnp.asarray(RNG.integers(0, 64, (n_leaf, fanout)), jnp.int32)
    vb = jnp.asarray(RNG.integers(0, n_leaf * fanout, n), jnp.int32)
    t, s = pt_walk_kernel(upper, ltier, lent, vb, interpret=True)
    wt, ws = ref.pt_walk_ref(upper, ltier, lent, vb)
    np.testing.assert_array_equal(np.asarray(t), np.asarray(wt))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(ws))


def _pt_walk_xla(upper, ltier, lent, vb):
    """Plain-XLA gather reference for the pt_walk kernel semantics."""
    fanout = lent.shape[1]
    leaf_idx = vb // fanout
    entry = vb % fanout
    leaf_id = upper[leaf_idx]
    valid = leaf_id >= 0
    safe = jnp.where(valid, leaf_id, 0)
    tier = jnp.where(valid, ltier[safe], -1)
    slot = jnp.where(valid, lent[safe, entry], -1)
    return tier, slot


@pytest.mark.parametrize("invalid_frac", [0.0, 0.5, 1.0])
def test_pt_walk_invalid_entries(invalid_frac):
    """Walks through unallocated (-1) upper entries must yield (-1, -1)."""
    n_leaf, fanout, n = 16, 64, 512
    upper = np.asarray(RNG.permutation(n_leaf), np.int32)
    kill = RNG.random(n_leaf) < invalid_frac
    if invalid_frac > 0:
        kill[0] = True                              # at least one hole
    upper[kill] = -1
    upper = jnp.asarray(upper)
    ltier = jnp.asarray(RNG.integers(0, 2, n_leaf), jnp.int32)
    lent = jnp.asarray(RNG.integers(0, 64, (n_leaf, fanout)), jnp.int32)
    # force every upper slot (valid and invalid) to be queried
    vb = jnp.asarray(np.concatenate([
        np.arange(n_leaf, dtype=np.int32) * fanout,
        RNG.integers(0, n_leaf * fanout, n - n_leaf).astype(np.int32)]))
    t, s = pt_walk_kernel(upper, ltier, lent, vb, interpret=True)
    wt, ws = _pt_walk_xla(upper, ltier, lent, vb)
    np.testing.assert_array_equal(np.asarray(t), np.asarray(wt))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(ws))
    hit_invalid = np.asarray(upper)[np.asarray(vb) // fanout] < 0
    assert np.all(np.asarray(t)[hit_invalid] == -1)
    assert np.all(np.asarray(s)[hit_invalid] == -1)
    if invalid_frac > 0:
        assert hit_invalid.any()


@pytest.mark.parametrize("n,q_block", [(512, 64), (1024, 128), (768, 256)])
def test_pt_walk_grid_tiling(n, q_block):
    """A non-trivial grid (n > q_block) must tile without edge effects."""
    n_leaf, fanout = 8, 128
    assert n > q_block
    upper = jnp.asarray(RNG.permutation(n_leaf), jnp.int32).at[1].set(-1)
    ltier = jnp.asarray(RNG.integers(0, 2, n_leaf), jnp.int32)
    lent = jnp.asarray(RNG.integers(0, 64, (n_leaf, fanout)), jnp.int32)
    vb = jnp.asarray(RNG.integers(0, n_leaf * fanout, n), jnp.int32)
    t, s = pt_walk_kernel(upper, ltier, lent, vb, q_block=q_block,
                          interpret=True)
    wt, ws = _pt_walk_xla(upper, ltier, lent, vb)
    np.testing.assert_array_equal(np.asarray(t), np.asarray(wt))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(ws))


@pytest.mark.parametrize("n,q_block", [
    (5, 64), (100, 64), (300, 256), (257, 128), (769, 256)])
def test_pt_walk_non_divisible_n(n, q_block):
    """N that doesn't divide q_block must pad-and-mask, not assert: the
    kernel zero-pads queries to a block multiple and slices the results
    back to N."""
    n_leaf, fanout = 8, 64
    upper = jnp.asarray(RNG.permutation(n_leaf), jnp.int32).at[2].set(-1)
    ltier = jnp.asarray(RNG.integers(0, 2, n_leaf), jnp.int32)
    lent = jnp.asarray(RNG.integers(0, 64, (n_leaf, fanout)), jnp.int32)
    vb = jnp.asarray(RNG.integers(0, n_leaf * fanout, n), jnp.int32)
    t, s = pt_walk_kernel(upper, ltier, lent, vb, q_block=q_block,
                          interpret=True)
    assert t.shape == (n,) and s.shape == (n,)
    wt, ws = _pt_walk_xla(upper, ltier, lent, vb)
    np.testing.assert_array_equal(np.asarray(t), np.asarray(wt))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(ws))


@pytest.mark.parametrize("P,bs,KH,Dh,M", [
    (8, 8, 1, 128, 1), (16, 16, 2, 128, 5), (32, 8, 4, 256, 12)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_copy_sweep(P, bs, KH, Dh, M, dtype):
    src = jnp.asarray(RNG.normal(size=(P, bs, KH, Dh)), dtype)
    dst = jnp.asarray(RNG.normal(size=(P, bs, KH, Dh)), dtype)
    srcs = RNG.choice(P, size=M, replace=False)
    dsts = RNG.choice(P, size=M, replace=False)
    ids = jnp.asarray(np.stack([srcs, dsts], 1), jnp.int32)
    got = block_copy_kernel(src, dst, ids, interpret=True)
    want = ref.block_copy_ref(src, dst, ids)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paged_attention_matches_dense():
    """Paged attention over a permuted pool == dense attention."""
    B, KH, G, Dh, bs, NB = 2, 2, 2, 128, 8, 4
    S = bs * NB
    q = jnp.asarray(RNG.normal(size=(B, KH, G, Dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, KH, S, Dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, KH, S, Dh)), jnp.float32)
    # scatter into pools
    P = B * NB
    perm = RNG.permutation(P)
    tables = jnp.asarray(perm.reshape(B, NB), jnp.int32)
    kp = jnp.zeros((KH, P, bs, Dh), jnp.float32)
    vp = jnp.zeros((KH, P, bs, Dh), jnp.float32)
    for b in range(B):
        for j in range(NB):
            kp = kp.at[:, perm[b * NB + j]].set(k[b, :, j * bs:(j + 1) * bs])
            vp = vp.at[:, perm[b * NB + j]].set(v[b, :, j * bs:(j + 1) * bs])
    lengths = jnp.asarray([S, S - 3], jnp.int32)
    got = paged_attention_kernel(q, kp, vp, tables, lengths, interpret=True)
    # dense reference
    s = jnp.einsum("bkgd,bksd->bkgs", q, k) / np.sqrt(Dh)
    mask = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    want = jnp.einsum("bkgs,bksd->bkgd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
