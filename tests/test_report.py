"""The perf observatory: BenchRecord schema, regression gate, flight
recorder, run manifest, and the extended artifact validator.

Five contracts:

1. **BenchRecord** — ``make_record`` emits schema-valid records with a
   machine fingerprint and dotted-path metrics; history.jsonl
   round-trips; run ids stay monotonic.
2. **The gate** — ``report --check`` passes on the repo's committed
   history/baselines (green path) and fails non-zero, naming the
   metric, on a seeded 30% synthetic regression; min/max/best entry
   kinds implement exactly the documented semantics.
3. **Quantiles** — ``Histogram.quantile`` + snapshot ``merge``:
   merge-then-quantile equals observe-all-then-quantile exactly, and
   both land within one bucket of the same-rank empirical quantile.
4. **Flight recorder** — a persistent poison through a real broker
   dumps a schema-valid postmortem carrying spans, a metrics delta and
   the quarantined digest — and the dump path never perturbs results.
5. **Validator** — partial same-track span overlap and non-monotonic
   B/E tracks are rejected; nesting/containment passes; the CLI is
   schema-aware across traces, history logs and postmortems.
"""
import json
import sys
import types
from pathlib import Path

import numpy as np
import pytest

from repro.obs import (FlightRecorder, Histogram, Telemetry, make_record,
                       append_record, flatten_metrics, load_history, merge,
                       next_run_id, quantile_from_snapshot, validate_record,
                       validate_postmortem, validate_trace_events)
from repro.obs import validate as validate_cli
from repro.obs import report as report_mod
from repro.obs.bench import namespace_of
from repro.obs.inject import FaultInjector, fail_lane
from repro.service import SimBroker, SimQuery
from repro.service.resilience import PoisonedQueryError

from test_service import MIXED_POLICIES, random_trace, tiny_machine

REPO = Path(__file__).resolve().parent.parent
COMMITTED_HISTORY = REPO / "artifacts" / "bench" / "history.jsonl"
COMMITTED_BASELINES = REPO / "artifacts" / "bench" / "baselines.json"


# ---------------------------------------------------------------------------
# BenchRecord schema + history
# ---------------------------------------------------------------------------
def test_make_record_is_schema_valid():
    rec = make_record(
        driver="demo", quick=True, run_id=3, wall_seconds=1.5,
        payload={"a": {"b": 2.0, "ok": True, "name": "skipme",
                       "pair": [1, 2]},
                 "snapshot": {"not": "a metric"}},
        figures=[("demo/x", 0.25, "speedup=2x")],
        clock=lambda: 1700000000.0)
    assert validate_record(rec) == []
    assert rec["metrics"] == {"a.b": 2.0, "a.ok": 1.0,
                              "a.pair.0": 1.0, "a.pair.1": 2.0}
    assert rec["figures"] == [["demo/x", 0.25, "speedup=2x"]]
    fp = rec["fingerprint"]
    assert fp["device_platform"] and fp["jax"] and fp["python"]
    assert rec["namespace"] == namespace_of(fp)


def test_validate_record_rejects():
    assert validate_record([]) == ["record is not an object"]
    rec = make_record(driver="demo", run_id=0)
    bad = dict(rec, schema="nope", run_id=-1)
    problems = "\n".join(validate_record(bad))
    assert "schema" in problems and "negative" in problems
    bad = dict(rec, metrics={"x": "not-a-number"})
    assert any("numeric" in p for p in validate_record(bad))


def test_flatten_metrics_skips_non_scalars():
    flat = flatten_metrics({
        "inf": float("inf"), "nan": float("nan"), "s": "str",
        "long": list(range(100)), "deep": {"v": 4},
        "telemetry": {"hidden": 1}, "n": 7})
    assert flat == {"deep.v": 4.0, "n": 7.0}


def test_history_roundtrip_and_monotonic_run_id(tmp_path):
    hist = tmp_path / "history.jsonl"
    assert next_run_id(hist) == 0
    for i in range(3):
        append_record(make_record(driver="d", run_id=i,
                                  payload={"m": i}), hist)
    records, problems = load_history(hist)
    assert problems == [] and len(records) == 3
    assert [r["metrics"]["m"] for r in records] == [0.0, 1.0, 2.0]
    assert next_run_id(hist) == 3
    # a corrupt line is reported, not silently swallowed
    with open(hist, "a") as fh:
        fh.write("{broken\n")
    _, problems = load_history(hist)
    assert any("unparseable" in p for p in problems)


def test_validator_rejects_duplicate_run_id_per_driver(tmp_path, capsys):
    """One run id is shared by every driver of a ``benchmarks.run``
    invocation, but a (run_id, driver) pair appearing twice in one
    manifest is a double-append and must fail validation."""
    hist = tmp_path / "history.jsonl"
    rec_a = make_record(driver="steady_state", run_id=0, payload={"m": 1})
    rec_b = make_record(driver="fault_batch", run_id=0, payload={"m": 2})
    append_record(rec_a, hist)
    append_record(rec_b, hist)    # same run id, different driver: fine
    assert validate_cli.main([str(hist)]) == 0
    capsys.readouterr()
    append_record(rec_a, hist)    # the exact double-append
    assert validate_cli.main([str(hist)]) == 1
    err = capsys.readouterr().err
    assert "duplicate record for run_id=0 driver='steady_state'" in err
    assert "first at line 1" in err


# ---------------------------------------------------------------------------
# the regression gate
# ---------------------------------------------------------------------------
def _history_of(tmp_path, values, driver="steady_state",
                payload_of=lambda v: {"steady": {"8lane": {"speedup": v}}}):
    hist = tmp_path / "history.jsonl"
    for i, v in enumerate(values):
        append_record(make_record(driver=driver, payload=payload_of(v),
                                  run_id=i, clock=lambda t=i: 1000.0 + t),
                      hist)
    return hist


def _baselines_of(tmp_path, entries):
    path = tmp_path / "baselines.json"
    path.write_text(json.dumps({
        "schema": "bench-baselines/v1",
        "namespaces": {"cpu": {"entries": entries}}}))
    return path


def test_seeded_30pct_regression_fails_and_names_metric(tmp_path, capsys):
    # best-known 6.0; the last three runs degraded 30% -> candidate 4.2
    # misses the 15% tolerance band and the gate must say which metric
    hist = _history_of(tmp_path, [6.0, 6.1, 4.2, 4.2, 4.2])
    base = _baselines_of(tmp_path, [
        {"driver": "steady_state", "metric": "steady.8lane.speedup",
         "kind": "best", "value": 6.0, "rel_tol": 0.15, "min_of_n": 3}])
    rc = report_mod.main(["--check", "--history", str(hist),
                          "--baselines", str(base)])
    assert rc == 1
    err = capsys.readouterr().err
    assert "REGRESSION" in err and "steady.8lane.speedup" in err


def test_min_of_n_damps_single_noisy_dip(tmp_path):
    # one bad run inside the window is tolerated (best-of-3) ...
    hist = _history_of(tmp_path, [6.0, 3.0, 5.9])
    base = _baselines_of(tmp_path, [
        {"driver": "steady_state", "metric": "steady.8lane.speedup",
         "kind": "best", "value": 6.0, "rel_tol": 0.15, "min_of_n": 3}])
    assert report_mod.main(["--check", "--history", str(hist),
                            "--baselines", str(base)]) == 0


def test_min_max_kinds_judge_latest_sample(tmp_path, capsys):
    hist = _history_of(tmp_path, [6.0, 1.1])     # newest violates a floor
    base = _baselines_of(tmp_path, [
        {"driver": "steady_state", "metric": "steady.8lane.speedup",
         "kind": "min", "value": 2.0}])
    assert report_mod.main(["--check", "--history", str(hist),
                            "--baselines", str(base)]) == 1
    capsys.readouterr()
    # a max bar: metric must stay at/below the ceiling
    hist2 = _history_of(tmp_path / "h2" if False else tmp_path,
                        [0.0, 0.0], driver="chaos",
                        payload_of=lambda v: {"gates": {"stranded": v}})
    base2 = _baselines_of(tmp_path, [
        {"driver": "chaos", "metric": "gates.stranded",
         "kind": "max", "value": 0}])
    assert report_mod.main(["--check", "--history", str(hist2),
                            "--baselines", str(base2)]) == 0


def test_missing_history_sample_is_a_failure(tmp_path, capsys):
    hist = _history_of(tmp_path, [6.0])
    base = _baselines_of(tmp_path, [
        {"driver": "steady_state", "metric": "no.such.metric",
         "kind": "min", "value": 1.0}])
    assert report_mod.main(["--check", "--history", str(hist),
                            "--baselines", str(base)]) == 1
    assert "no history sample" in capsys.readouterr().err


def test_update_baselines_ratchets_best_entries(tmp_path):
    hist = _history_of(tmp_path, [6.0, 7.5, 7.0])
    base = _baselines_of(tmp_path, [
        {"driver": "steady_state", "metric": "steady.8lane.speedup",
         "kind": "best", "value": 6.0, "rel_tol": 0.2, "min_of_n": 3},
        {"driver": "steady_state", "metric": "steady.8lane.speedup",
         "kind": "min", "value": 2.0}])
    assert report_mod.main(["--history", str(hist), "--baselines",
                            str(base), "--update-baselines"]) == 0
    obj = json.loads(base.read_text())
    entries = obj["namespaces"]["cpu"]["entries"]
    best = [e for e in entries if e["kind"] == "best"][0]
    assert best["value"] == 7.5                   # ratcheted to candidate
    assert [e for e in entries if e["kind"] == "min"][0]["value"] == 2.0


def test_green_path_on_committed_history():
    """The repo's own committed history + baselines pass the gate (the
    exact command CI runs), and the report renders with gate + driver
    trajectory sections."""
    assert COMMITTED_HISTORY.exists(), "committed history.jsonl missing"
    records, problems = load_history(COMMITTED_HISTORY)
    assert problems == [], problems
    assert records, "committed history is empty"
    baselines = report_mod.load_baselines(COMMITTED_BASELINES)
    checks = report_mod.check(records, baselines)
    bad = [c for c in checks if not c["ok"]]
    assert not bad, f"committed baselines violated: {bad}"
    report = report_mod.render_report(records, baselines, checks)
    assert "## Regression gate" in report
    assert "## Driver trajectory" in report
    assert "FAIL" not in report


# ---------------------------------------------------------------------------
# Histogram.quantile + merge (satellite property test)
# ---------------------------------------------------------------------------
def test_quantile_empty_and_bounds():
    h = Histogram()
    assert h.quantile(0.5) is None
    h.observe(0.003)
    assert h.quantile(0.0) == pytest.approx(0.003)
    assert h.quantile(1.0) == pytest.approx(0.003)
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_merge_rejects_mismatched_geometry():
    a, b = Histogram(lo=1e-6), Histogram(lo=1e-3)
    a.observe(0.5)
    b.observe(0.5)
    with pytest.raises(ValueError, match="lo"):
        merge(a.snapshot(), b.snapshot())


def test_merge_then_quantile_equals_observe_all_then_quantile():
    """The satellite property: fixed bucket boundaries make merge exact,
    so quantiles over the merged snapshot equal quantiles over one
    histogram fed everything — and both sit within one (log) bucket of
    the same-rank empirical quantile."""
    rng = np.random.default_rng(7)
    for trial in range(12):
        n = int(rng.integers(2, 400))
        vals = np.exp(rng.normal(loc=-5.0, scale=2.5, size=n))
        split = int(rng.integers(0, n + 1))
        h_all, h_a, h_b = Histogram(), Histogram(), Histogram()
        for i, v in enumerate(vals):
            h_all.observe(v)
            (h_a if i < split else h_b).observe(v)
        merged = merge(h_a.snapshot(), h_b.snapshot())
        assert merged["count"] == h_all.count
        assert merged["buckets"] == h_all.snapshot()["buckets"]
        for q in (0.0, 0.1, 0.5, 0.9, 0.99, 1.0):
            qm = quantile_from_snapshot(merged, q)
            qa = h_all.quantile(q)
            assert qm == pytest.approx(qa, rel=1e-12), (trial, q)
            # one-bucket-width accuracy vs the same-rank order statistic
            rank = min(max(int(np.ceil(q * n)), 1), n)
            emp = float(np.sort(vals)[rank - 1])
            assert abs(h_all.bucket_of(qa) - h_all.bucket_of(emp)) <= 1, \
                (trial, q, qa, emp)


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------
def test_flight_recorder_dump_contents(tmp_path):
    tel = Telemetry(tracing=True)
    fl = FlightRecorder(tel, tmp_path / "pm", clock=lambda: 1700000000.0)
    tel.counter("work.done").inc(5)
    with tel.span("step.one"):
        pass
    err = PoisonedQueryError("d3adb33f")
    path = fl.dump("unit.site", error=err, state={"extra": 1})
    obj = json.loads(path.read_text())
    assert validate_postmortem(obj) == []
    assert obj["site"] == "unit.site"
    assert obj["error"]["type"] == "PoisonedQueryError"
    assert obj["error"]["digest"] == "d3adb33f"
    assert [e["name"] for e in obj["spans"]] == ["step.one"]
    assert obj["metrics_delta"]["work.done"] == 5
    assert obj["state"] == {"extra": 1}
    # the dump marks a new baseline: an immediate re-dump has no delta,
    # and the same-second filename collision gets a suffix
    path2 = fl.dump("unit.site")
    assert path2 != path
    assert json.loads(path2.read_text())["metrics_delta"] == {}


def test_broker_poison_produces_postmortem(tmp_path):
    """A persistently poisoned lane through a real (tiny) broker dumps a
    schema-valid postmortem carrying spans, a metrics delta and the
    quarantined digest; the innocent lane still resolves."""
    mc = tiny_machine()
    tel = Telemetry(tracing=True)
    q_bad = SimQuery(trace=random_trace(mc, seed=1),
                     policy=MIXED_POLICIES[0], machine=mc)
    q_ok = SimQuery(trace=random_trace(mc, seed=2, name="ok"),
                    policy=MIXED_POLICIES[0], machine=mc)
    probe = SimBroker(pad_steps_floor=1)
    digest = probe.query_digest(q_bad)
    injector = FaultInjector(
        [fail_lane("sweep.device", digest, transient=False)])
    flight = FlightRecorder(tel, tmp_path / "pm")
    broker = SimBroker(max_lanes=2, telemetry=tel, injector=injector,
                       flight=flight, pad_steps_floor=1, sleep=lambda s: None)
    f_bad, f_ok = broker.submit_many([q_bad, q_ok])
    broker.drain()
    with pytest.raises(PoisonedQueryError):
        f_bad.result()
    assert f_ok.result().summary()["faults"] >= 0
    assert len(flight.dumps) == 1
    obj = json.loads(flight.dumps[0].read_text())
    assert validate_postmortem(obj) == []
    assert obj["site"] == "broker.poison"
    assert obj["error"]["digest"] == digest
    assert len(obj["spans"]) >= 1
    assert obj["metrics_delta"]
    assert digest in obj["state"]["quarantine"]
    assert obj["state"]["stats"]["quarantined"] == 1


def test_flight_dump_failure_never_breaks_settlement(tmp_path):
    mc = tiny_machine()
    q = SimQuery(trace=random_trace(mc, seed=3),
                 policy=MIXED_POLICIES[0], machine=mc)
    probe = SimBroker(pad_steps_floor=1)
    injector = FaultInjector([fail_lane(
        "sweep.device", probe.query_digest(q), transient=False)])

    class Exploding:
        def dump(self, *a, **kw):
            raise OSError("disk full")

    tel = Telemetry()
    broker = SimBroker(max_lanes=2, telemetry=tel, injector=injector,
                       flight=Exploding(), pad_steps_floor=1,
                       sleep=lambda s: None)
    fut = broker.submit(q)
    broker.drain()
    with pytest.raises(PoisonedQueryError):
        fut.result()
    assert tel.metrics.value("broker.flight_errors") == 1


# ---------------------------------------------------------------------------
# run manifest
# ---------------------------------------------------------------------------
def test_run_manifest_records_drivers_and_failures(tmp_path, monkeypatch):
    import benchmarks.run as runmod
    from benchmarks import common

    seen = {}
    ok_mod = types.ModuleType("benchmarks.fake_ok")
    ok_mod.main = lambda quick=False: seen.setdefault("quick", quick)
    bad_mod = types.ModuleType("benchmarks.fake_bad")

    def _boom(quick=False):
        raise RuntimeError("boom")
    bad_mod.main = _boom
    monkeypatch.setitem(sys.modules, "benchmarks.fake_ok", ok_mod)
    monkeypatch.setitem(sys.modules, "benchmarks.fake_bad", bad_mod)
    monkeypatch.setattr(runmod, "FIGURES", {
        "ok": ("fake_ok", "fake passing driver"),
        "bad": ("fake_bad", "fake failing driver")})
    monkeypatch.setattr(common, "ART", tmp_path)
    monkeypatch.setattr(common, "HISTORY", tmp_path / "history.jsonl")
    monkeypatch.setitem(common._RUN_STATE, "run_id", None)
    monkeypatch.setattr(sys, "argv", ["run", "--quick"])
    with pytest.raises(SystemExit):
        runmod.main()
    manifest = json.loads((tmp_path / "run_manifest.json").read_text())
    assert manifest["schema"] == "run-manifest/v1"
    assert manifest["quick"] is True and seen["quick"] is True
    assert isinstance(manifest["run_id"], int)
    assert manifest["drivers"]["ok"]["status"] == "ok"
    assert manifest["drivers"]["ok"]["seconds"] >= 0
    assert manifest["drivers"]["bad"]["status"] == "failed"
    assert "boom" in manifest["drivers"]["bad"]["error"]
    assert manifest["failures"] == ["bad"]


# ---------------------------------------------------------------------------
# validator extensions (satellite: overlap + monotonicity rejects)
# ---------------------------------------------------------------------------
def _span(name, ts, dur, tid=0, pid=0):
    return {"name": name, "cat": "t", "ph": "X", "ts": ts, "dur": dur,
            "pid": pid, "tid": tid}


def test_validator_rejects_partial_overlap_same_track():
    obj = {"traceEvents": [_span("a", 0, 10), _span("b", 5, 10)]}
    problems = validate_trace_events(obj)
    assert any("partially overlaps" in p for p in problems), problems


def test_validator_allows_nesting_and_cross_track_overlap():
    obj = {"traceEvents": [
        _span("outer", 0, 100),
        _span("inner", 10, 20),
        _span("inner2", 30, 70),
        _span("tail-aligned", 60, 40),      # exact containment to the edge
        _span("other-track", 5, 200, tid=1),
        _span("next", 101, 10),
    ]}
    assert validate_trace_events(obj) == []


def test_validator_rejects_non_monotonic_be_track():
    obj = {"traceEvents": [
        _span("x", 0, 1),
        {"name": "a", "cat": "t", "ph": "B", "ts": 10, "pid": 0, "tid": 0},
        {"ph": "E", "ts": 5, "pid": 0, "tid": 0},
    ]}
    problems = validate_trace_events(obj)
    assert any("non-monotonic" in p for p in problems), problems


def test_validate_cli_is_schema_aware(tmp_path, capsys):
    trace = tmp_path / "trace.json"
    trace.write_text(json.dumps({"traceEvents": [_span("a", 0, 1)]}))
    hist = tmp_path / "history.jsonl"
    append_record(make_record(driver="d", run_id=0), hist)
    tel = Telemetry(tracing=True)
    with tel.span("s"):
        pass
    pm = FlightRecorder(tel, tmp_path).dump("cli.site")
    assert validate_cli.main([str(trace), str(hist), str(pm)]) == 0
    out = capsys.readouterr().out
    assert "1 bench records" in out and "postmortem at cli.site" in out
    # a bad history line flips the exit code and names the line
    with open(hist, "a") as fh:
        fh.write(json.dumps({"schema": "bench-record/v1"}) + "\n")
    assert validate_cli.main([str(hist)]) == 1
    assert "line 2" in capsys.readouterr().err
    assert validate_cli.main([str(tmp_path / "nope.json")]) == 1
