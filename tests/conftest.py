"""Shared fixtures.

Tests that exercise benchmark drivers directly (e.g. the
``service_throughput`` quick smoke) must not append BenchRecords to the
*committed* ``artifacts/bench/history.jsonl`` — that log is the
regression gate's input and only real ``benchmarks.run`` invocations
belong in it.  Redirect the history sink to a per-test temp file for
every test; tests that want the real committed history (the green-path
gate test) read it by explicit path.
"""
import pytest


@pytest.fixture(autouse=True)
def _isolated_bench_history(tmp_path, monkeypatch):
    from benchmarks import common
    monkeypatch.setattr(common, "HISTORY", tmp_path / "history.jsonl")
    monkeypatch.setitem(common._RUN_STATE, "run_id", None)
