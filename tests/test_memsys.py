"""Tiered paged-KV cache: correctness + Radiant invariants (property-based)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # property tests skip; the rest run
    HAVE_HYPOTHESIS = False

from repro.memsys import tiered_kv as tkv

G, KH, DH, BS = 2, 2, 8, 4


def make_kv(n_hot=8, n_cold=32, n_seqs=4, max_seq=BS * tkv.FANOUT * 2):
    return tkv.init(G, n_hot, n_cold, BS, KH, DH, n_seqs, max_seq,
                    dtype=jnp.float32)


def tok(val):
    return jnp.full((G, KH, DH), val, jnp.float32)


def test_append_gather_roundtrip():
    kv = make_kv()
    append = jax.jit(tkv.append_token)
    vals = {0: [], 1: []}
    for t in range(10):
        for seq in (0, 1):
            v = 1.0 + seq * 100 + t
            kv = append(kv, jnp.asarray(seq), tok(v), tok(v * 2))
            vals[seq].append(v)
    for seq in (0, 1):
        n_blocks = -(-len(vals[seq]) // BS)
        k, v = tkv.gather_kv(kv, jnp.asarray(seq), n_blocks)
        got = np.asarray(k)[0, :, 0, 0]
        want = np.asarray(vals[seq] + [0.0] * (n_blocks * BS - len(vals[seq])))
        np.testing.assert_allclose(got[:len(vals[seq])], want[:len(vals[seq])])


def test_cold_fallback_when_hot_pool_full():
    kv = make_kv(n_hot=2)
    append = jax.jit(tkv.append_token)
    for t in range(4 * BS):     # needs 4 blocks; only 2 hot
        kv = append(kv, jnp.asarray(0), tok(float(t)), tok(float(t)))
    tier, slot = tkv.lookup_blocks(kv, jnp.asarray(0), 4)
    assert int(kv.stats[tkv.STAT_FALLBACK]) == 2
    assert list(np.asarray(tier)) == [tkv.HOT, tkv.HOT, tkv.COLD, tkv.COLD]
    # gather must still return the right data from both pools
    k, _ = tkv.gather_kv(kv, jnp.asarray(0), 4)
    np.testing.assert_allclose(np.asarray(k)[0, :4 * BS, 0, 0],
                               np.arange(4 * BS, dtype=np.float32))


def test_migrate_roundtrip_and_invariant():
    kv = make_kv()
    append = jax.jit(tkv.append_token)
    for t in range(2 * BS):
        kv = append(kv, jnp.asarray(0), tok(float(t)), tok(float(t)))
    k0, _ = tkv.gather_kv(kv, jnp.asarray(0), 2)
    kv = tkv.migrate_sequence(kv, jnp.asarray(0), tkv.COLD, 8)
    assert int(tkv.table_invariant_violations(kv)) == 0
    tier, _ = tkv.lookup_blocks(kv, jnp.asarray(0), 2)
    assert all(np.asarray(tier) == tkv.COLD)
    assert int(kv.leaf_tier[kv.upper[0, 0]]) == tkv.COLD  # Alg.1: leaf follows
    kv = tkv.migrate_sequence(kv, jnp.asarray(0), tkv.HOT, 8)
    assert int(tkv.table_invariant_violations(kv)) == 0
    assert int(kv.leaf_tier[kv.upper[0, 0]]) == tkv.HOT
    k1, _ = tkv.gather_kv(kv, jnp.asarray(0), 2)
    np.testing.assert_allclose(np.asarray(k0), np.asarray(k1))


def test_immobile_tables_violate_invariant():
    kv = make_kv()
    append = jax.jit(tkv.append_token)
    for t in range(BS):
        kv = append(kv, jnp.asarray(0), tok(1.0), tok(1.0))
    kv = tkv.migrate_sequence(kv, jnp.asarray(0), tkv.COLD, 8,
                              trigger_leaf=False)
    assert int(tkv.table_invariant_violations(kv)) > 0


def alive_usage(kv, n_seqs=3):
    """(slot set, hot-used count, alive leaf ids) over live sequences."""
    slots, leaves = [], []
    for s in range(n_seqs):
        t, sl = tkv.lookup_blocks(kv, jnp.asarray(s), MAXB)
        for ti, si in zip(np.asarray(t), np.asarray(sl)):
            if ti >= 0:
                slots.append((int(ti), int(si)))
        for lid in np.asarray(kv.upper[s]):
            if lid >= 0:
                leaves.append(int(lid))
    return slots, leaves


MAXB = 64          # covers every block a test sequence can grow to


def run_interleaving(ops):
    """Apply (seq, op) interleavings and check the Radiant invariant plus
    full resource conservation: no slot double-allocated, no leaf table
    page shared, every pool's used + free == capacity — sequences die
    (release frees blocks AND leaf pages) and may be re-grown after."""
    kv = make_kv(n_hot=6, n_cold=64, n_seqs=3)
    n_hot, n_cold = kv.hot_k.shape[1], kv.cold_k.shape[1]
    n_leaf = kv.leaf_tier.shape[0]
    append = jax.jit(tkv.append_token)
    mig = jax.jit(tkv.migrate_sequence,
                  static_argnames=("to_tier", "max_blocks", "trigger_leaf"))
    rel = jax.jit(tkv.release_sequence, static_argnames=("max_blocks",))
    for seq, op in ops:
        if op == "append":
            for _ in range(3):
                kv = append(kv, jnp.asarray(seq), tok(1.0), tok(1.0))
        elif op == "demote":
            kv = mig(kv, jnp.asarray(seq), tkv.COLD, MAXB)
        elif op == "promote":
            kv = mig(kv, jnp.asarray(seq), tkv.HOT, MAXB)
        else:
            kv = rel(kv, jnp.asarray(seq), MAXB)

    assert int(tkv.table_invariant_violations(kv)) == 0
    slots, leaves = alive_usage(kv)
    assert len(set(slots)) == len(slots), "double-allocated block slot"
    assert len(set(leaves)) == len(leaves), "leaf table page shared"
    hot_used = sum(1 for t, _ in slots if t == tkv.HOT)
    cold_used = sum(1 for t, _ in slots if t == tkv.COLD)
    assert hot_used + int(kv.hot_free_top) == n_hot, \
        "hot blocks leaked or double-freed across release interleavings"
    assert cold_used + int(kv.cold_free_top) == n_cold
    assert len(leaves) + int(kv.leaf_free_top) == n_leaf, \
        "leaf table pages leaked or double-freed"


OPS = ("append", "demote", "promote", "release")


@pytest.mark.parametrize("seed", range(4))
def test_random_interleavings_fixed_seeds(seed):
    """Deterministic property-style coverage (runs without hypothesis):
    seeded random append/migrate/release interleavings."""
    rng = np.random.default_rng(seed)
    ops = [(int(rng.integers(0, 3)), OPS[int(rng.integers(0, len(OPS)))])
           for _ in range(20)]
    run_interleaving(ops)


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 2),          # seq id
                              st.sampled_from(["append", "demote",
                                               "promote"])),
                    min_size=1, max_size=20))
    def test_property_invariant_and_freelists(ops):
        run_interleaving(ops)

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 2), st.sampled_from(OPS)),
                    min_size=1, max_size=24))
    def test_property_invariant_with_release_interleavings(ops):
        run_interleaving(ops)


def test_eviction_under_pressure_then_release_refills_hot():
    """The overload path end to end: a tenant overflows the hot pool
    (cold-fallback 'eviction'), gets demoted wholesale under pressure,
    a new tenant takes the freed hot space, and a final release returns
    every resource."""
    kv = make_kv(n_hot=2, n_cold=64)
    n_leaf = kv.leaf_tier.shape[0]
    append = jax.jit(tkv.append_token)
    for t in range(4 * BS):                  # needs 4 blocks; only 2 hot
        kv = append(kv, jnp.asarray(0), tok(float(t)), tok(float(t)))
    assert int(kv.stats[tkv.STAT_FALLBACK]) == 2
    assert int(kv.hot_free_top) == 0
    assert int(tkv.table_invariant_violations(kv)) == 0

    # memory pressure: demote the whole tenant; hot pool fully drains
    kv = tkv.migrate_sequence(kv, jnp.asarray(0), tkv.COLD, MAXB)
    assert int(kv.hot_free_top) == 2
    assert int(tkv.table_invariant_violations(kv)) == 0
    tier, _ = tkv.lookup_blocks(kv, jnp.asarray(0), 4)
    assert all(np.asarray(tier) == tkv.COLD)

    # the freed hot pool serves a new tenant immediately
    for t in range(2 * BS):
        kv = append(kv, jnp.asarray(1), tok(9.0), tok(9.0))
    tier1, _ = tkv.lookup_blocks(kv, jnp.asarray(1), 2)
    assert all(np.asarray(tier1) == tkv.HOT)
    assert int(tkv.table_invariant_violations(kv)) == 0

    # releases return every block and leaf page
    kv = tkv.release_sequence(kv, jnp.asarray(0), MAXB)
    kv = tkv.release_sequence(kv, jnp.asarray(1), MAXB)
    assert int(kv.hot_free_top) == 2
    assert int(kv.cold_free_top) == kv.cold_k.shape[1]
    assert int(kv.leaf_free_top) == n_leaf
    assert int(kv.seq_len[0]) == 0 and int(kv.seq_len[1]) == 0
    assert int(tkv.table_invariant_violations(kv)) == 0
