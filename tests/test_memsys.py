"""Tiered paged-KV cache: correctness + Radiant invariants (property-based)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.memsys import tiered_kv as tkv

G, KH, DH, BS = 2, 2, 8, 4


def make_kv(n_hot=8, n_cold=32, n_seqs=4, max_seq=BS * tkv.FANOUT * 2):
    return tkv.init(G, n_hot, n_cold, BS, KH, DH, n_seqs, max_seq,
                    dtype=jnp.float32)


def tok(val):
    return jnp.full((G, KH, DH), val, jnp.float32)


def test_append_gather_roundtrip():
    kv = make_kv()
    append = jax.jit(tkv.append_token)
    vals = {0: [], 1: []}
    for t in range(10):
        for seq in (0, 1):
            v = 1.0 + seq * 100 + t
            kv = append(kv, jnp.asarray(seq), tok(v), tok(v * 2))
            vals[seq].append(v)
    for seq in (0, 1):
        n_blocks = -(-len(vals[seq]) // BS)
        k, v = tkv.gather_kv(kv, jnp.asarray(seq), n_blocks)
        got = np.asarray(k)[0, :, 0, 0]
        want = np.asarray(vals[seq] + [0.0] * (n_blocks * BS - len(vals[seq])))
        np.testing.assert_allclose(got[:len(vals[seq])], want[:len(vals[seq])])


def test_cold_fallback_when_hot_pool_full():
    kv = make_kv(n_hot=2)
    append = jax.jit(tkv.append_token)
    for t in range(4 * BS):     # needs 4 blocks; only 2 hot
        kv = append(kv, jnp.asarray(0), tok(float(t)), tok(float(t)))
    tier, slot = tkv.lookup_blocks(kv, jnp.asarray(0), 4)
    assert int(kv.stats[tkv.STAT_FALLBACK]) == 2
    assert list(np.asarray(tier)) == [tkv.HOT, tkv.HOT, tkv.COLD, tkv.COLD]
    # gather must still return the right data from both pools
    k, _ = tkv.gather_kv(kv, jnp.asarray(0), 4)
    np.testing.assert_allclose(np.asarray(k)[0, :4 * BS, 0, 0],
                               np.arange(4 * BS, dtype=np.float32))


def test_migrate_roundtrip_and_invariant():
    kv = make_kv()
    append = jax.jit(tkv.append_token)
    for t in range(2 * BS):
        kv = append(kv, jnp.asarray(0), tok(float(t)), tok(float(t)))
    k0, _ = tkv.gather_kv(kv, jnp.asarray(0), 2)
    kv = tkv.migrate_sequence(kv, jnp.asarray(0), tkv.COLD, 8)
    assert int(tkv.table_invariant_violations(kv)) == 0
    tier, _ = tkv.lookup_blocks(kv, jnp.asarray(0), 2)
    assert all(np.asarray(tier) == tkv.COLD)
    assert int(kv.leaf_tier[kv.upper[0, 0]]) == tkv.COLD  # Alg.1: leaf follows
    kv = tkv.migrate_sequence(kv, jnp.asarray(0), tkv.HOT, 8)
    assert int(tkv.table_invariant_violations(kv)) == 0
    assert int(kv.leaf_tier[kv.upper[0, 0]]) == tkv.HOT
    k1, _ = tkv.gather_kv(kv, jnp.asarray(0), 2)
    np.testing.assert_allclose(np.asarray(k0), np.asarray(k1))


def test_immobile_tables_violate_invariant():
    kv = make_kv()
    append = jax.jit(tkv.append_token)
    for t in range(BS):
        kv = append(kv, jnp.asarray(0), tok(1.0), tok(1.0))
    kv = tkv.migrate_sequence(kv, jnp.asarray(0), tkv.COLD, 8,
                              trigger_leaf=False)
    assert int(tkv.table_invariant_violations(kv)) > 0


MAXB = 64          # covers every block a test sequence can grow to


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2),          # seq id
                          st.sampled_from(["append", "demote", "promote"])),
                min_size=1, max_size=20))
def test_property_invariant_and_freelists(ops):
    kv = make_kv(n_hot=6, n_cold=64, n_seqs=3)
    append = jax.jit(tkv.append_token)
    mig = jax.jit(tkv.migrate_sequence,
                  static_argnames=("to_tier", "max_blocks", "trigger_leaf"))
    for seq, op in ops:
        if op == "append":
            for _ in range(3):
                kv = append(kv, jnp.asarray(seq), tok(1.0), tok(1.0))
        elif op == "demote":
            kv = mig(kv, jnp.asarray(seq), tkv.COLD, MAXB)
        else:
            kv = mig(kv, jnp.asarray(seq), tkv.HOT, MAXB)
    # Radiant invariant: leaf tier agrees with children everywhere
    assert int(tkv.table_invariant_violations(kv)) == 0
    # allocator sanity: free tops within bounds, no double allocation
    n_hot = kv.hot_k.shape[1]
    tiers, slots = [], []
    for s in range(3):
        t, sl = tkv.lookup_blocks(kv, jnp.asarray(s), MAXB)
        t, sl = np.asarray(t), np.asarray(sl)
        for ti, si in zip(t, sl):
            if ti >= 0:
                tiers.append(ti)
                slots.append((ti, si))
    assert len(set(slots)) == len(slots), "double-allocated block slot"
    n_hot_used = sum(1 for t, _ in slots if t == tkv.HOT)
    assert n_hot_used + int(kv.hot_free_top) == n_hot
