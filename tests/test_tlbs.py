"""Property tests for the TLB/PWC model in isolation.

``core/tlbs.py`` underpins both phase A and the time-blocked fast
window's inner scan, but until now was only exercised end-to-end.  Pinned
here: LRU eviction order with deterministic lowest-way tie-breaking
(empty slots stamped -1 sort before any age), ``invalidate_matching``
clearing exactly the matching tags, and the scalar ``update_one`` /
``lookup_one`` forms agreeing with the batched ones on random request
streams.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import tlbs

I32 = jnp.int32


def _arr(x):
    return np.asarray(x)


def fill(tlb, thread, tags, start_now=0):
    now = start_now
    for tag in tags:
        t = jnp.full((tlb.tags.shape[0],), -1, I32).at[thread].set(tag)
        active = jnp.zeros((tlb.tags.shape[0],), bool).at[thread].set(True)
        hit, way = tlbs.lookup(tlb, t)
        tlb = tlbs.update(tlb, t, way, jnp.asarray(now, I32), active)
        now += 1
    return tlb, now


def test_lru_evicts_oldest_then_lowest_way():
    """A full set evicts the least-recently-used way; a re-touch changes
    the victim; empty slots always win over any filled way."""
    tlb = tlbs.make_tlb(n_threads=1, sets=1, ways=4)
    # empty slots are chosen lowest-way-first
    for expect_way, tag in enumerate([10, 20, 30, 40]):
        hit, way = tlbs.lookup(tlb, jnp.asarray([tag], I32))
        assert not bool(hit[0]) and int(way[0]) == expect_way
        tlb = tlbs.update(tlb, jnp.asarray([tag], I32), way,
                          jnp.asarray(expect_way, I32),
                          jnp.asarray([True]))
    # touch 10 (way 0) at a later time: 20 (way 1) is now the LRU victim
    hit, way = tlbs.lookup(tlb, jnp.asarray([10], I32))
    assert bool(hit[0]) and int(way[0]) == 0
    tlb = tlbs.update(tlb, jnp.asarray([10], I32), way,
                      jnp.asarray(7, I32), jnp.asarray([True]))
    hit, victim = tlbs.lookup(tlb, jnp.asarray([50], I32))
    assert not bool(hit[0]) and int(victim[0]) == 1
    tlb = tlbs.update(tlb, jnp.asarray([50], I32), victim,
                      jnp.asarray(8, I32), jnp.asarray([True]))
    assert set(_arr(tlb.tags)[0, 0].tolist()) == {10, 50, 30, 40}


def test_lru_tie_break_lowest_way():
    """Equal-age ways (same ``now`` stamp) break ties to the lowest way —
    the property the pure-Python oracle replicates via argmin."""
    tlb = tlbs.make_tlb(n_threads=1, sets=1, ways=3)
    for w, tag in enumerate([1, 2, 3]):
        tlb = tlbs.update(tlb, jnp.asarray([tag], I32),
                          jnp.asarray([w]), jnp.asarray(5, I32),
                          jnp.asarray([True]))
    _, victim = tlbs.lookup(tlb, jnp.asarray([9], I32))
    assert int(victim[0]) == 0


def test_update_inactive_is_noop():
    tlb = tlbs.make_tlb(n_threads=2, sets=2, ways=2)
    tags0 = _arr(tlb.tags).copy()
    t = jnp.asarray([3, 5], I32)
    _, way = tlbs.lookup(tlb, t)
    tlb2 = tlbs.update(tlb, t, way, jnp.asarray(1, I32),
                       jnp.asarray([False, False]))
    np.testing.assert_array_equal(_arr(tlb2.tags), tags0)
    np.testing.assert_array_equal(_arr(tlb2.lru), _arr(tlb.lru))


def test_invalidate_matching_only_clears_matching():
    """Only entries whose shifted tag indexes a set bit die; the rest
    keep their tags AND their LRU stamps."""
    tlb = tlbs.make_tlb(n_threads=1, sets=4, ways=2)
    tags = [0, 1, 5, 9, 14]       # sets 0,1,1,1,2
    tlb, _ = fill(tlb, 0, tags)
    flushed = np.zeros(16, bool)
    flushed[[1, 14]] = True
    out = tlbs.invalidate_matching(tlb, jnp.asarray(flushed), 0)
    kept = set(_arr(out.tags).ravel().tolist()) - {-1}
    assert kept == {0, 5, 9}
    # survivors keep their LRU stamps, victims are reset to empty (-1)
    sel = _arr(tlb.tags) == 5
    assert (_arr(out.lru)[sel] == _arr(tlb.lru)[sel]).all()
    assert (_arr(out.lru)[_arr(tlb.tags) == 14] == -1).all()


def test_invalidate_matching_shifted_tags():
    """shift=k groups tags by tag>>k — the leaf-PT shootdown form."""
    tlb = tlbs.make_tlb(n_threads=1, sets=4, ways=4)
    tlb, _ = fill(tlb, 0, [0, 1, 2, 3, 4, 5, 6, 7])
    flushed = np.zeros(2, bool)
    flushed[1] = True             # kill tags with tag>>2 == 1 (4..7)
    out = tlbs.invalidate_matching(tlb, jnp.asarray(flushed), 2)
    kept = set(_arr(out.tags).ravel().tolist()) - {-1}
    assert kept == {0, 1, 2, 3}


def test_scalar_forms_match_batched_on_random_streams():
    """update_one/lookup_one (the sequential fault path) vs the batched
    update/lookup on identical single-thread request streams."""
    rng = np.random.default_rng(0)
    T, sets, ways = 3, 4, 2
    bat = tlbs.make_tlb(T, sets, ways)
    sca = tlbs.make_tlb(T, sets, ways)
    for now in range(80):
        thread = int(rng.integers(T))
        tag = int(rng.integers(0, 24))
        active = bool(rng.random() < 0.9)
        t_vec = jnp.full((T,), -1, I32).at[thread].set(tag)
        act_vec = jnp.zeros((T,), bool).at[thread].set(active)
        hit_b, way_b = tlbs.lookup(bat, t_vec)
        bat = tlbs.update(bat, t_vec, way_b, jnp.asarray(now, I32), act_vec)
        hit_s = tlbs.lookup_one(sca, jnp.asarray(thread), jnp.asarray(tag))
        assert bool(hit_s) == bool(hit_b[thread]), f"step {now}"
        sca = tlbs.update_one(sca, jnp.asarray(thread), jnp.asarray(tag),
                              jnp.asarray(now, I32), jnp.asarray(active))
        np.testing.assert_array_equal(_arr(bat.tags)[thread],
                                      _arr(sca.tags)[thread],
                                      err_msg=f"step {now}")
        np.testing.assert_array_equal(_arr(bat.lru)[thread],
                                      _arr(sca.lru)[thread],
                                      err_msg=f"step {now}")


def test_flush_all():
    tlb = tlbs.make_tlb(2, 2, 2)
    tlb, _ = fill(tlb, 0, [1, 2, 3])
    out = tlbs.flush_all(tlb)
    assert (_arr(out.tags) == -1).all() and (_arr(out.lru) == -1).all()
