"""Checkpoint: atomic roundtrip, crash-safety, resume, shape validation."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt


def tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"params": {"w": jax.random.normal(k, (4, 8)),
                       "b": jnp.zeros((8,), jnp.bfloat16)},
            "opt": {"m": jnp.ones((4, 8)), "step": jnp.asarray(7)}}


def test_roundtrip(tmp_path):
    t = tree()
    ckpt.save(str(tmp_path), 10, t)
    assert ckpt.latest_step(str(tmp_path)) == 10
    example = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    back = ckpt.restore(str(tmp_path), 10, example)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_ignores_partial_saves(tmp_path):
    t = tree()
    ckpt.save(str(tmp_path), 5, t)
    # a crashed save: directory without a manifest
    (tmp_path / "step_9").mkdir()
    (tmp_path / "step_12.tmp").mkdir()
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_restore_validates_shapes(tmp_path):
    t = tree()
    ckpt.save(str(tmp_path), 1, t)
    bad = jax.tree.map(lambda x: jax.ShapeDtypeStruct((3,) + x.shape,
                                                      x.dtype), t)
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), 1, bad)


def test_async_save(tmp_path):
    t = tree()
    th = ckpt.save(str(tmp_path), 3, t, blocking=False)
    th.join()
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_overwrite_same_step(tmp_path):
    t1, t2 = tree(0), tree(1)
    ckpt.save(str(tmp_path), 2, t1)
    ckpt.save(str(tmp_path), 2, t2)
    example = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t2)
    back = ckpt.restore(str(tmp_path), 2, example)
    np.testing.assert_array_equal(np.asarray(back["params"]["w"]),
                                  np.asarray(t2["params"]["w"]))
