"""JAX simulator vs pure-Python oracle: exact-semantics equivalence.

Small machines, every policy bundle, with and without THP, with segment
frees.  Counters and placement arrays must match exactly; cycle totals to
float32 rounding.
"""
import numpy as np
import pytest

from repro.core import (CostConfig, MachineConfig, PolicyConfig,
                        TieredMemSimulator, Trace, FIRST_TOUCH, INTERLEAVE,
                        PT_BIND_ALL, PT_BIND_HIGH, PT_FOLLOW_DATA)
from repro.core.ref import OracleSim

EXACT_KEYS = ("l1_hits", "stlb_hits", "walks", "walk_mem_reads", "faults",
              "slow_allocs", "data_migrations", "demotions",
              "l4_mig_success", "l4_mig_already_dest", "l4_mig_in_dram",
              "l4_mig_sibling_guard", "l4_mig_lock_skip",
              "data_pages_dram", "data_pages_nvmm",
              "leaf_pages_dram", "leaf_pages_nvmm", "oom_killed", "oom_step")
CYCLE_KEYS = ("total_cycles", "walk_cycles", "stall_cycles",
              "data_mem_cycles", "fault_cycles", "migration_cycles")


def tiny_machine(page_order=0):
    return MachineConfig(n_threads=4, dram_pages_per_node=600,
                         nvmm_pages_per_node=2400, va_pages=1 << 12,
                         page_order=page_order,
                         l1_tlb_sets=4, l1_tlb_ways=2, stlb_sets=8,
                         stlb_ways=4, pde_pwc_entries=4, pdpte_pwc_entries=2)


def random_trace(mc, steps=160, seed=0, n_segs=2, free_at=None):
    rng = np.random.default_rng(seed)
    T = mc.n_threads
    # mix of sequential faulting and random re-access
    va = np.where(rng.random((steps, T)) < 0.5,
                  rng.integers(0, mc.va_pages // 2, (steps, T)),
                  rng.integers(0, mc.va_pages, (steps, T))).astype(np.int32)
    va[rng.random((steps, T)) < 0.05] = -1       # idle slots
    wr = rng.random((steps, T)) < 0.3
    free_seg = np.full((steps,), -1, np.int32)
    if free_at is not None:
        free_seg[free_at] = 0
    seg = np.zeros((mc.n_map,), np.int32)
    seg[mc.n_map // 2:] = 1
    llc = np.full((steps,), 0.4, np.float32)
    return Trace(va=va, is_write=wr, free_seg=free_seg, llc=llc,
                 seg_of_map=seg, name="rand")


POLICIES = [
    PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_FOLLOW_DATA,
                 mig=False, autonuma=False),
    PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_FOLLOW_DATA,
                 mig=False, autonuma=True, autonuma_period=16,
                 autonuma_budget=32),
    PolicyConfig(data_policy=INTERLEAVE, pt_policy=PT_BIND_HIGH,
                 mig=True, autonuma=True, autonuma_period=16,
                 autonuma_budget=32),
    PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_BIND_HIGH,
                 mig=True, autonuma=True, autonuma_period=16,
                 autonuma_budget=32),
    PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_BIND_ALL,
                 mig=False, autonuma=False),
    PolicyConfig(data_policy=INTERLEAVE, pt_policy=PT_FOLLOW_DATA,
                 mig=False, autonuma=True, autonuma_period=16,
                 autonuma_budget=32, autonuma_exchange=False),
]


def _compare(mc, pc, trace):
    cc = CostConfig()
    jax_res = TieredMemSimulator(mc=mc, cc=cc, pc=pc).run(trace).summary()
    oracle = OracleSim(mc, cc, pc)
    oracle.run(trace)
    ref = oracle.summary()
    for k in EXACT_KEYS:
        assert jax_res[k] == ref[k], \
            f"{pc.label()}: {k}: jax={jax_res[k]} oracle={ref[k]}"
    for k in CYCLE_KEYS:
        np.testing.assert_allclose(jax_res[k], ref[k], rtol=1e-5,
                                   err_msg=f"{pc.label()}: {k}")


@pytest.mark.parametrize("pidx", range(len(POLICIES)))
def test_oracle_equivalence(pidx):
    mc = tiny_machine()
    _compare(mc, POLICIES[pidx], random_trace(mc, seed=pidx))


def test_oracle_equivalence_with_free():
    mc = tiny_machine()
    pc = POLICIES[3]
    _compare(mc, pc, random_trace(mc, seed=42, free_at=100))


def test_oracle_equivalence_thp():
    mc = tiny_machine(page_order=9)
    for pidx in (0, 3):
        _compare(mc, POLICIES[pidx], random_trace(mc, seed=7 + pidx))


def test_oracle_equivalence_memory_pressure():
    # footprint ~2x DRAM so first-touch spills and AutoNUMA churns
    mc = MachineConfig(n_threads=4, dram_pages_per_node=200,
                       nvmm_pages_per_node=1600, va_pages=1 << 11,
                       l1_tlb_sets=4, l1_tlb_ways=2, stlb_sets=8,
                       stlb_ways=4, pde_pwc_entries=4, pdpte_pwc_entries=2)
    for pidx in (1, 2, 3):
        _compare(mc, POLICIES[pidx], random_trace(mc, seed=pidx, steps=256))


def test_oracle_equivalence_radix6():
    # scaled-radix machine used by the benchmark suite
    mc = MachineConfig(n_threads=4, dram_pages_per_node=600,
                       nvmm_pages_per_node=2400, va_pages=1 << 12,
                       radix_bits=6,
                       l1_tlb_sets=4, l1_tlb_ways=2, stlb_sets=8,
                       stlb_ways=4, pde_pwc_entries=4, pdpte_pwc_entries=2)
    for pidx in (2, 3):
        _compare(mc, POLICIES[pidx], random_trace(mc, seed=20 + pidx))
