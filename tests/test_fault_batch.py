"""Conflict-aware batched phase B vs the sequential fori_loop vs the oracle.

The batched fault engine (host ``fault_schedule`` + device
``alloc.alloc_many`` + vectorized commits) must be bit-identical to the
retained sequential per-thread path — placements and counters exactly,
cycle totals to float32 rounding — on ordinary traces, on adversarial
conflict-heavy traces (all threads faulting the same leaf / the same
page in one step), and through an OOM-during-burst.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CostConfig, MachineConfig, PolicyConfig,
                        TieredMemSimulator, Trace, pad_trace, sweep,
                        FIRST_TOUCH, INTERLEAVE, PT_BIND_ALL, PT_BIND_HIGH,
                        PT_FOLLOW_DATA)
from repro.core.ref import OracleSim
from repro.core.sim import (SCHED_DO, SCHED_NEED_LEAF, SCHED_NEED_MID,
                            SCHED_NEED_ROOT, SCHED_NEED_TOP, SCHED_WINNER,
                            fault_schedule, fault_step_mask)

EXACT_KEYS = ("l1_hits", "stlb_hits", "walks", "walk_mem_reads", "faults",
              "slow_allocs", "data_migrations", "demotions",
              "l4_mig_success", "l4_mig_already_dest", "l4_mig_in_dram",
              "l4_mig_sibling_guard", "l4_mig_lock_skip",
              "data_pages_dram", "data_pages_nvmm",
              "leaf_pages_dram", "leaf_pages_nvmm", "oom_killed", "oom_step")
CYCLE_KEYS = ("total_cycles", "walk_cycles", "stall_cycles",
              "data_mem_cycles", "fault_cycles", "migration_cycles")
PLACEMENT_ARRAYS = ("data_node", "leaf_node", "mid_node", "top_node",
                    "root_node", "leaf_dram_children", "node_free",
                    "node_reclaimable", "interleave_ptr")

POLICIES = [
    PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_FOLLOW_DATA,
                 autonuma=True, autonuma_period=16, autonuma_budget=32),
    PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_BIND_HIGH, mig=True,
                 autonuma=True, autonuma_period=16, autonuma_budget=32),
    PolicyConfig(data_policy=INTERLEAVE, pt_policy=PT_FOLLOW_DATA,
                 autonuma=False),
    PolicyConfig(data_policy=INTERLEAVE, pt_policy=PT_BIND_HIGH,
                 autonuma=True, autonuma_period=16, autonuma_budget=16),
]


def tiny_machine(**kw):
    kw.setdefault("n_threads", 4)
    kw.setdefault("dram_pages_per_node", 600)
    kw.setdefault("nvmm_pages_per_node", 2400)
    kw.setdefault("va_pages", 1 << 12)
    return MachineConfig(l1_tlb_sets=4, l1_tlb_ways=2, stlb_sets=8,
                         stlb_ways=4, pde_pwc_entries=4,
                         pdpte_pwc_entries=2, **kw)


def make_trace(mc, va, free_at=None):
    steps = va.shape[0]
    free_seg = np.full((steps,), -1, np.int32)
    if free_at is not None:
        free_seg[free_at] = 0
    seg = np.zeros((mc.n_map,), np.int32)
    seg[mc.n_map // 2:] = 1
    return Trace(va=va.astype(np.int32),
                 is_write=np.ones_like(va, bool),
                 free_seg=free_seg,
                 llc=np.full((steps,), 0.4, np.float32), seg_of_map=seg)


def random_trace(mc, steps=160, seed=0, free_at=None):
    rng = np.random.default_rng(seed)
    T = mc.n_threads
    va = np.where(rng.random((steps, T)) < 0.5,
                  rng.integers(0, mc.va_pages // 2, (steps, T)),
                  rng.integers(0, mc.va_pages, (steps, T))).astype(np.int32)
    va[rng.random((steps, T)) < 0.05] = -1
    return make_trace(mc, va, free_at)


def conflict_trace(mc):
    """Adversarial conflict structure, repeated past a mid-run free:

    all threads faulting the SAME page in one step (one winner, the rest
    wait), all threads faulting distinct pages under the SAME leaf PT page
    (every thread a data winner, one leaf-PT winner), a wait/fault mix and
    idle lanes.
    """
    half = mc.n_map // 2
    L = 1 << mc.radix_bits             # granules per leaf PT page
    rows = [[7, 7, 7, 7],              # same granule: 1 winner + 3 waits
            [L, L + 1, L + 2, L + 3],  # same (new) leaf PT entry, 4 pages
            [7, L, half, half],        # re-touch + conflicting new pair
            [-1, 12, -1, half + 5],    # idle threads
            [half + 5, 7, 12, L + 1]]  # all mapped: fault-free step
    va = np.array(rows * 12, np.int32)
    return make_trace(mc, va, free_at=30)


def sequential_trace(mc, steps):
    """Populate burst: every thread maps new pages every step."""
    T = mc.n_threads
    s = np.arange(steps, dtype=np.int32)[:, None]
    t = np.arange(T, dtype=np.int32)[None, :]
    va = np.minimum(s * T + t, mc.va_pages - 1).astype(np.int32)
    return make_trace(mc, va)


def assert_batched_matches_sequential(mc, pc, trace, cc=None):
    cc = cc or CostConfig()
    bat = TieredMemSimulator(mc=mc, cc=cc, pc=pc, phase_b="batched").run(trace)
    seq = TieredMemSimulator(mc=mc, cc=cc, pc=pc,
                             phase_b="sequential", debug=True).run(trace)
    s1, s2 = bat.summary(), seq.summary()
    for k in EXACT_KEYS:
        assert s1[k] == s2[k], f"{pc.label()}: {k}: {s1[k]} != {s2[k]}"
    for arr in PLACEMENT_ARRAYS:
        np.testing.assert_array_equal(
            np.asarray(getattr(bat.final_state, arr)),
            np.asarray(getattr(seq.final_state, arr)),
            err_msg=f"{pc.label()}: {arr}")
    for k in CYCLE_KEYS:
        np.testing.assert_allclose(s1[k], s2[k], rtol=1e-6,
                                   err_msg=f"{pc.label()}: {k}")
    for k in bat.timeline:
        np.testing.assert_allclose(bat.timeline[k], seq.timeline[k],
                                   rtol=1e-6, err_msg=f"{pc.label()}: tl/{k}")
    return bat


def assert_matches_oracle(res, mc, cc, pc, trace):
    oracle = OracleSim(mc, cc, pc)
    oracle.run(trace)          # also asserts the fault schedule internally
    ref = oracle.summary()
    s = res.summary()
    for k in EXACT_KEYS:
        assert s[k] == ref[k], f"{pc.label()}: oracle {k}: {s[k]} != {ref[k]}"
    for k in CYCLE_KEYS:
        np.testing.assert_allclose(s[k], ref[k], rtol=1e-5,
                                   err_msg=f"{pc.label()}: oracle {k}")


def test_batched_matches_sequential_and_oracle():
    mc = tiny_machine()
    cc = CostConfig()
    trace = random_trace(mc, seed=3, free_at=100)
    for pc in POLICIES:
        res = assert_batched_matches_sequential(mc, pc, trace, cc)
        assert_matches_oracle(res, mc, cc, pc, trace)


def test_conflict_heavy_trace():
    """All threads faulting the same leaf page / the same data page in one
    step: first-thread-wins masks must reproduce the sequential winner,
    the wait path, and the PT-entry sharing exactly."""
    mc = tiny_machine()
    cc = CostConfig()
    trace = conflict_trace(mc)
    sched = fault_schedule(trace, mc)
    # step 0: one winner, three same-page waiters; the winner allocates
    # the whole root/top/mid/leaf chain
    assert ((sched[0] & SCHED_DO) > 0).all()
    assert list((sched[0] & SCHED_WINNER) > 0) == [True, False, False, False]
    chain = (SCHED_NEED_ROOT | SCHED_NEED_TOP | SCHED_NEED_MID
             | SCHED_NEED_LEAF)
    assert sched[0, 0] & chain == chain
    # step 1: every thread is a data winner of a page under one NEW leaf
    # PT page; only thread 0 gets the leaf-allocation bit
    assert ((sched[1] & SCHED_WINNER) > 0).all()
    need_leaf = (sched[1] & SCHED_NEED_LEAF) > 0
    assert list(need_leaf) == [True, False, False, False]
    for pc in POLICIES:
        res = assert_batched_matches_sequential(mc, pc, trace, cc)
        assert_matches_oracle(res, mc, cc, pc, trace)


def test_oom_during_burst():
    """bind-all under a populate storm OOMs mid-burst (paper fig. 7); the
    batched engine must latch at the identical thread boundary."""
    mc = tiny_machine(dram_pages_per_node=150, nvmm_pages_per_node=1600,
                      va_pages=1 << 11, radix_bits=4)
    cc = CostConfig()
    trace = sequential_trace(mc, steps=256)
    for ptp in (PT_FOLLOW_DATA, PT_BIND_ALL, PT_BIND_HIGH):
        pc = PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=ptp,
                          autonuma=False)
        res = assert_batched_matches_sequential(mc, pc, trace, cc)
        assert_matches_oracle(res, mc, cc, pc, trace)
        if ptp == PT_BIND_ALL:
            assert res.summary()["oom_killed"]


def test_thp_machine():
    mc = tiny_machine(page_order=9)
    cc = CostConfig()
    trace = random_trace(mc, seed=51)
    for pc in POLICIES[:2]:
        res = assert_batched_matches_sequential(mc, pc, trace, cc)
        assert_matches_oracle(res, mc, cc, pc, trace)


def test_sweep_lanes_match_sequential_reference():
    """An 8-lane vmapped sweep of the batched engine vs 8 sequential-path
    runs: the select-penalty fix must not perturb any lane."""
    mc = tiny_machine()
    cc = CostConfig()
    trace = conflict_trace(mc)
    pols = [PolicyConfig(data_policy=d, pt_policy=p, autonuma=False)
            for d in (FIRST_TOUCH, INTERLEAVE)
            for p in (PT_FOLLOW_DATA, PT_BIND_ALL, PT_BIND_HIGH)]
    pols += [PolicyConfig(data_policy=d, pt_policy=PT_BIND_HIGH, mig=True,
                          autonuma=False) for d in (FIRST_TOUCH, INTERLEAVE)]
    batch = sweep(mc, cc, pols, trace, phase_b="batched")
    for pc, res in zip(pols, batch):
        seq = TieredMemSimulator(mc=mc, cc=cc, pc=pc,
                                 phase_b="sequential", debug=True).run(trace)
        s1, s2 = res.summary(), seq.summary()
        for k in EXACT_KEYS:
            assert s1[k] == s2[k], f"{pc.label()}: {k}: {s1[k]} != {s2[k]}"
        for arr in PLACEMENT_ARRAYS:
            np.testing.assert_array_equal(
                np.asarray(getattr(res.final_state, arr)),
                np.asarray(getattr(seq.final_state, arr)),
                err_msg=f"{pc.label()}: {arr}")
        for k in CYCLE_KEYS:
            np.testing.assert_allclose(s1[k], s2[k], rtol=1e-6,
                                       err_msg=f"{pc.label()}: {k}")


def test_fault_schedule_invariants():
    """Host-schedule structure: winners are unique per granule per step,
    NEED bits imply WINNER implies DO, fault_step_mask is the DO bits'
    step-wise any, and frees re-arm both data pages and leaf PT entries."""
    mc = tiny_machine()
    trace = random_trace(mc, seed=9, free_at=80)
    sched = fault_schedule(trace, mc)
    do = (sched & SCHED_DO) > 0
    winner = (sched & SCHED_WINNER) > 0
    needs = (sched & (SCHED_NEED_ROOT | SCHED_NEED_TOP | SCHED_NEED_MID
                      | SCHED_NEED_LEAF)) > 0
    assert not (winner & ~do).any()
    assert not (needs & ~winner).any()
    np.testing.assert_array_equal(fault_step_mask(trace, mc), do.any(axis=1))
    # winners are unique per granule within a step
    m = np.clip(trace.va >> mc.map_shift, 0, mc.n_map - 1)
    for s in range(trace.va.shape[0]):
        wm = m[s][winner[s]]
        assert len(wm) == len(set(wm.tolist()))
    # after the mid-run free, freed pages fault (DO) again
    freed = np.where(np.asarray(trace.seg_of_map) == 0)[0]
    post = slice(80, None)
    touched_freed = np.isin(m[post], freed) & (trace.va[post] >= 0)
    assert (do[post] & touched_freed).any()
    # memoization: identical trace content returns the cached array
    assert fault_schedule(trace, mc) is sched


def test_resumed_state_overapproximation():
    """Resuming from a pre-populated state: DO bits over-approximate and
    phase B must no-op on already-mapped pages (batched == sequential)."""
    mc = tiny_machine()
    pc = POLICIES[0]
    trace = random_trace(mc, seed=13, steps=96)
    full = assert_batched_matches_sequential(mc, pc, trace)
    first = Trace(va=trace.va[:48], is_write=trace.is_write[:48],
                  free_seg=trace.free_seg[:48], llc=trace.llc[:48],
                  seg_of_map=trace.seg_of_map)
    second = Trace(va=trace.va[48:], is_write=trace.is_write[48:],
                   free_seg=trace.free_seg[48:], llc=trace.llc[48:],
                   seg_of_map=trace.seg_of_map)
    sim = TieredMemSimulator(mc=mc, pc=pc, phase_b="batched")
    mid = sim.run(first)
    state = jax.tree.map(jnp.asarray, mid.final_state)
    res = sim.run(second, state=state)
    np.testing.assert_array_equal(np.asarray(res.final_state.data_node),
                                  np.asarray(full.final_state.data_node))
    assert res.summary()["faults"] == full.summary()["faults"]


def test_resume_after_cross_segment_free_reallocates_leaf():
    """A non-leaf-aligned segment free can clear a leaf PT page while a
    sibling granule's data page stays mapped.  Resuming after that free,
    the host schedule (built from an empty address space) pins its
    NEED_LEAF bit on a thread that never actually faults — the engine
    must still allocate the truly-missing leaf for the next real fault,
    exactly like the sequential path."""
    mc = tiny_machine(radix_bits=4)            # 16 granules per leaf
    T = mc.n_threads
    seg = np.zeros((mc.n_map,), np.int32)
    seg[8:] = 1                                # boundary mid-leaf-0

    def rows_to_trace(rows, free_at=None):
        va = np.array(rows, np.int32)
        free_seg = np.full((va.shape[0],), -1, np.int32)
        if free_at is not None:
            free_seg[free_at] = 0
        return Trace(va=va, is_write=np.ones_like(va, bool),
                     free_seg=free_seg,
                     llc=np.full((va.shape[0],), 0.4, np.float32),
                     seg_of_map=seg)

    # map granule 0 (seg 0) and granule 8 (seg 1) — both under leaf 0 —
    # then free seg 0: leaf 0 is cleared, granule 8 stays mapped
    first = rows_to_trace([[0, 8, 16, 24][:T] + [-1] * max(T - 4, 0),
                           [-1] * T], free_at=1)
    # resume: re-touch the surviving granule 8 (phantom host winner),
    # then genuinely fault granule 9 under the missing leaf 0
    second = rows_to_trace([[8] + [-1] * (T - 1),
                            [9] + [-1] * (T - 1)])
    pc = PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_FOLLOW_DATA,
                      autonuma=False)
    finals = {}
    for mode in ("batched", "sequential"):
        sim = TieredMemSimulator(mc=mc, pc=pc, phase_b=mode,
                                 debug=(mode == "sequential"))
        st = jax.tree.map(jnp.asarray, sim.run(first).final_state)
        assert int(np.asarray(st.leaf_node)[0]) == -1      # leaf freed
        assert int(np.asarray(st.data_node)[8]) >= 0       # page survives
        finals[mode] = sim.run(second, state=st).final_state
    for arr in PLACEMENT_ARRAYS:
        np.testing.assert_array_equal(
            np.asarray(getattr(finals["batched"], arr)),
            np.asarray(getattr(finals["sequential"], arr)), err_msg=arr)
    # the real fault re-allocated the orphaned leaf
    assert int(np.asarray(finals["batched"].leaf_node)[0]) >= 0
