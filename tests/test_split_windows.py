"""Window splitting and scan-tick hoisting: plans, bitwise identity,
and executable sharing.

``plan_windows`` classifies every block-step window from the host-side
event schedule (fast / full replay / hoisted scan tick / split span)
and quantizes segment capacities to powers of two so the split geometry
lands in the compile key without fracturing executable reuse.  These
tests pin:

  * the classification rules, including the partial-tail-with-faults
    stability rule and the pow2 capacity buckets;
  * bitwise identity of hoist/split windows against per-step execution,
    property-tested over random event placements (window boundaries,
    interiors, singletons) and policy families (AutoNUMA / TPP / Nomad
    / migration off), with seeded fallbacks when hypothesis is absent;
  * that traces whose event rows differ but whose quantized geometry
    matches share one sweep executable (compile count stays flat).
"""
import numpy as np
import pytest

from repro.core import (CostConfig, PolicyConfig, FIRST_TOUCH, INTERLEAVE,
                        PT_BIND_HIGH, PT_FOLLOW_DATA, nomad, sweep,
                        sweep_compile_count, tpp)
from repro.core.sim import (WIN_FAST, WIN_FULL, WIN_HOIST, WIN_SPLIT,
                            blocked_xs, plan_windows)

from test_blocked import (assert_blocked_matches_per_step, make_trace,
                          steady_trace, tiny_machine)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def quiet_masks(steps):
    return (np.zeros(steps, bool), np.zeros(steps, bool),
            np.zeros(steps, bool))


# ---------------------------------------------------------------------------
# 1. planner classification and geometry quantization
# ---------------------------------------------------------------------------

def test_plan_classifies_fast_hoist_split_full():
    S, B = 64, 16
    df, ds, hf = quiet_masks(S)
    p = plan_windows(df, ds, hf, S, B)
    assert p.counts == (4, 0, 0, 0)
    assert p.geom is None            # all-fast: no per-step body compiled
    assert int(p.emit_valid.sum()) == S

    ds[21] = True                    # lone scan tick in window 1 -> hoist
    p = plan_windows(df, ds, hf, S, B)
    assert p.counts == (3, 0, 1, 0)

    hf[36:39] = True                 # narrow fault span in window 2 -> split
    p = plan_windows(df, ds, hf, S, B)
    assert p.counts == (2, 0, 1, 1)

    df[49] = True                    # span 49..63 wider than block // 2:
    df[63] = True                    # window 3 replays in full
    p = plan_windows(df, ds, hf, S, B)
    assert p.counts == (1, 1, 1, 1)
    assert int(p.emit_valid.sum()) == S
    assert p.counts[WIN_FAST] + p.counts[WIN_FULL] \
        + p.counts[WIN_HOIST] + p.counts[WIN_SPLIT] == p.n_windows


def test_partial_tail_with_faults_replays_full():
    """In a partial tail window the span end is the trace's last faulting
    step, so letting it pick split geometry would make the compile key a
    function of trace length modulo block: the planner must fall back to
    a full replay there."""
    S, B = 40, 16                    # windows of 16, 16, and a tail of 8
    df, ds, hf = quiet_masks(S)
    hf[38] = True
    p = plan_windows(df, ds, hf, S, B)
    assert p.counts[WIN_FULL] == 1
    assert p.counts[WIN_SPLIT] == 0
    assert int(p.emit_valid.sum()) == S


def test_geometry_quantizes_to_pow2_buckets():
    S, B = 64, 16

    def one_fault(step):
        df, ds, hf = quiet_masks(S)
        hf[step] = True
        return plan_windows(df, ds, hf, S, B)

    # fault at window-1 rows 3 vs 4: both prefixes round up to capacity
    # 4 and both suffixes to 16, so the plans share geometry and shapes
    a, b = one_fault(19), one_fault(20)
    assert a.counts[WIN_SPLIT] == 1
    assert a.geom == b.geom
    assert a.emit_valid.shape == b.emit_valid.shape
    assert a.rows_in == b.rows_in
    # row 9 needs a 16-row prefix bucket: genuinely new geometry
    c = one_fault(25)
    assert c.geom != a.geom


# ---------------------------------------------------------------------------
# 2. hoisted scan ticks across migration-policy families
# ---------------------------------------------------------------------------

def test_hoist_engages_and_stays_bitwise():
    """period == block puts one scan tick at row 0 of every post-populate
    window: those windows must take the hoist branch (no per-step replay)
    and still match per-step bit for bit — AutoNUMA, TPP and Nomad all
    route their periodic work through the hoisted scan op."""
    mc = tiny_machine()
    cc = CostConfig()
    trace = steady_trace(mc, steps=192, seed=9)
    families = [
        PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_FOLLOW_DATA,
                     autonuma=True, autonuma_period=16, autonuma_budget=32),
        tpp(autonuma_period=16, autonuma_budget=32),
        nomad(autonuma_period=16, autonuma_budget=32),
    ]
    for pc in families:
        _, plan = blocked_xs(trace, mc, pc, block=16)
        assert plan.counts[WIN_HOIST] > 0, pc.label()
        assert_blocked_matches_per_step(mc, pc, trace, cc, block=16)


# ---------------------------------------------------------------------------
# 3. property test: random event rows vs the per-step reference
# ---------------------------------------------------------------------------

def fuzz_case(seed):
    rng = np.random.default_rng(seed)
    mc = tiny_machine()
    cc = CostConfig()
    block = int(rng.choice([8, 16]))
    n_w = int(rng.integers(3, 6))
    S = n_w * block - int(rng.integers(0, block // 2))  # maybe partial tail
    T = mc.n_threads

    # fault-free base: a short populate burst, then re-access of the pool
    pop_rows = 4
    pool = pop_rows * T
    s = np.arange(pop_rows, dtype=np.int64)[:, None]
    t = np.arange(T, dtype=np.int64)[None, :]
    pop = s * T + t
    run = rng.integers(0, pool, (S - pop_rows, T))
    va = (np.concatenate([pop, run]) << mc.map_shift).astype(np.int32)

    # inject fresh-granule fault rows at window boundaries, interiors and
    # the final (possibly partial) row
    fresh = pool
    candidates = ([int(x) for x in rng.integers(pop_rows, S, 3)]
                  + [2 * block - 1, 2 * block, S - 1])
    picks = sorted({c for c in candidates if pop_rows <= c < S})
    rng.shuffle(picks)
    for step in picks[:int(rng.integers(1, 5))]:
        width = int(rng.integers(1, T + 1))
        va[step, :width] = (np.arange(fresh, fresh + width)
                            << mc.map_shift).astype(np.int32)
        fresh += width
    free_at = int(rng.integers(pop_rows, S)) if rng.random() < 0.5 else None
    trace = make_trace(mc, va, free_at)

    period = int(rng.choice([8, 16, 32]))
    family = int(rng.integers(0, 4))
    if family == 0:
        pc = PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_FOLLOW_DATA,
                          autonuma=True, autonuma_period=period,
                          autonuma_budget=32)
    elif family == 1:
        pc = tpp(autonuma_period=period, autonuma_budget=32)
    elif family == 2:
        pc = nomad(autonuma_period=period, autonuma_budget=32)
    else:
        pc = PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_FOLLOW_DATA,
                          autonuma=False)
    assert_blocked_matches_per_step(mc, pc, trace, cc, block=block)


@pytest.mark.parametrize("seed", range(4))
def test_fuzz_split_hoist_vs_per_step_fixed_seeds(seed):
    """Deterministic property-style coverage (runs without hypothesis)."""
    fuzz_case(seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(st.integers(min_value=10, max_value=10 ** 6))
    def test_property_split_hoist_vs_per_step(seed):
        fuzz_case(seed)


# ---------------------------------------------------------------------------
# 4. executable sharing across traces with equal quantized geometry
# ---------------------------------------------------------------------------

def test_sweep_shares_executables_across_same_geometry():
    """Three traces, identical shapes, one single-row fault window each:
    fault at rows 3 and 4 of the window land in the same pow2 capacity
    bucket (prefix 4 / event 1 / suffix 16) and must reuse one compiled
    sweep; row 9 needs a wider prefix bucket and costs exactly one more."""
    mc = tiny_machine(va_pages=1 << 11)   # distinct mc: private cache keys
    cc = CostConfig()
    pcs = [PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_FOLLOW_DATA,
                        autonuma=False),
           PolicyConfig(data_policy=INTERLEAVE, pt_policy=PT_BIND_HIGH,
                        autonuma=False)]
    T = mc.n_threads
    pop_rows = 16                          # window 0 faults on every row
    pool = pop_rows * T

    def tr(fault_step, seed):
        s = np.arange(pop_rows, dtype=np.int64)[:, None]
        t = np.arange(T, dtype=np.int64)[None, :]
        pop = s * T + t
        run = np.random.default_rng(seed).integers(
            0, pool, (64 - pop_rows, T))
        va = (np.concatenate([pop, run]) << mc.map_shift).astype(np.int32)
        va[fault_step] = ((np.arange(pool, pool + T)
                           << mc.map_shift).astype(np.int32))
        return make_trace(mc, va)

    before = sweep_compile_count()
    sweep(mc, cc, pcs, tr(35, 1), block=16)    # window-2 row 3
    base = sweep_compile_count()
    assert base == before + 1
    # row 4 of its window: same quantized geometry, zero new compiles
    sweep(mc, cc, pcs, tr(36, 2), block=16)
    assert sweep_compile_count() == base
    # row 9: prefix capacity bucket doubles — exactly one new executable
    sweep(mc, cc, pcs, tr(41, 3), block=16)
    assert sweep_compile_count() == base + 1
