"""Batched policy-sweep engine vs sequential runs vs the pure-Python oracle.

The vmap refactor must not change semantics: every lane of a ``sweep()``
must be bit-identical (placement arrays, counters) to the corresponding
independent ``TieredMemSimulator`` run and to the ``core.ref`` oracle,
with cycle totals matching to float32 rounding.  The whole sweep must also
compile exactly once per trace shape.
"""
import numpy as np
import pytest

from repro.core import (CostConfig, MachineConfig, PolicyConfig,
                        TieredMemSimulator, Trace, pad_trace, sweep,
                        sweep_compile_count, FIRST_TOUCH, INTERLEAVE,
                        PT_BIND_ALL, PT_BIND_HIGH, PT_FOLLOW_DATA)
from repro.core.ref import OracleSim

EXACT_KEYS = ("l1_hits", "stlb_hits", "walks", "walk_mem_reads", "faults",
              "slow_allocs", "data_migrations", "demotions",
              "l4_mig_success", "l4_mig_already_dest", "l4_mig_in_dram",
              "l4_mig_sibling_guard", "l4_mig_lock_skip",
              "data_pages_dram", "data_pages_nvmm",
              "leaf_pages_dram", "leaf_pages_nvmm", "oom_killed", "oom_step")
CYCLE_KEYS = ("total_cycles", "walk_cycles", "stall_cycles",
              "data_mem_cycles", "fault_cycles", "migration_cycles")
PLACEMENT_ARRAYS = ("data_node", "leaf_node", "mid_node", "top_node",
                    "root_node", "leaf_dram_children", "node_free")

# The issue's sweep set: {first-touch, interleave} x {follow_data, BHi},
# plus Mig and the bind-all pathology.
POLICIES = [
    PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_FOLLOW_DATA,
                 autonuma=True, autonuma_period=16, autonuma_budget=32),
    PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_BIND_HIGH, mig=True,
                 autonuma=True, autonuma_period=16, autonuma_budget=32),
    PolicyConfig(data_policy=INTERLEAVE, pt_policy=PT_FOLLOW_DATA,
                 autonuma=False),
    PolicyConfig(data_policy=INTERLEAVE, pt_policy=PT_BIND_HIGH,
                 autonuma=True, autonuma_period=16, autonuma_budget=16),
]


def tiny_machine():
    return MachineConfig(n_threads=4, dram_pages_per_node=600,
                         nvmm_pages_per_node=2400, va_pages=1 << 12,
                         l1_tlb_sets=4, l1_tlb_ways=2, stlb_sets=8,
                         stlb_ways=4, pde_pwc_entries=4, pdpte_pwc_entries=2)


def random_trace(mc, steps=160, seed=0, free_at=None, name="rand"):
    rng = np.random.default_rng(seed)
    T = mc.n_threads
    va = np.where(rng.random((steps, T)) < 0.5,
                  rng.integers(0, mc.va_pages // 2, (steps, T)),
                  rng.integers(0, mc.va_pages, (steps, T))).astype(np.int32)
    va[rng.random((steps, T)) < 0.05] = -1
    wr = rng.random((steps, T)) < 0.3
    free_seg = np.full((steps,), -1, np.int32)
    if free_at is not None:
        free_seg[free_at] = 0
    seg = np.zeros((mc.n_map,), np.int32)
    seg[mc.n_map // 2:] = 1
    llc = np.full((steps,), 0.4, np.float32)
    return Trace(va=va, is_write=wr, free_seg=free_seg, llc=llc,
                 seg_of_map=seg, name=name)


def assert_lane_matches_sequential(res, seq):
    s1, s2 = res.summary(), seq.summary()
    for k in EXACT_KEYS:
        assert s1[k] == s2[k], f"{res.policy_label}: {k}: {s1[k]} != {s2[k]}"
    for arr in PLACEMENT_ARRAYS:
        np.testing.assert_array_equal(
            np.asarray(getattr(res.final_state, arr)),
            np.asarray(getattr(seq.final_state, arr)),
            err_msg=f"{res.policy_label}: {arr}")
    for k in CYCLE_KEYS:
        np.testing.assert_allclose(s1[k], s2[k], rtol=1e-6,
                                   err_msg=f"{res.policy_label}: {k}")
    for k in res.timeline:
        np.testing.assert_allclose(res.timeline[k], seq.timeline[k],
                                   rtol=1e-6,
                                   err_msg=f"{res.policy_label}: tl/{k}")


def assert_lane_matches_oracle(res, mc, cc, pc, trace):
    oracle = OracleSim(mc, cc, pc)
    oracle.run(trace)
    ref = oracle.summary()
    s = res.summary()
    for k in EXACT_KEYS:
        assert s[k] == ref[k], \
            f"{pc.label()}: oracle {k}: {s[k]} != {ref[k]}"
    for k in CYCLE_KEYS:
        np.testing.assert_allclose(s[k], ref[k], rtol=1e-5,
                                   err_msg=f"{pc.label()}: oracle {k}")


def test_sweep_matches_sequential_and_oracle():
    """One batched sweep == 4 independent runs == 4 oracle runs."""
    mc = tiny_machine()
    cc = CostConfig()
    trace = random_trace(mc, seed=3, free_at=100)

    batch = sweep(mc, cc, POLICIES, trace)
    assert len(batch) == len(POLICIES)
    for pc, res in zip(POLICIES, batch):
        seq = TieredMemSimulator(mc=mc, cc=cc, pc=pc).run(trace)
        assert_lane_matches_sequential(res, seq)
        assert_lane_matches_oracle(res, mc, cc, pc, trace)


def test_sweep_single_compile_per_trace_shape():
    """A >=4-policy sweep costs exactly one lax.scan compilation, and
    re-sweeping the same shape (other policies, other trace data) costs
    zero more.  The time-blocked engine tiles steps into fixed windows,
    so shapes quantize at window granularity: a step count landing in the
    same window count reuses the program for free, while one that adds a
    window compiles exactly once more."""
    mc = tiny_machine()
    cc = CostConfig()
    trace = random_trace(mc, seed=11, steps=96)

    before = sweep_compile_count()
    sweep(mc, cc, POLICIES, trace)
    after_first = sweep_compile_count()
    assert after_first == before + 1

    # same shape, different policy bundles and different trace content
    reordered = list(reversed(POLICIES))
    sweep(mc, cc, reordered, random_trace(mc, seed=12, steps=96))
    assert sweep_compile_count() == after_first

    # 96 and 128 steps both tile to two 64-step windows: free reuse
    sweep(mc, cc, POLICIES, random_trace(mc, seed=13, steps=128))
    assert sweep_compile_count() == after_first

    # a window count not seen before (5 windows — 3 was compiled by an
    # earlier test in this module) is a genuinely new shape: exactly one
    # more compile
    sweep(mc, cc, POLICIES, random_trace(mc, seed=14, steps=320))
    assert sweep_compile_count() == after_first + 1


def test_sweep_multi_trace_grid():
    """Policies x padded traces in one scan, including a mid-run free."""
    mc = tiny_machine()
    cc = CostConfig()
    policies = POLICIES[:2]
    traces = [random_trace(mc, seed=21, steps=120, name="a"),
              random_trace(mc, seed=22, steps=96, free_at=60, name="b")]
    steps = max(t.n_steps for t in traces)
    traces = [pad_trace(t, steps) for t in traces]

    grid = sweep(mc, cc, policies, traces)
    assert len(grid) == len(traces) and len(grid[0]) == len(policies)
    for trace, row in zip(traces, grid):
        for pc, res in zip(policies, row):
            assert res.trace_name == trace.name
            seq = TieredMemSimulator(mc=mc, cc=cc, pc=pc).run(trace)
            assert_lane_matches_sequential(res, seq)


def sequential_trace(mc, steps, name="seq"):
    """Sequential heap growth: every step maps new pages (and, with a small
    radix, keeps demanding new PT pages long after DRAM has filled)."""
    T = mc.n_threads
    s = np.arange(steps, dtype=np.int32)[:, None]
    t = np.arange(T, dtype=np.int32)[None, :]
    va = np.minimum(s * T + t, mc.va_pages - 1).astype(np.int32)
    return Trace(va=va, is_write=np.ones((steps, T), bool),
                 free_seg=np.full((steps,), -1, np.int32),
                 llc=np.full((steps,), 0.3, np.float32),
                 seg_of_map=np.zeros((mc.n_map,), np.int32), name=name)


def test_sweep_bind_all_oom_lane():
    """An OOM-ing bind-all lane must not perturb its sweep neighbours."""
    mc = MachineConfig(n_threads=4, dram_pages_per_node=150,
                       nvmm_pages_per_node=1600, va_pages=1 << 11,
                       radix_bits=4,
                       l1_tlb_sets=4, l1_tlb_ways=2, stlb_sets=8,
                       stlb_ways=4, pde_pwc_entries=4, pdpte_pwc_entries=2)
    cc = CostConfig()
    policies = [
        PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_FOLLOW_DATA,
                     autonuma=False),
        PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_BIND_ALL,
                     autonuma=False),
        PolicyConfig(data_policy=FIRST_TOUCH, pt_policy=PT_BIND_HIGH,
                     autonuma=False),
    ]
    trace = sequential_trace(mc, steps=256)
    batch = sweep(mc, cc, policies, trace)
    assert batch[1].summary()["oom_killed"], \
        "bind-all should OOM under memory pressure (paper fig. 7)"
    for pc, res in zip(policies, batch):
        seq = TieredMemSimulator(mc=mc, cc=cc, pc=pc).run(trace)
        assert_lane_matches_sequential(res, seq)
        assert_lane_matches_oracle(res, mc, cc, pc, trace)


def test_sweep_thp_machine():
    """fig13's setting: THP machine (3-level walks, PMD leaves)."""
    mc = MachineConfig(n_threads=4, dram_pages_per_node=600,
                       nvmm_pages_per_node=2400, va_pages=1 << 12,
                       page_order=9,
                       l1_tlb_sets=4, l1_tlb_ways=2, stlb_sets=8,
                       stlb_ways=4, pde_pwc_entries=4, pdpte_pwc_entries=2)
    cc = CostConfig()
    policies = [POLICIES[0], POLICIES[1]]
    trace = random_trace(mc, seed=51)
    for pc, res in zip(policies, sweep(mc, cc, policies, trace)):
        seq = TieredMemSimulator(mc=mc, cc=cc, pc=pc).run(trace)
        assert_lane_matches_sequential(res, seq)
        assert_lane_matches_oracle(res, mc, cc, pc, trace)


def test_sweep_rejects_mixed_periods_and_shapes():
    mc = tiny_machine()
    cc = CostConfig()
    tr = random_trace(mc, seed=41, steps=64)
    mixed = [PolicyConfig(autonuma=True, autonuma_period=16),
             PolicyConfig(autonuma=True, autonuma_period=32)]
    with pytest.raises(ValueError, match="autonuma_period"):
        sweep(mc, cc, mixed, tr)
    with pytest.raises(ValueError, match="shape"):
        sweep(mc, cc, POLICIES, [tr, random_trace(mc, seed=42, steps=65)])


def test_policy_config_rejects_bad_codes():
    with pytest.raises(ValueError, match="data_policy"):
        PolicyConfig(data_policy=PT_FOLLOW_DATA)   # PT code in data field
    with pytest.raises(ValueError, match="pt_policy"):
        PolicyConfig(pt_policy=99)
    with pytest.raises(ValueError, match="data_policy"):
        PolicyConfig(data_policy="first-touch")    # typo'd legacy spelling
    # legacy string spellings still normalize to codes
    pc = PolicyConfig(data_policy="interleave", pt_policy="bind_high")
    assert pc.data_policy == INTERLEAVE and pc.pt_policy == PT_BIND_HIGH
